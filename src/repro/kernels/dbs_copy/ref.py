"""Deprecation shim: the oracle lives in ``repro.kernels.dbs.ref``."""
from repro.kernels.dbs.ref import dbs_copy_ref  # noqa: F401
