"""Pure-jnp oracle for the DBS extent copy (CoW data plane)."""
from __future__ import annotations

import jax.numpy as jnp


def dbs_copy_ref(pool, src, dst, mask):
    """pool: (E, page, D); src/dst: (N,) extent ids; mask: (N,) bool.
    Copies pool[src[i]] -> pool[dst[i]] where mask[i]. Lanes must target
    distinct dst extents (DBS allocation guarantees this)."""
    safe_src = jnp.maximum(src, 0)
    safe_dst = jnp.maximum(dst, 0)
    vals = jnp.where(mask[:, None, None], pool[safe_src], pool[safe_dst])
    return pool.at[safe_dst].set(vals)
