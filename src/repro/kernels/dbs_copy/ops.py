"""Deprecation shim: the ops surface lives in ``repro.kernels.dbs.ops``."""
from repro.kernels.dbs.ops import (_use_interpret, dbs_copy,  # noqa: F401
                                   dbs_copy_pool, dbs_copy_reference,
                                   default_interpret)
