"""jit'd wrappers for the DBS extent-copy kernel.

``dbs_copy`` is the raw (E, page, D) entry point; ``dbs_copy_pool`` adapts
an engine payload pool with arbitrary trailing payload dims — it is the form
the fused engine step (core/fused.py) places on the copy-on-write hot path.
See docs/KERNELS.md for the grid/BlockSpec design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dbs_copy.kernel import dbs_copy as _dbs_copy_kernel
from repro.kernels.dbs_copy.ref import dbs_copy_ref


def default_interpret() -> bool:
    """Repo convention: Pallas kernels run compiled on TPU and fall back to
    ``interpret=True`` everywhere else (docs/KERNELS.md)."""
    return jax.default_backend() != "tpu"


_use_interpret = default_interpret  # back-compat alias


@jax.jit
def dbs_copy(pool, src, dst, mask):
    """Copy pool[src[i]] -> pool[dst[i]] where mask[i] (CoW data plane).

    pool: (E, page, D); trailing payload dims must be pre-flattened to D.
    """
    return _dbs_copy_kernel(pool, src, dst, mask,
                            interpret=default_interpret())


def dbs_copy_pool(pool, src, dst, mask, *, interpret=None, scratch=False):
    """Extent CoW copy over an (E, page, *payload) engine pool.

    Flattens the trailing payload dims to the kernel's (E, page, D) layout
    and restores them. Not jitted itself — it is traced inside the caller's
    program (the fused engine step), which is the whole point: the copy
    happens device-side with no intervening dispatch.

    Masked-off lanes are redirected to a scratch extent rather than clamped
    into the live range: grid steps run sequentially against the aliased
    output, but interpret mode reads each step's inputs from the *original*
    buffer, so a masked lane clamped onto a real lane's dst would overwrite
    the copy with stale contents. With ``scratch=True`` the pool's LAST row
    is that dump — the caller guarantees the allocator never hands it out
    (ReplicaGroup sizes pools to n_extents+1), keeping the kernel fully
    aliased. With ``scratch=False`` a zero row is appended and sliced off
    instead (two pool copies — fine for ad-hoc use, not the hot path).
    src/dst may be -1 on masked lanes (the WriteOps NULL convention); real
    lanes must be in range.
    """
    if interpret is None:
        interpret = default_interpret()
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    m = mask.astype(bool)
    if scratch:
        dump = e - 1                 # reserved row, never allocator-visible
        padded = flat
    else:
        dump = e
        padded = jnp.concatenate(
            [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
    src_r = jnp.where(m, jnp.maximum(src, 0), dump)  # masked: dump->dump
    dst_r = jnp.where(m, jnp.maximum(dst, 0), dump)
    out = _dbs_copy_kernel(padded, src_r, dst_r, m, interpret=interpret)
    return out[:e].reshape(pool.shape)


dbs_copy_reference = dbs_copy_ref
