"""jit'd wrapper for the DBS extent-copy kernel."""
from __future__ import annotations

import jax

from repro.kernels.dbs_copy.kernel import dbs_copy as _dbs_copy_kernel
from repro.kernels.dbs_copy.ref import dbs_copy_ref


def _use_interpret():
    return jax.default_backend() != "tpu"


@jax.jit
def dbs_copy(pool, src, dst, mask):
    """Copy pool[src[i]] -> pool[dst[i]] where mask[i] (CoW data plane).

    pool: (E, page, D); trailing payload dims must be pre-flattened to D.
    """
    return _dbs_copy_kernel(pool, src, dst, mask,
                            interpret=_use_interpret())


dbs_copy_reference = dbs_copy_ref
