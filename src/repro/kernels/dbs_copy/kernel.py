"""Deprecation shim: the kernel lives in ``repro.kernels.dbs.copy_kernel``."""
from repro.kernels.dbs.copy_kernel import dbs_copy  # noqa: F401
