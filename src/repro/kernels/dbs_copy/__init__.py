from repro.kernels.dbs_copy.ops import (dbs_copy, dbs_copy_pool,  # noqa: F401
                                        dbs_copy_reference)
