from repro.kernels.dbs_copy.ops import dbs_copy, dbs_copy_reference  # noqa: F401
