"""Deprecation shim: ``repro.kernels.dbs_copy`` moved into the unified
``repro.kernels.dbs`` package (which adds the ``dbs_rw`` scatter/gather
family and the kernel registry). These re-exports keep seed imports
working; new code should import ``repro.kernels.dbs``."""
import warnings

warnings.warn(
    "repro.kernels.dbs_copy is deprecated; import repro.kernels.dbs "
    "(the unified DBS kernel package) instead",
    DeprecationWarning, stacklevel=2)

from repro.kernels.dbs import (dbs_copy, dbs_copy_pool,  # noqa: F401,E402
                               dbs_copy_reference)
