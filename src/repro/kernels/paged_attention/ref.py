"""Pure-jnp oracle for paged decode attention (DBS read through block table).

Hole semantics match the DBS data plane (``dbs_rw_read`` / the fused read
gather): a block-table entry of -1 is an unallocated page — the gather
clamps the index so nothing reads out of bounds, and every position on a
hole page is masked out of the softmax.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, pool_k, pool_v, block_table, lengths, *,
                        window: int = 0, logit_cap: float = 0.0, scale=None):
    """q: (B,H,hd); pools: (E,page,KV,hd); block_table: (B,P) extent ids
    (holes -1); lengths: (B,) tokens in cache (query attends to positions
    < lengths, i.e. the query position is lengths-1 having just been
    written). Returns (B,H,hd) fp32."""
    b, h, d = q.shape
    e, page, kv, _ = pool_k.shape
    p_max = block_table.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    tbl = jnp.maximum(block_table, 0)               # clamped gather
    k = pool_k[tbl]                                 # (B,P,page,KV,hd)
    v = pool_v[tbl]
    k = k.reshape(b, p_max * page, kv, -1)
    v = v.reshape(b, p_max * page, kv, -1)
    pos = jnp.arange(p_max * page)
    valid = pos[None, :] < lengths[:, None]         # (B,S)
    # hole pages contribute nothing, whatever extent row the clamp gathered
    valid &= jnp.repeat(block_table >= 0, page, axis=1)
    if window and window > 0:
        valid &= pos[None, :] > (lengths[:, None] - 1 - window)

    qf = q.astype(jnp.float32).reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) * scale
    if logit_cap:
        logits = jnp.tanh(logits / logit_cap) * logit_cap
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid[:, None, None], w, 0.0)     # all-hole lanes -> 0
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, v.shape[-1])


def paged_attention_pool_ref(q, pool, block_table, lengths, *, k_plane,
                             v_plane, window: int = 0, logit_cap: float = 0.0,
                             scale=None):
    """Plane-indexed oracle over ONE engine extent pool
    (E, page, n_planes, KV, hd) — the XLA twin of
    ``kernel.paged_attention_pool_fwd`` (serving's ``kernel="xla"`` route
    and the parity tests' reference)."""
    return paged_attention_ref(q, pool[:, :, k_plane], pool[:, :, v_plane],
                               block_table, lengths, window=window,
                               logit_cap=logit_cap, scale=scale)
