"""Pure-jnp oracle for paged decode attention (DBS read through block table)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, pool_k, pool_v, block_table, lengths, *,
                        window: int = 0, logit_cap: float = 0.0, scale=None):
    """q: (B,H,hd); pools: (E,page,KV,hd); block_table: (B,P) extent ids;
    lengths: (B,) tokens in cache (query attends to positions < lengths,
    i.e. the query position is lengths-1 having just been written).
    Returns (B,H,hd) fp32."""
    b, h, d = q.shape
    e, page, kv, _ = pool_k.shape
    p_max = block_table.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    k = pool_k[block_table]                         # (B,P,page,KV,hd)
    v = pool_v[block_table]
    k = k.reshape(b, p_max * page, kv, -1)
    v = v.reshape(b, p_max * page, kv, -1)
    pos = jnp.arange(p_max * page)
    valid = pos[None, :] < lengths[:, None]         # (B,S)
    if window and window > 0:
        valid &= pos[None, :] > (lengths[:, None] - 1 - window)

    qf = q.astype(jnp.float32).reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) * scale
    if logit_cap:
        logits = jnp.tanh(logits / logit_cap) * logit_cap
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, v.shape[-1])
