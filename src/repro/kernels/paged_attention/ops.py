"""jit'd wrapper for the paged decode attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_fwd
from repro.kernels.paged_attention.ref import paged_attention_ref


def _use_interpret():
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window", "logit_cap", "scale"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, window=0,
                    logit_cap=0.0, scale=None):
    """q: (B,H,hd) one decode token per sequence; pools (E,page,KV,hd);
    block_table (B,P) extent ids; lengths (B,). Returns (B,H,hd_v)."""
    return paged_attention_fwd(q, pool_k, pool_v, block_table, lengths,
                               window=window, logit_cap=logit_cap,
                               scale=scale, interpret=_use_interpret())


paged_attention_reference = paged_attention_ref
