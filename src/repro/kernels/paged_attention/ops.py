"""jit'd wrappers for the paged decode attention kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (paged_attention_fwd,
                                                  paged_attention_pool_fwd)
from repro.kernels.paged_attention.ref import (paged_attention_pool_ref,
                                               paged_attention_ref)


def _use_interpret():
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window", "logit_cap", "scale"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, window=0,
                    logit_cap=0.0, scale=None):
    """q: (B,H,hd) one decode token per sequence; pools (E,page,KV,hd);
    block_table (B,P) extent ids (holes -1); lengths (B,).
    Returns (B,H,hd_v)."""
    return paged_attention_fwd(q, pool_k, pool_v, block_table, lengths,
                               window=window, logit_cap=logit_cap,
                               scale=scale, interpret=_use_interpret())


@partial(jax.jit, static_argnames=("k_plane", "v_plane", "window",
                                   "logit_cap", "scale"))
def paged_attention_pool(q, pool, block_table, lengths, *, k_plane, v_plane,
                         window=0, logit_cap=0.0, scale=None):
    """Zero-copy serving entry point: attend over two planes of ONE engine
    extent pool (E, page, n_planes, KV, hd) through the volume extent map.
    Standalone jit for direct callers; inside an outer jit (the serving
    decode program) call ``paged_attention_pool_fwd`` directly."""
    return paged_attention_pool_fwd(q, pool, block_table, lengths,
                                    k_plane=k_plane, v_plane=v_plane,
                                    window=window, logit_cap=logit_cap,
                                    scale=scale, interpret=_use_interpret())


paged_attention_reference = paged_attention_ref
paged_attention_pool_reference = paged_attention_pool_ref
