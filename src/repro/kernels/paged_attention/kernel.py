"""Paged decode attention as a Pallas TPU kernel — the DBS read path.

The block table (the volume's in-memory extent map, paper §IV-D) is a
*scalar-prefetch* operand: BlockSpec index_maps dereference it to stream
exactly the extents owned by each sequence HBM->VMEM, page by page — the
TPU analogue of DBS reading 1 MB extents off NVMe with O(1) lookups. The
online-softmax accumulator persists in VMEM scratch across the sequential
page grid dimension.

Hole pages (``table[vol, page] == -1``, exactly the sentinel
``dbs_rw_read`` masks) are handled the same way as in the DBS data plane:
the index map clamps the extent id to 0 so the prefetcher never DMAs a
negative row, and the kernel skips the page entirely — a hole contributes
nothing to the softmax. Pages past a sequence's length are skipped with
@pl.when too (their DMA is still issued by the prefetcher — acceptable
because the serving engine sizes tables to ceil(len/page); fully-empty
tails only exist transiently).

``paged_attention_pool_fwd`` is the zero-copy serving entry point: K and V
are not separate caches but two *planes* of ONE engine extent pool
``(E, page, n_planes, KV, hd)`` — the very pool the fused/sharded step
scatters write SQEs into (core/fused.py). The kernel gathers directly from
that pool through the volume's extent map; no intermediate copy of the KV
cache ever exists.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
            *, scale, window, logit_cap, page, kv, g):
    b = pl.program_id(0)
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[b]
    base = ip * page
    # hole pages (extent -1: never written, or trimmed) contribute nothing —
    # the same sentinel dbs_rw_read masks on the block-device read path
    run = (base < length) & (tbl_ref[b, ip] >= 0)
    if window:  # pages wholly below the sliding window are skipped too
        run &= (base + page - 1) > (length - 1 - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                     # (H, hd)
        # k/v blocks arrive as (page, KV, hd) from split pools or
        # (page, 1, KV, hd) as one plane of the engine pool — same layout
        k = k_ref[...].reshape(page, kv, -1).astype(jnp.float32)
        v = v_ref[...].reshape(page, kv, -1).astype(jnp.float32)
        h, d = q.shape
        qg = q.reshape(kv, g, d)
        logits = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale      # (KV, g, page)
        if logit_cap:
            logits = jnp.tanh(logits / logit_cap) * logit_cap
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (kv, g, page), 2)
        valid = pos < length
        if window:
            valid &= pos > (length - 1 - window)
        logits = jnp.where(valid, logits, NEG_INF)
        m_prev = m_sc[...]                                   # (KV, g)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, -1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # (KV, g, hd_v)
        acc_sc[...] = acc_sc[...] * corr[..., None] + pv
        m_sc[...] = m_new

    @pl.when(ip == pl.num_programs(1) - 1)
    def _fin():
        out = acc_sc[...] / jnp.maximum(l_sc[...][..., None], 1e-30)
        o_ref[0] = out.reshape(kv * g, -1).astype(o_ref.dtype)


def _call(q, operands, in_specs, block_table, lengths, *, page, kv, dv,
          window, logit_cap, scale, interpret):
    b, h, d = q.shape
    p_max = block_table.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             logit_cap=logit_cap, page=page, kv=kv, g=g)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,          # block_table, lengths
            grid=(b, p_max),
            in_specs=[
                pl.BlockSpec((1, h, d), lambda b_, p_, tbl, ln: (b_, 0, 0)),
            ] + in_specs,
            out_specs=pl.BlockSpec((1, h, dv),
                                   lambda b_, p_, tbl, ln: (b_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, dv), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, *operands)


def paged_attention_fwd(q, pool_k, pool_v, block_table, lengths, *,
                        window=0, logit_cap=0.0, scale=None, interpret=True):
    """q: (B,H,hd); pools: (E,page,KV,hd_{k,v}); block_table: (B,P);
    lengths: (B,). Hole pages (extent -1) are skipped. Returns (B,H,hd_v)."""
    e, page, kv, dk = pool_k.shape
    dv = pool_v.shape[-1]
    in_specs = [
        # clamp: the prefetcher must never DMA a negative extent row; the
        # kernel's `run` guard discards whatever row 0 holds for hole pages
        pl.BlockSpec((1, page, kv, dk),
                     lambda b_, p_, tbl, ln: (jnp.maximum(tbl[b_, p_], 0),
                                              0, 0, 0)),
        pl.BlockSpec((1, page, kv, dv),
                     lambda b_, p_, tbl, ln: (jnp.maximum(tbl[b_, p_], 0),
                                              0, 0, 0)),
    ]
    return _call(q, (pool_k, pool_v), in_specs, block_table, lengths,
                 page=page, kv=kv, dv=dv, window=window, logit_cap=logit_cap,
                 scale=scale, interpret=interpret)


def paged_attention_pool_fwd(q, pool, block_table, lengths, *, k_plane,
                             v_plane, window=0, logit_cap=0.0, scale=None,
                             interpret=True):
    """Zero-copy variant: gather K/V straight out of ONE engine extent pool.

    q: (B,H,hd); pool: (E, page, n_planes, KV, hd) — the fused/sharded
    engine's payload pool, where plane ``2*l`` holds layer l's keys and
    ``2*l+1`` its values (serving/engine.py); block_table: (B,P) rows of
    the volume extent map (holes -1); lengths: (B,). The BlockSpec index
    maps stream exactly two (page, KV, hd) planes of each owned extent —
    the block device IS the KV cache, no staging copy."""
    e, page, n_planes, kv, d = pool.shape
    in_specs = [
        pl.BlockSpec((1, page, 1, kv, d),
                     lambda b_, p_, tbl, ln: (jnp.maximum(tbl[b_, p_], 0),
                                              0, k_plane, 0, 0)),
        pl.BlockSpec((1, page, 1, kv, d),
                     lambda b_, p_, tbl, ln: (jnp.maximum(tbl[b_, p_], 0),
                                              0, v_plane, 0, 0)),
    ]
    return _call(q, (pool, pool), in_specs, block_table, lengths,
                 page=page, kv=kv, dv=d, window=window, logit_cap=logit_cap,
                 scale=scale, interpret=interpret)
