from repro.kernels.paged_attention.ops import (paged_attention,  # noqa: F401
                                               paged_attention_reference)
