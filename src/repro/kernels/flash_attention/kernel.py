"""FlashAttention forward as a Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv dim is the
minor(sequential) grid dimension, so the online-softmax accumulators live in
VMEM scratch and persist across kv steps (TPU grids execute sequentially).
Q/K/V blocks are staged HBM->VMEM by BlockSpec; MXU-aligned block shapes
(multiples of 128 on the sequence dims, head_dim is the lane dim).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, causal, window, logit_cap, sk, sq, bq, bk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * bq + (sk - sq)       # suffix-aligned absolute positions
    k_start = ik * bk

    # block-level early-out bounds (still a sequential grid step, but the
    # masked block skips the MXU work entirely)
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > (qpos - window)

    run = jnp.any(mask) if (causal or window) else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if logit_cap:
            logits = jnp.tanh(logits / logit_cap) * logit_cap
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_sc[...]                              # (bq, 1)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _fin():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                        scale=None, block_q=256, block_k=256,
                        interpret=True):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd) -> (B,H,Sq,hd)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    grid = (b, h, sq // bq, sk // bk)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, logit_cap=logit_cap,
                             sk=sk, sq=sq, bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
