"""Pure-jnp oracle for flash attention (independent of models.attention)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window: int = 0,
                  logit_cap: float = 0.0, scale=None):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd). Queries at positions
    Sk-Sq..Sk-1 (suffix alignment). Returns (B,H,Sq,hd) fp32."""
    b, h, sq, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, kv, g, sq, d)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32)) * scale
    if logit_cap:
        logits = jnp.tanh(logits / logit_cap) * logit_cap
    sk = k.shape[2]
    qpos = jnp.arange(sq) + (sk - sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d)
