"""jit'd public wrapper: layout adaptation + backend dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _use_interpret():
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window", "logit_cap", "scale",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, window=0,
                    logit_cap=0.0, scale=None, block_q=256, block_k=256):
    """Model-layout entry: q (B,S,H,hd); k,v (B,S,KV,hd) -> (B,S,H,hd).

    Positions are suffix-aligned (standard causal LM); q_pos/k_pos args are
    accepted for API parity with the XLA paths and ignored (they are always
    arange in train/prefill).
    """
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_fwd(qt, kt, vt, causal=True, window=window,
                              logit_cap=logit_cap, scale=scale,
                              block_q=block_q, block_k=block_k,
                              interpret=_use_interpret())
    return out.swapaxes(1, 2).astype(q.dtype)


def flash_attention_reference(q, k, v, *, window=0, logit_cap=0.0, scale=None):
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = attention_ref(qt, kt, vt, causal=True, window=window,
                        logit_cap=logit_cap, scale=scale)
    return out.swapaxes(1, 2).astype(q.dtype)
