"""RWKV-6 chunked recurrence as a Pallas TPU kernel.

Grid: (batch, heads, chunks) — chunks is the sequential minor dimension; the
(hd x hd) recurrent state lives in fp32 VMEM scratch across chunk steps.
Within a chunk the recurrence is evaluated in its quadratic "linear
attention with decay" form (MXU matmuls over (C, hd) tiles), the same
schedule as models/ssm.py's XLA path — chunked scan states HBM-resident
there, VREG/VMEM-resident here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_sc, *,
            chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_sc[...] = jnp.zeros_like(s_sc)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    logw = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    s_in = s_sc[...]                             # (hd, hd)

    cum = jnp.cumsum(logw, axis=0)
    cum_excl = cum - logw
    r_dec = r * jnp.exp(cum_excl)
    # inter-chunk
    y = jax.lax.dot_general(r_dec, s_in, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk (strictly lower triangular)
    att = jax.lax.dot_general(r_dec, k * jnp.exp(-cum),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C,C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(cols < rows, att, 0.0)
    y = y + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal bonus
    y = y + jnp.sum(r * (u[None, :] * k), axis=-1, keepdims=True) * v
    # state update
    total = cum[-1]
    k_dec = k * jnp.exp(total[None, :] - cum)
    s_new = jnp.exp(total)[:, None] * s_in + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_sc[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == pl.num_programs(2) - 1)
    def _fin():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def rwkv6_scan_fwd(r, k, v, logw, u, *, chunk=64, interpret=True):
    """r,k,v,logw: (B,S,H,hd); u: (H,hd). Returns (y, s_final (B,H,hd,hd))."""
    b, s, h, d = r.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    grid = (b, h, s // c)
    # layout: (B,H,S,hd) blocks
    rt, kt, vt, wt = (t.swapaxes(1, 2) for t in (r, k, v, logw))

    kern = functools.partial(_kernel, chunk=c)
    y, s_f = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, ic: (h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return y.swapaxes(1, 2), s_f
