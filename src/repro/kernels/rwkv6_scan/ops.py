"""jit'd wrapper for the RWKV-6 chunked-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_fwd
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def _use_interpret():
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, logw, u, *, chunk=64):
    """r,k,v,logw: (B,S,H,hd); u: (H,hd) -> (y (B,S,H,hd), s (B,H,hd,hd))."""
    return rwkv6_scan_fwd(r, k, v, logw, u, chunk=chunk,
                          interpret=_use_interpret())


rwkv6_scan_reference = rwkv6_scan_ref
