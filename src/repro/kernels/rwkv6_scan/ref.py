"""Pure-jnp oracle: RWKV-6 recurrence, step-by-step (no chunking)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u, s0):
    """r,k,v,logw: (B,S,H,hd) fp32; u: (H,hd); s0: (B,H,hd,hd).

    y_t = r_t @ (S_{t-1} + (u*k_t)^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns (y (B,S,H,hd), s_final).
    """
    w = jnp.exp(logw.astype(jnp.float32))

    def step(s, xs):
        rt, kt, vt, wt = xs                              # (B,H,hd)
        att = s + (u[None] * kt)[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s = wt[..., :, None] * s + kt[..., :, None] * vt[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s_f, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), s_f
