# dbs_copy is a deprecation shim (warns on import) — no longer eagerly
# imported here; reach it explicitly or use repro.kernels.dbs
from repro.kernels import flash_attention, paged_attention, rwkv6_scan  # noqa: F401
