from repro.kernels import dbs_copy, flash_attention, paged_attention, rwkv6_scan  # noqa: F401
