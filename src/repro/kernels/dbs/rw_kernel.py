"""``dbs_rw``: the DBS write-scatter / read-gather pair as Pallas kernels.

The write kernel owns the WHOLE write data plane of a batch — CoW extent
copy AND payload block stores in one pass — where ``dbs_copy`` only ran the
copy half and left the block scatter to XLA. The read kernel owns the
round-robin gather, hole masking included. Both follow the jetstream
ragged-attention model: a 1-D grid with scalar-prefetch operands driving
the BlockSpec index maps, so each grid step's HBM<->VMEM DMAs are issued
from data-dependent extent ids and double-buffered by the Pallas pipeline
emitter (step i+1's row fetch overlaps step i's compute/write-back).

Write grid: one step per batch lane, but only GROUP LEADER lanes touch a
real extent row — a leader composes its destination row per block from
either a member lane's payload (``lane_of``) or the source row (the CoW
source when copying, the destination itself when writing in place) and
writes the row ONCE. Routing every non-leader/masked lane to a reserved
dump row is what makes the kernel safe under the interpret-mode staleness
rule (docs/KERNELS.md): no two grid steps ever write the same live row, and
no step reads a row another step wrote.

Read grid: one step per read lane; the index map DMAs exactly the (1, 1, D)
block named by the clamped extent id, and the kernel masks holes
(``ext < 0``) to zeros in VMEM using the RAW extent id, which rides along
as a second scalar-prefetch operand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _write_kernel(src_ref, dst_ref, lane_ref, src_row, payload, o_ref):
    i = pl.program_id(0)
    lanes = lane_ref[i]                    # (page,) writing lane, -1 = keep
    take = lanes >= 0
    rows = payload[jnp.maximum(lanes, 0)]  # (page, D)
    o_ref[...] = jnp.where(take[None, :, None], rows[None], src_row[...])


def dbs_rw_write(pool, src, dst, lane_of, payload, *, interpret=True):
    """pool: (E, page, D); src/dst: (B,) int32 extent ids; lane_of: (B, page)
    int32 block -> payload lane (-1 keeps the source block); payload: (B, D).

    src/dst must be PRE-ROUTED (ops.py ``_route_writes``): every live row is
    named by exactly one lane, and inert lanes point src == dst at a dump
    row so their write is a bit-identical no-op.
    """
    e, page, d = pool.shape
    b = src.shape[0]
    return pl.pallas_call(
        _write_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,          # src, dst, lane_of
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, page, d),
                             lambda i, s, dt, ln: (s[i], 0, 0)),
                # whole payload: constant index map, so the pipeline keeps
                # it resident in VMEM instead of re-fetching per step
                pl.BlockSpec((b, d), lambda i, s, dt, ln: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page, d),
                                   lambda i, s, dt, ln: (dt[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},        # pool (first tensor arg) -> out
        interpret=interpret,
    )(src, dst, lane_of, pool, payload)


def _read_kernel(ext_ref, extc_ref, blk_ref, blk, o_ref):
    i = pl.program_id(0)
    o_ref[...] = jnp.where(ext_ref[i] >= 0, blk[...], 0)


def dbs_rw_read(pool, ext, block, *, interpret=True):
    """pool: (E, page, D); ext: (B,) int32, -1 = hole (reads as zeros);
    block: (B,) int32 block offset within the page. Returns (B, D)."""
    e, page, d = pool.shape
    b = ext.shape[0]
    extc = jnp.clip(ext, 0, e - 1)          # clamped id drives the DMA...
    blkc = jnp.clip(block, 0, page - 1)
    out = pl.pallas_call(
        _read_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,          # ext (raw), ext (clamped), block
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, 1, d),
                             lambda i, e_, ec, bk: (ec[i], bk[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, d),
                                   lambda i, e_, ec, bk: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, 1, d), pool.dtype),
        interpret=interpret,
    )(ext, extc, blkc, pool)                # ...the raw id masks the hole
    return out[:, 0, :]
