"""The DBS kernel registry: named data-plane implementations.

Mirrors the backend (core/backends.py ``register_backend``) and transport
(core/transport.py ``register_transport``) registries: a name resolves to a
``DBSKernel`` — one ``write`` (the whole write data plane of a batch: CoW
extent copies + payload block stores) and one ``read`` (the hole-masked
block gather) — and ``EngineConfig(kernel=...)`` threads the name through
every engine backend (fused/sharded/ring) instead of the old ``cow=``
string branch in ``fused._cow_apply``.

Built-ins:

========  ==================================================================
name      implementation
========  ==================================================================
pallas    ``dbs_rw`` Pallas kernels (rw_kernel.py): the whole step's data
          movement is kernel-owned (compiled on TPU, interpret elsewhere)
xla       ``dbs.apply_write_ops`` gather/scatter + the XLA hole-masked
          gather — the selectable reference path (the old ``cow="ref"``)
ref       pure-jnp mirror of the kernels' row-composition formulation
          (ref.py) — triangulates pallas against xla in the tests
copy      the PR-3 hybrid: ``dbs_copy`` Pallas CoW copy + XLA block
          scatter/gather (the old ``cow="pallas"`` data plane)
========  ==================================================================

All four are bit-identical on engine batches; the registry exists so the
choice is a config axis (and so embedders can register their own, like the
backend registry allows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dbs import ops as _ops


@dataclass(frozen=True)
class DBSKernel:
    """One registered data plane.

    ``write(pool, ops, payload, block_offsets) -> pool'`` applies a
    ``dbs.WriteOps`` batch to an (E, page, *payload) pool (the engine pool
    convention: the last row is the reserved scratch/dump extent).
    ``read(pool, ext, block_offsets) -> (B, *payload)`` gathers one block
    per lane, holes (``ext < 0``) masked to zeros.
    """
    name: str
    write: Callable
    read: Callable


_REGISTRY: Dict[str, DBSKernel] = {}


def register_kernel(name: str, write: Optional[Callable] = None, *,
                    read: Optional[Callable] = None,
                    override: bool = False) -> DBSKernel:
    """Register a ``DBSKernel`` under ``name`` from its two callables (or
    pass a ready ``DBSKernel`` as ``write``). Duplicate names raise (the
    uniform registry contract); embedders that mean to shadow a built-in
    pass ``override=True``."""
    if isinstance(write, DBSKernel):
        kern = write
    else:
        if write is None or read is None:
            raise ValueError("register_kernel needs write= and read= "
                             "callables (or a DBSKernel)")
        kern = DBSKernel(name=name, write=write, read=read)
    if name in _REGISTRY and not override:
        raise ValueError(
            f"duplicate kernel {name!r} (registered: "
            f"{', '.join(available_kernels())}); pass override=True "
            "to replace")
    _REGISTRY[name] = kern
    return kern


def available_kernels() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_kernel(name: str) -> DBSKernel:
    """Resolve the kernel registered under ``name``."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r} (registered: "
            f"{', '.join(available_kernels())})") from None


def resolve_kernel_name(cfg) -> str:
    """``EngineConfig`` -> registry name, honouring the legacy ``cow`` axis:
    an explicit ``kernel`` wins; ``kernel="auto"`` follows ``cow``
    (``"pallas"``/``"ref"`` keep their historical meaning, ``"auto"`` picks
    the Pallas path on TPU and the XLA reference elsewhere)."""
    kernel = getattr(cfg, "kernel", "auto")
    if kernel != "auto":
        return kernel
    cow = getattr(cfg, "cow", "auto")
    if cow == "pallas":
        return "pallas"
    if cow == "ref":
        return "xla"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# built-in entries
# ---------------------------------------------------------------------------
def _xla_write(pool, ops, payload, block_offsets):
    from repro.core import dbs
    return dbs.apply_write_ops(pool, ops, payload, block_offsets)


def _xla_read(pool, ext, block_offsets):
    got = pool[jnp.maximum(ext, 0), block_offsets]
    m = (ext >= 0).reshape(ext.shape + (1,) * (got.ndim - ext.ndim))
    return jnp.where(m, got, 0)


def _copy_write(pool, ops, payload, block_offsets):
    # the PR-3 hybrid: Pallas CoW copy, then the XLA block scatter.
    # write_pages guarantees cow_src>=0 implies ok, but gate on ok anyway so
    # a hostile ops batch can never route a copy through a clamped dst.
    pool = _ops.dbs_copy_pool(pool, ops.cow_src, ops.dst,
                              (ops.cow_src >= 0) & ops.ok, scratch=True)
    # not-ok lanes scatter out of bounds and are dropped (write_pages note)
    drop_dst = jnp.where(ops.ok, jnp.maximum(ops.dst, 0), pool.shape[0])
    return pool.at[drop_dst, block_offsets].set(payload, mode="drop")


def _ref_write(pool, ops, payload, block_offsets):
    from repro.kernels.dbs.ref import dbs_rw_write_ref
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    pay = payload.reshape(payload.shape[0], -1)
    src, dst, lane_of = _ops._route_writes(ops, page, block_offsets, e - 1)
    return dbs_rw_write_ref(flat, src, dst, lane_of, pay).reshape(pool.shape)


def _ref_read(pool, ext, block_offsets):
    from repro.kernels.dbs.ref import dbs_rw_read_ref
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    out = dbs_rw_read_ref(flat, ext, block_offsets)
    return out.reshape((ext.shape[0],) + pool.shape[2:])


register_kernel("pallas", _ops.dbs_rw_write_pool, read=_ops.dbs_rw_read_pool)
register_kernel("xla", _xla_write, read=_xla_read)
register_kernel("ref", _ref_write, read=_ref_read)
register_kernel("copy", _copy_write, read=_xla_read)
