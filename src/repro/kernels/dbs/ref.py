"""Pure-jnp oracles for the DBS kernel family (CoW copy + rw scatter/gather).

``dbs_rw_write_ref``/``dbs_rw_read_ref`` mirror the KERNELS' row-composition
formulation (one composed row per routed lane), so registry ``kernel="ref"``
exercises the Pallas data layout without Pallas — a third implementation the
equivalence tests triangulate against ``kernel="xla"`` (apply_write_ops'
gather/scatter formulation).
"""
from __future__ import annotations

import jax.numpy as jnp


def dbs_copy_ref(pool, src, dst, mask):
    """pool: (E, page, D); src/dst: (N,) extent ids; mask: (N,) bool.
    Copies pool[src[i]] -> pool[dst[i]] where mask[i]. Lanes must target
    distinct dst extents (DBS allocation guarantees this)."""
    safe_src = jnp.maximum(src, 0)
    safe_dst = jnp.maximum(dst, 0)
    vals = jnp.where(mask[:, None, None], pool[safe_src], pool[safe_dst])
    return pool.at[safe_dst].set(vals)


def dbs_rw_write_ref(pool, src, dst, lane_of, payload):
    """Row-composition mirror of ``rw_kernel._write_kernel``: for lane i,
    ``out[dst[i]]`` = ``pool[src[i]]`` with block j replaced by
    ``payload[lane_of[i, j]]`` wherever ``lane_of[i, j] >= 0``. Inputs must
    be pre-routed (ops.py ``_route_writes``): live rows are named by exactly
    one lane; dump-routed lanes compose a no-op (src == dst, lane_of -1)."""
    take = lane_of >= 0                                # (B, page)
    rows = payload[jnp.maximum(lane_of, 0)]            # (B, page, D)
    vals = jnp.where(take[..., None], rows, pool[jnp.maximum(src, 0)])
    return pool.at[jnp.maximum(dst, 0)].set(vals)


def dbs_rw_read_ref(pool, ext, block):
    """Hole-masked block gather: pool[ext[i], block[i]], zeros on ext < 0."""
    e, page = pool.shape[:2]
    got = pool[jnp.clip(ext, 0, e - 1), jnp.clip(block, 0, page - 1)]
    return jnp.where((ext >= 0)[:, None], got, 0)
