"""The DBS kernel family's shared ops surface.

One module serves both kernels: ``default_interpret`` (the repo's
TPU-or-interpret convention), the pure shape-adapting pool wrappers the
engine step traces inline (``dbs_copy_pool``, ``dbs_rw_write_pool``,
``dbs_rw_read_pool``), and the nominal-bytes accounting the roofline gate
charges each kernel with. See docs/KERNELS.md for the grid/BlockSpec design
and the interpret-mode staleness rule the routing here exists to satisfy.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dbs.copy_kernel import dbs_copy as _dbs_copy_kernel
from repro.kernels.dbs.ref import dbs_copy_ref
from repro.kernels.dbs.rw_kernel import dbs_rw_read, dbs_rw_write


def default_interpret() -> bool:
    """Repo convention: Pallas kernels run compiled on TPU and fall back to
    ``interpret=True`` everywhere else (docs/KERNELS.md)."""
    return jax.default_backend() != "tpu"


_use_interpret = default_interpret  # back-compat alias


@partial(jax.jit, static_argnames=("interpret",))
def _dbs_copy_jit(pool, src, dst, mask, interpret):
    return _dbs_copy_kernel(pool, src, dst, mask, interpret=interpret)


def dbs_copy(pool, src, dst, mask):
    """Copy pool[src[i]] -> pool[dst[i]] where mask[i] (CoW data plane).

    pool: (E, page, D); trailing payload dims must be pre-flattened to D.
    The interpret mode is resolved per CALL and keys the jit cache as a
    static arg — a backend change after the first call re-dispatches to the
    right specialization instead of silently reusing the mode captured at
    first trace (the bug the old module-level ``@jax.jit`` had).
    """
    return _dbs_copy_jit(pool, src, dst, mask, default_interpret())


def dbs_copy_pool(pool, src, dst, mask, *, interpret=None, scratch=False):
    """Extent CoW copy over an (E, page, *payload) engine pool.

    Flattens the trailing payload dims to the kernel's (E, page, D) layout
    and restores them. Not jitted itself — it is traced inside the caller's
    program (the fused engine step), which is the whole point: the copy
    happens device-side with no intervening dispatch.

    Masked-off lanes are redirected to a scratch extent rather than clamped
    into the live range: grid steps run sequentially against the aliased
    output, but interpret mode reads each step's inputs from the *original*
    buffer, so a masked lane clamped onto a real lane's dst would overwrite
    the copy with stale contents. With ``scratch=True`` the pool's LAST row
    is that dump — the caller guarantees the allocator never hands it out
    (ReplicaGroup sizes pools to n_extents+1), keeping the kernel fully
    aliased. With ``scratch=False`` a zero row is appended and sliced off
    instead (two pool copies — fine for ad-hoc use, not the hot path).
    src/dst may be -1 on masked lanes (the WriteOps NULL convention); real
    lanes must be in range.
    """
    if interpret is None:
        interpret = default_interpret()
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    m = mask.astype(bool)
    if scratch:
        dump = e - 1                 # reserved row, never allocator-visible
        padded = flat
    else:
        dump = e
        padded = jnp.concatenate(
            [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
    src_r = jnp.where(m, jnp.maximum(src, 0), dump)  # masked: dump->dump
    dst_r = jnp.where(m, jnp.maximum(dst, 0), dump)
    out = _dbs_copy_kernel(padded, src_r, dst_r, m, interpret=interpret)
    return out[:e].reshape(pool.shape)


def _route_writes(ops, page, block_offsets, dump):
    """Route a WriteOps batch into the write kernel's one-row-per-lane form.

    ``write_pages`` groups duplicate (volume, page) lanes under one leader
    that allocated/CoW'd the shared destination extent; the kernel needs the
    inverse view — per ROW, which lane writes which block. Elect the first
    live lane of each dst group leader (for control-plane ops that is
    exactly write_pages' leader, the lane carrying ``cow_src``; hand-built
    batches must follow the same convention), build its (page,) block ->
    writing-lane map with a scatter-max (the HIGHEST lane wins a block, the
    order XLA's sequential scatter applies duplicate updates in), and park
    every other lane on the ``dump`` row with ``src == dst`` so its write is
    a bit-identical no-op. Returns (src, dst, lane_of) for ``dbs_rw_write``.
    """
    b = ops.dst.shape[0]
    arange = jnp.arange(b, dtype=jnp.int32)
    ok = ops.ok & (ops.dst >= 0)
    same = ok[None, :] & ok[:, None] & (ops.dst[None, :] == ops.dst[:, None])
    leader = jnp.argmax(same, axis=1)       # first live lane sharing my dst
    is_leader = ok & (leader == arange)
    blk = jnp.full((b + 1, page), -1, jnp.int32)
    blk = blk.at[jnp.where(ok, leader, b), block_offsets].max(arange)[:b]
    lane_of = jnp.where(is_leader[:, None], blk, -1)
    src = jnp.where(is_leader,
                    jnp.where(ops.cow_src >= 0, ops.cow_src, ops.dst), dump)
    dst = jnp.where(is_leader, ops.dst, dump)
    return src, dst, lane_of


def dbs_rw_write_pool(pool, ops, payload, block_offsets, *, interpret=None,
                      scratch=True):
    """The whole write data plane — CoW copy + payload block stores — as one
    ``dbs_rw_write`` pass over an (E, page, *payload) engine pool.

    Bit-identical to ``dbs.apply_write_ops`` (the ``kernel="xla"``
    reference) excluding the dump row. ``scratch=True`` reuses the pool's
    reserved last row as the dump (the engine convention — the kernel stays
    fully input/output-aliased); ``scratch=False`` appends and slices off a
    throwaway row for ad-hoc pools.
    """
    if interpret is None:
        interpret = default_interpret()
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    pay = payload.reshape(payload.shape[0], -1)
    if scratch:
        dump = e - 1
        padded = flat
    else:
        dump = e
        padded = jnp.concatenate(
            [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
    src, dst, lane_of = _route_writes(ops, page, block_offsets, dump)
    out = dbs_rw_write(padded, src, dst, lane_of, pay, interpret=interpret)
    return out[:e].reshape(pool.shape)


def dbs_rw_read_pool(pool, ext, block_offsets, *, interpret=None):
    """Hole-masked block gather over an (E, page, *payload) engine pool:
    returns (B, *payload); lanes with ``ext < 0`` read as zeros."""
    if interpret is None:
        interpret = default_interpret()
    e, page = pool.shape[:2]
    flat = pool.reshape(e, page, -1)
    out = dbs_rw_read(flat, ext, block_offsets, interpret=interpret)
    return out.reshape((ext.shape[0],) + pool.shape[2:])


# ---------------------------------------------------------------------------
# nominal-bytes accounting (the roofline gate's numerator)
# ---------------------------------------------------------------------------
def dbs_write_bytes(n_lanes: int, n_cow: int, page_blocks: int,
                    block_elems: int, itemsize: int) -> int:
    """Bytes a write batch SEMANTICALLY moves (implementation-independent,
    so achieved-bytes/s ratios compare across kernels): each CoW lane reads
    + writes one whole extent row, each live lane writes one block."""
    row = page_blocks * block_elems * itemsize
    return n_cow * 2 * row + n_lanes * block_elems * itemsize


def dbs_read_bytes(n_lanes: int, block_elems: int, itemsize: int) -> int:
    """Bytes a read batch semantically moves: one block read + written out
    per lane."""
    return 2 * n_lanes * block_elems * itemsize


dbs_copy_reference = dbs_copy_ref
