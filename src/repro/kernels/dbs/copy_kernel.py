"""DBS extent copy (copy-on-write data plane) as a Pallas TPU kernel.

Grid: one step per write op. src/dst extent ids and the CoW mask are
scalar-prefetch operands; BlockSpec index_maps dereference them so each step
DMAs exactly one source extent HBM->VMEM and writes it to the destination
extent. The pool is input/output-aliased — extents not named by any dst id
are untouched, like a real block device. Masked-off lanes rewrite their
destination extent with its own contents (a no-op write), keeping the
kernel branch-free on the DMA path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_ref, dst_ref, mask_ref, src_blk, dst_blk, o_ref):
    i = pl.program_id(0)
    do_copy = mask_ref[i] != 0
    o_ref[...] = jnp.where(do_copy, src_blk[...], dst_blk[...])


def dbs_copy(pool, src, dst, mask, *, interpret=True):
    """pool: (E, page, D); src/dst: (N,) int32; mask: (N,) bool/int32."""
    e, page, d = pool.shape
    n = src.shape[0]
    mask_i = mask.astype(jnp.int32)
    src_c = jnp.maximum(src, 0)
    dst_c = jnp.maximum(dst, 0)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,          # src, dst, mask
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, page, d),
                             lambda i, s, dt, m: (s[i], 0, 0)),
                pl.BlockSpec((1, page, d),
                             lambda i, s, dt, m: (dt[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page, d),
                                   lambda i, s, dt, m: (dt[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},        # pool (first tensor arg) -> out
        interpret=interpret,
    )(src_c, dst_c, mask_i, pool, pool)
