"""The unified DBS kernel package: ``dbs_copy`` + ``dbs_rw`` behind one ops
surface and a registry (docs/KERNELS.md). ``repro.kernels.dbs_copy`` is the
deprecation shim over this package."""
from repro.kernels.dbs.ops import (dbs_copy, dbs_copy_pool,  # noqa: F401
                                   dbs_copy_reference, dbs_read_bytes,
                                   dbs_rw_read_pool, dbs_rw_write_pool,
                                   dbs_write_bytes, default_interpret)
from repro.kernels.dbs.ref import (dbs_copy_ref, dbs_rw_read_ref,  # noqa: F401
                                   dbs_rw_write_ref)
from repro.kernels.dbs.registry import (DBSKernel,  # noqa: F401
                                        available_kernels, make_kernel,
                                        register_kernel, resolve_kernel_name)
from repro.kernels.dbs.rw_kernel import dbs_rw_read, dbs_rw_write  # noqa: F401
