"""Tokenized data pipeline: shard-aware sources + background prefetch.

Synthetic source = a deterministic Zipfian token stream (seeded per data
shard so shards are disjoint); memmap source reads packed token files. The
prefetcher keeps ``depth`` batches in flight on a worker thread — the
straggler-mitigation lever at the input layer (a slow storage read never
stalls the step while the queue is non-empty).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class SyntheticLM:
    """Deterministic Zipf-ish LM stream: batch["tokens"/"labels"] (B,S[,K])."""

    def __init__(self, vocab: int, batch: int, seq: int, *, codebooks: int = 1,
                 shard: int = 0, n_shards: int = 1, seed: int = 0):
        if batch % n_shards:
            raise ValueError("batch must divide by n_shards")
        self.vocab, self.batch, self.seq = vocab, batch // n_shards, seq
        self.codebooks = codebooks
        self.rng = np.random.default_rng(seed * 1009 + shard)
        # Zipf-like marginal so losses behave like text, capped to vocab
        ranks = np.arange(1, min(vocab, 50_000) + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            shape = (self.batch, self.seq + 1)
            if self.codebooks > 1:
                shape += (self.codebooks,)
            ids = self.rng.choice(len(self.p), size=shape, p=self.p
                                  ).astype(np.int32)
            yield {"tokens": ids[:, :-1], "labels": ids[:, 1:]}


class MemmapLM:
    """Packed int32 token file -> (B,S) batches, disjoint per shard."""

    def __init__(self, path: str, batch: int, seq: int, *, shard: int = 0,
                 n_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.batch = batch // n_shards
        self.seq = seq
        per = len(self.tokens) // n_shards
        self.lo, self.hi = shard * per, (shard + 1) * per
        self.cursor = self.lo

    def __iter__(self):
        span = self.batch * (self.seq + 1)
        while True:
            if self.cursor + span > self.hi:
                self.cursor = self.lo
            chunk = np.asarray(self.tokens[self.cursor:self.cursor + span])
            self.cursor += span
            ids = chunk.reshape(self.batch, self.seq + 1)
            yield {"tokens": ids[:, :-1], "labels": ids[:, 1:]}


class Prefetcher:
    """Background-thread prefetch queue (depth batches in flight)."""

    def __init__(self, source, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in source:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
