"""ServeEngine: continuous batching on top of the optimized engine layers.

One running engine = one "Longhorn node":

- admission goes through the **multi-queue frontend** (ublk analogue),
- live requests own **slots** in a fixed SlotTable (Messages Array) — the
  decode batch is always the full slot array, inactive lanes masked,
- each request's KV state is a **DBS volume** owned by a
  ``blockdev.VolumeManager`` over the ``"host"`` control-plane backend:
  cache pages are allocated through ``VolumeManager.alloc_pages`` (DBS
  ``write_pages`` underneath) as the sequence crosses page boundaries, and
  the manager's flattened extent map *is* the block table the attention
  gather reads through — the KV pools are the *external data plane* the
  returned ``WriteOps`` drive,
- **forking** a session is ``VolumeManager.clone`` — prefix pages shared,
  diverging writes copy-on-write through the ``dbs_copy`` data plane (one
  copy per layer pool),
- completion retires the slot and ``VolumeManager.delete`` frees the
  extents.

Single-host execution here (smoke/bench scale); the multi-pod data plane of
the same decode step is exercised by launch/dryrun.py via shard_map.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ExecutionPlan
from repro.core import slots
from repro.core.blockdev import VolumeManager
from repro.core.frontend import MultiQueueFrontend, Request
from repro.core.ring import OP_CLONE, ST_OK
from repro.models import blocks as B
from repro.models import model as M


@dataclass
class GenRequest:
    req_id: int
    prompt: np.ndarray            # (S,) int32 (or (S,K) for codebooks)
    max_new: int = 16
    out_tokens: List[int] = field(default_factory=list)
    slot: int = -1
    volume: int = -1
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, n_queues: int = 2,
                 plan: Optional[ExecutionPlan] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.plan = plan or ExecutionPlan(remat="none", attn_impl="chunked",
                                          compute_dtype="float32")
        self.n_slots = n_slots
        self.max_len = max_len
        page = cfg.page_blocks
        self.n_pages = math.ceil(max_len / page)

        self.frontend = MultiQueueFrontend(n_queues, n_slots, batch=n_slots)
        # DBS metadata: volumes = sessions; extents shared across layers
        # (every layer pool is indexed by the same extent ids). The volume
        # lifecycle + page allocation goes through the public API's
        # control-plane backend — the KV pools below are the external data
        # plane its WriteOps drive (core/blockdev.py, core/backends.py).
        n_extents = n_slots * self.n_pages * 2 + 8   # headroom for forks/CoW
        self.volumes = VolumeManager(
            backend="host", null_storage=True, n_extents=n_extents,
            max_volumes=2 * n_slots, max_pages=self.n_pages,
            page_blocks=page, payload_elems=1)
        self.caches = M.init_cache(cfg, n_slots, max_len, paged=True,
                                   dtype=jnp.dtype(self.plan.compute_dtype))
        # paged pools must span the DBS extent space
        self.caches = [self._grow_pool(c, n_extents) for c in self.caches]
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.slot_vol = np.full((n_slots,), -1, np.int64)
        self.live: Dict[int, GenRequest] = {}
        self._steps = 0

    @property
    def state(self):
        """The DBS metadata behind the session volumes (VolumeManager-owned;
        ``state.table`` is the paged-attention block table)."""
        return self.volumes.state

    def _grow_pool(self, cache, n_extents):
        if cache is None or "pool_k" not in cache:
            return cache
        c = dict(cache)
        for key in ("pool_k", "pool_v"):
            p = cache[key]
            c[key] = jnp.zeros((n_extents,) + p.shape[1:], p.dtype)
        return c

    # ------------------------------------------------------------------ API
    def submit(self, req: GenRequest) -> None:
        self.frontend.submit(Request(req_id=req.req_id, kind="write",
                                     volume=-1, page=0, payload=req))

    def fork(self, req_id: int, new_req_id: int, max_new: int = 16
             ) -> Optional[GenRequest]:
        """Fork a live session: clone its DBS volume (prefix sharing + CoW)."""
        src = self.live.get(req_id)
        if src is None or src.slot < 0:
            return None
        child_vol = self.volumes.clone(src.volume)
        if child_vol is None:
            return None
        vid = child_vol.vid
        child = GenRequest(req_id=new_req_id,
                           prompt=np.zeros((0,), np.int64), max_new=max_new)
        child.out_tokens = list(src.out_tokens)
        # claim a slot directly (fork bypasses the admission queue); the
        # Messages Array records the op that owns the slot (ring opcode lane)
        self.frontend.table, ids, ok = slots.admit(
            self.frontend.table, jnp.array([True]),
            jnp.array([vid], jnp.int32), jnp.array([0], jnp.int32),
            jnp.int32(self._steps),
            opcodes=jnp.array([OP_CLONE], jnp.int32))
        if not bool(ok[0]):
            self.volumes.delete(vid)
            return None
        child.slot = int(ids[0])
        child.volume = vid
        self.slot_vol[child.slot] = vid
        self.pos = self.pos.at[child.slot].set(self.pos[src.slot])
        self.live[new_req_id] = child
        return child

    # ------------------------------------------------------- engine stepping
    def _admit(self) -> List[GenRequest]:
        slot_ids, reqs = self.frontend.poll_batch()
        admitted = []
        for sid, r in zip(jax.device_get(slot_ids), reqs):
            g: GenRequest = r.payload
            g.slot = int(sid)
            g.volume = self.volumes.create().vid
            self.slot_vol[g.slot] = g.volume
            self.live[g.req_id] = g
            admitted.append(g)
        return admitted

    def _alloc_pages(self, vols, pages, mask):
        """Control plane: allocate/CoW the page each lane writes this step —
        through the VolumeManager; the returned WriteOps drive this engine's
        external data plane (the per-layer KV pools)."""
        ops = self.volumes.alloc_pages(vols, pages, mask=mask)
        if bool(jax.device_get(jnp.any(ops.cow_src >= 0))):
            from repro.kernels.dbs import dbs_copy
            for i, c in enumerate(self.caches):
                if c is not None and "pool_k" in c:
                    c = dict(c)
                    for key in ("pool_k", "pool_v"):
                        p = c[key]
                        flat = p.reshape(p.shape[0], p.shape[1], -1)
                        flat = dbs_copy(flat, ops.cow_src, ops.dst,
                                        ops.cow_src >= 0)
                        c[key] = flat.reshape(p.shape)
                    self.caches[i] = c
        return ops

    def _prefill_one(self, g: GenRequest) -> None:
        prompt = np.asarray(g.prompt)
        s = prompt.shape[0]
        if s == 0:
            return
        page = self.cfg.page_blocks
        pad = (-s) % page
        padded = np.pad(prompt, [(0, pad)] + [(0, 0)] * (prompt.ndim - 1))
        n_pages = padded.shape[0] // page
        # allocate all prompt pages up front
        vols = jnp.full((n_pages,), g.volume, jnp.int32)
        self._alloc_pages(vols, jnp.arange(n_pages, dtype=jnp.int32),
                          jnp.ones((n_pages,), bool))
        # single-sequence prefill writing into this engine's pools
        bt_row = self.state.table[g.volume][None, :]
        caches_one = []
        for c in self.caches:
            if c is None:
                caches_one.append(None)
                continue
            c1 = {}
            for k, v in c.items():
                if k.startswith("pool"):
                    c1[k] = v
                elif k == "block_table":
                    c1[k] = bt_row
                else:
                    c1[k] = v[g.slot:g.slot + 1]
            caches_one.append(c1)
        tok = jnp.asarray(padded)[None]
        logits, caches_one = M.prefill(self.params, tok, self.cfg, self.plan,
                                       caches_one)
        # scatter the per-sequence cache rows back; pools are shared already
        new_caches = []
        for c, c1 in zip(self.caches, caches_one):
            if c is None:
                new_caches.append(None)
                continue
            cn = dict(c)
            for k, v in c1.items():
                if k.startswith("pool"):
                    cn[k] = v
                elif k != "block_table":
                    cn[k] = cn[k].at[g.slot].set(v[0])
            new_caches.append(cn)
        self.caches = new_caches
        self.pos = self.pos.at[g.slot].set(s)
        if s < padded.shape[0]:
            pass  # padded tail positions are masked by pos-based causality

    def step(self) -> List[Tuple[int, int]]:
        """One continuous-batching iteration. Returns [(req_id, token)]."""
        for g in self._admit():
            self._prefill_one(g)
        active = np.array([self.slot_vol[i] >= 0 and any(
            r.slot == i and not r.done for r in self.live.values())
            for i in range(self.n_slots)])
        if not active.any():
            return []
        # control plane: the page each active lane writes this step
        vols = jnp.asarray(np.where(active, self.slot_vol, 0), jnp.int32)
        pages = self.pos // self.cfg.page_blocks
        self._alloc_pages(vols, pages, jnp.asarray(active))
        # refresh block tables from the DBS extent maps
        bt = self.state.table[vols]
        self.caches = M.with_block_tables(self.caches, bt)
        # data plane
        last = jnp.asarray(
            [(self.live_by_slot(i).out_tokens[-1]
              if self.live_by_slot(i) and self.live_by_slot(i).out_tokens
              else self._last_prompt_token(i)) for i in range(self.n_slots)],
            jnp.int32)
        if self.cfg.n_codebooks > 1:
            last = jnp.broadcast_to(last[:, None], (self.n_slots,
                                                    self.cfg.n_codebooks))
        logits, self.caches = M.decode_step(
            self.params, last, self.pos, self.cfg, self.plan, self.caches)
        nxt = jnp.argmax(logits, axis=-1)
        if self.cfg.n_codebooks > 1:
            nxt = nxt[:, 0]
        nxt_host = np.asarray(jax.device_get(nxt))
        self.pos = self.pos + jnp.asarray(active, jnp.int32)
        out = []
        self._steps += 1
        for i in range(self.n_slots):
            if not active[i]:
                continue
            g = self.live_by_slot(i)
            g.out_tokens.append(int(nxt_host[i]))
            out.append((g.req_id, int(nxt_host[i])))
            if len(g.out_tokens) >= g.max_new or \
                    int(jax.device_get(self.pos[i])) >= self.max_len:
                self._finish(g)
        return out

    def live_by_slot(self, slot: int) -> Optional[GenRequest]:
        for g in self.live.values():
            if g.slot == slot and not g.done:
                return g
        return None

    def _last_prompt_token(self, slot: int) -> int:
        g = self.live_by_slot(slot)
        if g is None or g.prompt.shape[0] == 0:
            return 0
        t = g.prompt[-1]
        return int(t if np.ndim(t) == 0 else t.flat[0])

    def _finish(self, g: GenRequest) -> None:
        g.done = True
        self.frontend.table = slots.retire(
            self.frontend.table, jnp.asarray([g.slot], jnp.int32),
            statuses=jnp.int32(ST_OK))
        self.volumes.delete(g.volume)
        self.slot_vol[g.slot] = -1
        g.slot = -1

    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self.step()
            if all(g.done for g in self.live.values()) and \
                    self.frontend.depth() == 0:
                break
        return {rid: g.out_tokens for rid, g in self.live.items()}


class ServePool:
    """The serve path over a pool of engine shards (core/sharded.py's scale
    axis applied to serving): S independent ServeEngine "nodes", requests
    hash-sharded by ``req_id % S``, stepped together.

    Each shard keeps its own slot table, DBS metadata and KV pools — the
    same isolation the block-engine ``EnginePool`` gives its shards — so a
    heavy tenant saturates one shard's slots without starving the others.
    Forking stays shard-local (``dbs.clone`` shares extents only within one
    DBS state), so a forked child lives on its parent's shard regardless of
    its req_id; ``_home`` tracks that routing.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_shards: int = 2, **kw):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.shards = [ServeEngine(cfg, params, **kw)
                       for _ in range(n_shards)]
        self._home: Dict[int, int] = {}

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, req_id: int) -> int:
        return self._home.get(req_id, req_id % self.n_shards)

    def submit(self, req: GenRequest) -> None:
        # hash routing only — recording it in _home would let a later
        # submit clobber a live forked child's off-hash home
        self.shards[req.req_id % self.n_shards].submit(req)

    def fork(self, req_id: int, new_req_id: int, max_new: int = 16
             ) -> Optional[GenRequest]:
        shard = self.shard_of(req_id)
        child = self.shards[shard].fork(req_id, new_req_id, max_new)
        if child is not None and shard != new_req_id % self.n_shards:
            self._home[new_req_id] = shard       # off-hash: remember it
        return child

    def step(self) -> List[Tuple[int, int]]:
        """One pool iteration: every shard's continuous-batching step."""
        out: List[Tuple[int, int]] = []
        for sh in self.shards:
            out.extend(sh.step())
        for rid in [r for r, s in self._home.items()
                    if self.shards[s].live.get(r) is not None
                    and self.shards[s].live[r].done]:
            del self._home[rid]                  # finished forks: unpin
        return out

    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self.step()
            if all(all(g.done for g in sh.live.values())
                   and sh.frontend.depth() == 0 for sh in self.shards):
                break
        out: Dict[int, List[int]] = {}
        for sh in self.shards:
            out.update({rid: g.out_tokens for rid, g in sh.live.items()})
        return out
