"""ServeEngine: continuous batching on top of the optimized engine layers.

One running engine = one "Longhorn node":

- admission goes through the **multi-queue frontend** (ublk analogue),
- live requests own **slots** in a fixed SlotTable (Messages Array) — the
  decode batch is always the full slot array, inactive lanes masked,
- each request's KV state is a **DBS volume** owned by a
  ``blockdev.VolumeManager``. On the default **zero-copy** backends
  (``kv_backend="fused"`` / ``"sharded"``) the engine's payload pool *is*
  the KV cache: one block holds one token's K/V for every layer
  (``payload_shape=(n_planes, KV, hd)``, plane ``2l`` = layer l keys,
  ``2l+1`` = values), page allocation and CoW ride ordinary write SQEs
  batched into ONE pump per step, and the paged-attention kernel gathers
  K/V straight out of the extent pool through the volume's extent map
  (``kernels/paged_attention``) — no staging copy of the KV cache ever
  exists,
- **forking** a session is ``VolumeManager.clone`` — prefix extents
  shared, diverging writes CoW'd in-kernel by the DBS write step — O(1)
  in context length,
- completion retires the slot and ``VolumeManager.delete`` frees the
  extents.

``kv_backend="host"`` keeps the pre-zero-copy data path (model-owned KV
pools driven by host ``alloc_pages`` + per-layer ``dbs_copy`` CoW) as the
measured copy-based baseline — ``benchmarks/ladder.py run_serve`` gates
zero-copy throughput against it.

Single-host execution here (smoke/bench scale); the multi-pod data plane of
the same decode step is exercised by launch/dryrun.py via shard_map.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ArchConfig, ATTN_MLA, ATTN_RWKV,
                                ExecutionPlan)
from repro.core import slots
from repro.core.blockdev import VolumeManager
from repro.core.frontend import MultiQueueFrontend, Request
from repro.core.ring import OP_CLONE, ST_OK
from repro.kernels.paged_attention.kernel import paged_attention_pool_fwd
from repro.kernels.paged_attention.ref import paged_attention_pool_ref
from repro.models import blocks as B
from repro.models import model as M


@dataclass
class GenRequest:
    req_id: int
    prompt: np.ndarray            # (S,) int32 (or (S,K) for codebooks)
    max_new: int = 16
    out_tokens: List[int] = field(default_factory=list)
    slot: int = -1
    volume: int = -1
    done: bool = False
    # per-decode-step logits, recorded only when the engine was built with
    # record_logits=True (the fork bit-identity tests)
    logit_trace: List[np.ndarray] = field(default_factory=list)


def _paged_layer_info(cfg: ArchConfig, sig) -> Optional[Tuple[int, int, int]]:
    """(kd, vd, n_kv) for layers whose decode cache is paged (pool-backed),
    mirroring ``blocks.init_layer_cache``; None for ring/recurrent layers."""
    if sig.attn == ATTN_RWKV or sig.window:
        return None
    if sig.attn == ATTN_MLA:
        m = cfg.mla
        return m.kv_lora_rank + m.rope_head_dim, m.kv_lora_rank, 1
    hd = cfg.resolved_head_dim
    return hd, hd, cfg.n_kv_heads


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, n_queues: int = 2,
                 plan: Optional[ExecutionPlan] = None, seed: int = 0,
                 kv_backend: str = "fused", kv_shards: int = 1,
                 kv_replicas: int = 2, kernel: str = "auto",
                 record_logits: bool = False):
        self.cfg = cfg
        self.params = params
        self.plan = plan or ExecutionPlan(remat="none", attn_impl="chunked",
                                          compute_dtype="float32")
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv_backend = kv_backend
        self.record_logits = record_logits
        page = cfg.page_blocks
        self.n_pages = math.ceil(max_len / page)
        dtype = jnp.dtype(self.plan.compute_dtype)

        self.frontend = MultiQueueFrontend(n_queues, n_slots, batch=n_slots)
        # DBS metadata: volumes = sessions; extents shared across layers
        # (one extent row holds every layer's K/V for its page of tokens).
        n_extents = n_slots * self.n_pages * 2 + 8   # headroom for forks/CoW
        self._zero_copy = kv_backend != "host"
        if self._zero_copy:
            infos = [_paged_layer_info(cfg, s) for s in B.layer_sigs(cfg)]
            self._paged = [(li,) + info for li, info in enumerate(infos)
                           if info is not None]
            if not self._paged:
                raise ValueError("zero-copy serving needs at least one "
                                 "paged-attention layer; use "
                                 "kv_backend='host' for pure-recurrent nets")
            kvs = {info[3] for info in self._paged}
            if len(kvs) > 1:
                raise ValueError(f"mixed KV head counts {sorted(kvs)} not "
                                 "supported by the pooled KV layout")
            self._n_kv = kvs.pop()
            self._dmax = max(max(kd, vd) for _, kd, vd, _ in self._paged)
            n_planes = 2 * len(self._paged)
            self._payload_shape = (n_planes, self._n_kv, self._dmax)
            # the engine extent pool IS the KV cache: the volume manager's
            # write SQEs allocate/CoW its rows, the paged-attention kernel
            # reads them through the extent map
            self.volumes = VolumeManager(
                backend=kv_backend, n_shards=kv_shards,
                n_replicas=kv_replicas, kernel=kernel,
                n_extents=n_extents, max_volumes=2 * n_slots,
                max_pages=self.n_pages, page_blocks=page,
                batch=max(2 * n_slots, 16),
                payload_shape=self._payload_shape)
            self.caches = M.init_cache(cfg, n_slots, max_len, paged=True,
                                       dtype=dtype)
            # the model-owned pools are vestigial in zero-copy mode (the
            # paged_decode_fn reads the engine pool instead); shrink them to
            # one dummy extent so they cost nothing to thread through jit
            self.caches = [self._shrink_pool(c) for c in self.caches]
            # device-resident views of the engine's KV store; refreshed
            # after every pump that may move extents (_pump_writes)
            self._pools = self.volumes.device_pools()
            self._table = self.volumes.device_extent_map()
            self._attn_pallas = (kernel == "pallas" or (
                kernel == "auto" and jax.default_backend() == "tpu"))
            self._cow_pending: set = set()
            self._step_fn = jax.jit(self._decode_program)
        else:
            # copy-based baseline: host control plane + model-owned pools
            self.volumes = VolumeManager(
                backend="host", null_storage=True, n_extents=n_extents,
                max_volumes=2 * n_slots, max_pages=self.n_pages,
                page_blocks=page, payload_elems=1)
            self.caches = M.init_cache(cfg, n_slots, max_len, paged=True,
                                       dtype=dtype)
            # paged pools must span the DBS extent space
            self.caches = [self._grow_pool(c, n_extents) for c in self.caches]
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_vol = np.full((n_slots,), -1, np.int64)
        self.live: Dict[int, GenRequest] = {}
        self._steps = 0

    @property
    def state(self):
        """The DBS metadata behind the session volumes (``state.table`` is
        the paged-attention block table). host: the oracle state; fused:
        replica 0's; sharded: replica 0's stacked (S, ...) state."""
        if not self._zero_copy:
            return self.volumes.state
        storage = self.volumes.engine.backend
        if hasattr(storage, "states"):               # sharded (stacked)
            return storage.states[0]
        return storage.device_state()[0][0]          # fused replica 0

    def _grow_pool(self, cache, n_extents):
        if cache is None or "pool_k" not in cache:
            return cache
        c = dict(cache)
        for key in ("pool_k", "pool_v"):
            p = cache[key]
            c[key] = jnp.zeros((n_extents,) + p.shape[1:], p.dtype)
        return c

    def _shrink_pool(self, cache):
        if cache is None or "pool_k" not in cache:
            return cache
        c = dict(cache)
        for key in ("pool_k", "pool_v"):
            p = cache[key]
            c[key] = jnp.zeros((1,) + p.shape[1:], p.dtype)
        return c

    # ------------------------------------------------------------------ API
    def submit(self, req: GenRequest) -> None:
        self.frontend.submit(Request(req_id=req.req_id, kind="write",
                                     volume=-1, page=0, payload=req))

    def fork(self, req_id: int, new_req_id: int, max_new: int = 16
             ) -> Optional[GenRequest]:
        """Fork a live session: clone its DBS volume. O(1) in context
        length — prefix extents are shared, not copied; the parent's and
        child's next writes to the shared frontier page CoW in-kernel."""
        src = self.live.get(req_id)
        if src is None or src.slot < 0:
            return None
        child_vol = self.volumes.clone(src.volume)
        if child_vol is None:
            return None
        vid = child_vol.vid
        child = GenRequest(req_id=new_req_id,
                           prompt=np.zeros((0,), np.int64), max_new=max_new)
        child.out_tokens = list(src.out_tokens)
        # claim a slot directly (fork bypasses the admission queue); the
        # Messages Array records the op that owns the slot (ring opcode lane)
        self.frontend.table, ids, ok = slots.admit(
            self.frontend.table, jnp.array([True]),
            jnp.array([vid], jnp.int32), jnp.array([0], jnp.int32),
            jnp.int32(self._steps),
            opcodes=jnp.array([OP_CLONE], jnp.int32))
        if not bool(ok[0]):
            self.volumes.delete(vid)
            return None
        child.slot = int(ids[0])
        child.volume = vid
        self.slot_vol[child.slot] = vid
        self.pos[child.slot] = self.pos[src.slot]
        self.live[new_req_id] = child
        if self._zero_copy:
            # both sides' next write to the shared frontier page must ride a
            # write SQE so the in-kernel CoW un-shares it before the decode
            # scatter touches it
            self._cow_pending.add(req_id)
            self._cow_pending.add(new_req_id)
            self._table = self.volumes.device_extent_map()
        return child

    def control(self, kind: str, **kw):
        """Replica-plane control (fail/rebuild/...) on the KV store. The
        engine's pool copy is synced to the live KV first — a rebuild donor
        must see every decode scatter, not just the last pumped state."""
        if self._zero_copy:
            self.volumes.set_device_pools(self._pools)
        out = self.volumes.engine.control(kind, **kw)
        if self._zero_copy:
            self._pools = self.volumes.device_pools()
            self._table = self.volumes.device_extent_map()
        return out

    # ------------------------------------------------------- engine stepping
    def _admit(self) -> List[GenRequest]:
        slot_ids, reqs = self.frontend.poll_batch()
        admitted = []
        for sid, r in zip(jax.device_get(slot_ids), reqs):
            g: GenRequest = r.payload
            g.slot = int(sid)
            g.volume = self.volumes.create().vid
            self.slot_vol[g.slot] = g.volume
            self.live[g.req_id] = g
            admitted.append(g)
        return admitted

    # ---------------------------------------------- zero-copy KV data plane
    def _pump_writes(self) -> None:
        """Complete every queued write SQE in ONE batched pump: page
        allocation and CoW for all lanes resolve inside the engine's fused
        step. The engine's pool copy is synced with ours around the pump
        (the decode program's scatters live in ``self._pools`` between
        pumps), and the extent-map view is refreshed after."""
        self.volumes.set_device_pools(self._pools)
        self.volumes.flush()
        self._pools = self.volumes.device_pools()
        self._table = self.volumes.device_extent_map()

    def _submit_kv_write(self, vid: int, pos: int, payload=None) -> None:
        page = self.cfg.page_blocks
        if payload is None:
            payload = np.zeros(self._payload_shape, np.float32)
        self.volumes.submit(Request(
            req_id=self.volumes._rid(vid), kind="write", volume=vid,
            page=pos // page, block=pos % page, payload=payload))

    def _decode_program(self, params, last, pos, active, bt, pools, caches):
        """One fully-fused decode step over the engine's KV pools: per paged
        layer, scatter the new token's K/V into every replica pool at its
        extent row and attend straight off the pool through the extent map.
        Returns (logits, next tokens, caches, mutated pools)."""
        caches = M.with_block_tables(caches, bt)
        page = self.cfg.page_blocks
        cell = {"pools": tuple(pools), "j": 0}
        lanes = jnp.arange(bt.shape[0])
        dmax = self._dmax

        def paged_fn(q, k_new, v_new, pk, pv, bt_, q_pos, *, window=0,
                     logit_cap=0.0, scale=None):
            j = cell["j"]
            cell["j"] += 1
            _, kd, vd, _ = self._paged[j]
            kp, vp = 2 * j, 2 * j + 1
            p = q_pos[:, 0]
            ext = bt_[lanes, p // page]
            off = p % page
            # inactive lanes and holes scatter nowhere (mode="drop" at -1 —
            # the DBS hole sentinel)
            extw = jnp.where(active & (ext >= 0), ext, -1)
            kn, vn = k_new[:, 0], v_new[:, 0]
            if kn.shape[-1] < dmax:
                kn = jnp.pad(kn, ((0, 0), (0, 0), (0, dmax - kn.shape[-1])))
            if vn.shape[-1] < dmax:
                vn = jnp.pad(vn, ((0, 0), (0, 0), (0, dmax - vn.shape[-1])))
            new_pools = []
            for pool in cell["pools"]:
                pool = pool.at[extw, off, kp].set(kn.astype(pool.dtype),
                                                  mode="drop")
                pool = pool.at[extw, off, vp].set(vn.astype(pool.dtype),
                                                  mode="drop")
                new_pools.append(pool)
            cell["pools"] = tuple(new_pools)
            qk = q[:, 0]                         # (B, H, hd): one token
            if qk.shape[-1] < dmax:
                qk = jnp.pad(qk, ((0, 0), (0, 0), (0, dmax - qk.shape[-1])))
            # the pool's trailing dim is padded to dmax — the kernel's
            # default 1/sqrt(d) would use the padded dim, so pass the true
            # head-dim scale explicitly
            eff_scale = (float(scale) if scale is not None
                         else 1.0 / math.sqrt(kd))
            lengths = p + 1
            if self._attn_pallas:
                out = paged_attention_pool_fwd(
                    qk, cell["pools"][0], bt_, lengths, k_plane=kp,
                    v_plane=vp, window=window, logit_cap=logit_cap,
                    scale=eff_scale,
                    interpret=jax.default_backend() != "tpu")
            else:
                out = paged_attention_pool_ref(
                    qk, cell["pools"][0], bt_, lengths, k_plane=kp,
                    v_plane=vp, window=window, logit_cap=logit_cap,
                    scale=eff_scale)
            out = out[..., :vd].astype(q.dtype)[:, None]
            return out, pk, pv

        logits, caches = M.decode_step(params, last, pos, self.cfg,
                                       self.plan, caches,
                                       paged_decode_fn=paged_fn)
        nxt = jnp.argmax(logits, axis=-1)
        return logits, nxt, caches, cell["pools"]

    def _prefill_one_zero(self, g: GenRequest) -> None:
        """Prefill a prompt, then push its K/V into the engine pools as
        ordinary write SQEs (one per prompt token/block) — allocation and
        payload ride the same batched pump as every other write; the caller
        flushes once for all admitted prompts."""
        prompt = np.asarray(g.prompt)
        s = prompt.shape[0]
        if s == 0:
            return
        dtype = jnp.dtype(self.plan.compute_dtype)
        # single-sequence prefill with dense K/V caches for the paged
        # layers (their pool content goes to the ENGINE pool, not the
        # model's); recurrent/ring layer caches are the batch rows
        caches_one = []
        for c in self.caches:
            if c is None:
                caches_one.append(None)
                continue
            if "pool_k" in c:
                kd = c["pool_k"].shape[-1]
                vd = c["pool_v"].shape[-1]
                n_kv = c["pool_k"].shape[2]
                caches_one.append({
                    "k": jnp.zeros((1, s, n_kv, kd), dtype),
                    "v": jnp.zeros((1, s, n_kv, vd), dtype)})
            else:
                caches_one.append({k: v[g.slot:g.slot + 1]
                                   for k, v in c.items()})
        tok = jnp.asarray(prompt)[None]
        _logits, caches_one = M.prefill(self.params, tok, self.cfg,
                                        self.plan, caches_one)
        # one payload block per prompt token: every layer's K/V planes
        pay = np.zeros((s,) + self._payload_shape, np.float32)
        kv_host = jax.device_get([(caches_one[li]["k"], caches_one[li]["v"])
                                  for li, *_ in self._paged])
        for j, (_li, kd, vd, _) in enumerate(self._paged):
            k, v = kv_host[j]
            pay[:, 2 * j, :, :kd] = np.asarray(k[0], np.float32)
            pay[:, 2 * j + 1, :, :vd] = np.asarray(v[0], np.float32)
        for t in range(s):
            self._submit_kv_write(g.volume, t, payload=pay[t])
        # recurrent/ring rows back into the batch caches
        for li, (c, c1) in enumerate(zip(self.caches, caches_one)):
            if c is None or "pool_k" in c:
                continue
            cn = dict(c)
            for k, v in c1.items():
                cn[k] = cn[k].at[g.slot].set(v[0])
            self.caches[li] = cn
        self.pos[g.slot] = s

    # --------------------------------------------- copy-based KV data plane
    def _alloc_pages(self, vols, pages, mask):
        """Copy-based control plane: allocate/CoW through the host backend;
        the returned WriteOps drive the model-owned KV pools (one dbs_copy
        per layer pool on CoW — the copies the zero-copy path retires)."""
        ops = self.volumes.alloc_pages(vols, pages, mask=mask)
        if bool(jax.device_get(jnp.any(ops.cow_src >= 0))):
            from repro.kernels.dbs import dbs_copy
            for i, c in enumerate(self.caches):
                if c is not None and "pool_k" in c:
                    c = dict(c)
                    for key in ("pool_k", "pool_v"):
                        p = c[key]
                        flat = p.reshape(p.shape[0], p.shape[1], -1)
                        flat = dbs_copy(flat, ops.cow_src, ops.dst,
                                        ops.cow_src >= 0)
                        c[key] = flat.reshape(p.shape)
                    self.caches[i] = c
        return ops

    def _prefill_one_host(self, g: GenRequest) -> None:
        prompt = np.asarray(g.prompt)
        s = prompt.shape[0]
        if s == 0:
            return
        page = self.cfg.page_blocks
        pad = (-s) % page
        padded = np.pad(prompt, [(0, pad)] + [(0, 0)] * (prompt.ndim - 1))
        n_pages = padded.shape[0] // page
        # allocate all prompt pages up front
        vols = jnp.full((n_pages,), g.volume, jnp.int32)
        self._alloc_pages(vols, jnp.arange(n_pages, dtype=jnp.int32),
                          jnp.ones((n_pages,), bool))
        # single-sequence prefill writing into this engine's pools
        bt_row = self.state.table[g.volume][None, :]
        caches_one = []
        for c in self.caches:
            if c is None:
                caches_one.append(None)
                continue
            c1 = {}
            for k, v in c.items():
                if k.startswith("pool"):
                    c1[k] = v
                elif k == "block_table":
                    c1[k] = bt_row
                else:
                    c1[k] = v[g.slot:g.slot + 1]
            caches_one.append(c1)
        tok = jnp.asarray(padded)[None]
        logits, caches_one = M.prefill(self.params, tok, self.cfg, self.plan,
                                       caches_one)
        # scatter the per-sequence cache rows back; pools are shared already
        new_caches = []
        for c, c1 in zip(self.caches, caches_one):
            if c is None:
                new_caches.append(None)
                continue
            cn = dict(c)
            for k, v in c1.items():
                if k.startswith("pool"):
                    cn[k] = v
                elif k != "block_table":
                    cn[k] = cn[k].at[g.slot].set(v[0])
            new_caches.append(cn)
        self.caches = new_caches
        self.pos[g.slot] = s

    def _prefill_one(self, g: GenRequest) -> None:
        if self._zero_copy:
            self._prefill_one_zero(g)
        else:
            self._prefill_one_host(g)

    # ----------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, int]]:
        """One continuous-batching iteration. Returns [(req_id, token)]."""
        admitted = self._admit()
        pending = False
        for g in admitted:
            self._prefill_one(g)
            pending = pending or (self._zero_copy
                                  and np.asarray(g.prompt).shape[0] > 0)
        active = np.array([self.slot_vol[i] >= 0 and any(
            r.slot == i and not r.done for r in self.live.values())
            for i in range(self.n_slots)])
        if not active.any():
            if pending:
                self._pump_writes()
            return []
        page = self.cfg.page_blocks
        if self._zero_copy:
            # control plane: lanes crossing a page boundary allocate their
            # new page, freshly-forked lanes CoW their shared frontier page
            # — all as write SQEs completed by ONE batched pump
            for i in range(self.n_slots):
                if not active[i]:
                    continue
                g = self.live_by_slot(i)
                if (self.pos[i] % page == 0
                        or g.req_id in self._cow_pending):
                    self._submit_kv_write(int(self.slot_vol[i]),
                                          int(self.pos[i]))
                    self._cow_pending.discard(g.req_id)
                    pending = True
            if pending:
                self._pump_writes()
        vols = jnp.asarray(np.where(active, self.slot_vol, 0), jnp.int32)
        last = jnp.asarray(
            [(self.live_by_slot(i).out_tokens[-1]
              if self.live_by_slot(i) and self.live_by_slot(i).out_tokens
              else self._last_prompt_token(i)) for i in range(self.n_slots)],
            jnp.int32)
        if self.cfg.n_codebooks > 1:
            last = jnp.broadcast_to(last[:, None], (self.n_slots,
                                                    self.cfg.n_codebooks))
        pos_dev = jnp.asarray(self.pos)
        if self._zero_copy:
            # data plane: one fused program — KV scatter into the engine
            # pools + paged attention through the extent map
            bt = self._table[vols]
            logits, nxt, self.caches, self._pools = self._step_fn(
                self.params, last, pos_dev, jnp.asarray(active), bt,
                self._pools, self.caches)
        else:
            pages = jnp.asarray(self.pos // page, jnp.int32)
            self._alloc_pages(vols, pages, jnp.asarray(active))
            # refresh block tables from the DBS extent maps
            bt = self.state.table[vols]
            self.caches = M.with_block_tables(self.caches, bt)
            logits, self.caches = M.decode_step(
                self.params, last, pos_dev, self.cfg, self.plan, self.caches)
            nxt = jnp.argmax(logits, axis=-1)
        if self.cfg.n_codebooks > 1:
            nxt = nxt[:, 0]
        if self.record_logits:
            nxt_host, logits_host = jax.device_get((nxt, logits))
            logits_host = np.asarray(logits_host)
        else:
            nxt_host = np.asarray(jax.device_get(nxt))
            logits_host = None
        self.pos = self.pos + active.astype(np.int32)
        out = []
        self._steps += 1
        for i in range(self.n_slots):
            if not active[i]:
                continue
            g = self.live_by_slot(i)
            g.out_tokens.append(int(nxt_host[i]))
            if logits_host is not None:
                g.logit_trace.append(logits_host[i].copy())
            out.append((g.req_id, int(nxt_host[i])))
            if len(g.out_tokens) >= g.max_new or \
                    int(self.pos[i]) >= self.max_len:
                self._finish(g)
        return out

    def live_by_slot(self, slot: int) -> Optional[GenRequest]:
        for g in self.live.values():
            if g.slot == slot and not g.done:
                return g
        return None

    def _last_prompt_token(self, slot: int) -> int:
        g = self.live_by_slot(slot)
        if g is None or g.prompt.shape[0] == 0:
            return 0
        t = g.prompt[-1]
        return int(t if np.ndim(t) == 0 else t.flat[0])

    def _finish(self, g: GenRequest) -> None:
        g.done = True
        self.frontend.table = slots.retire(
            self.frontend.table, jnp.asarray([g.slot], jnp.int32),
            statuses=jnp.int32(ST_OK))
        self.volumes.delete(g.volume)
        if self._zero_copy:
            self._cow_pending.discard(g.req_id)
        self.slot_vol[g.slot] = -1
        g.slot = -1

    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self.step()
            if all(g.done for g in self.live.values()) and \
                    self.frontend.depth() == 0:
                break
        return {rid: g.out_tokens for rid, g in self.live.items()}


class ServePool:
    """The serve path over a pool of engine shards (core/sharded.py's scale
    axis applied to serving): S independent ServeEngine "nodes", requests
    hash-sharded by ``req_id % S``, stepped together.

    Each shard keeps its own slot table, DBS metadata and KV pools — the
    same isolation the block-engine ``EnginePool`` gives its shards — so a
    heavy tenant saturates one shard's slots without starving the others.
    Forking stays shard-local (``dbs.clone`` shares extents only within one
    DBS state), so a forked child lives on its parent's shard regardless of
    its req_id; ``_home`` tracks that routing.

    ``**kw`` forwards to ``ServeEngine`` — in particular ``kv_backend=``,
    ``kv_shards=``, ``kv_replicas=`` and ``kernel=``, so a pool of serve
    nodes can each run its KV store on the sharded replicated engine.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_shards: int = 2, **kw):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.shards = [ServeEngine(cfg, params, **kw)
                       for _ in range(n_shards)]
        self._home: Dict[int, int] = {}

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, req_id: int) -> int:
        return self._home.get(req_id, req_id % self.n_shards)

    def submit(self, req: GenRequest) -> None:
        # hash routing only — recording it in _home would let a later
        # submit clobber a live forked child's off-hash home
        self.shards[req.req_id % self.n_shards].submit(req)

    def fork(self, req_id: int, new_req_id: int, max_new: int = 16
             ) -> Optional[GenRequest]:
        shard = self.shard_of(req_id)
        child = self.shards[shard].fork(req_id, new_req_id, max_new)
        if child is not None and shard != new_req_id % self.n_shards:
            self._home[new_req_id] = shard       # off-hash: remember it
        return child

    def step(self) -> List[Tuple[int, int]]:
        """One pool iteration: every shard's continuous-batching step."""
        out: List[Tuple[int, int]] = []
        for sh in self.shards:
            out.extend(sh.step())
        for rid in [r for r, s in self._home.items()
                    if self.shards[s].live.get(r) is not None
                    and self.shards[s].live[r].done]:
            del self._home[rid]                  # finished forks: unpin
        return out

    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self.step()
            if all(all(g.done for g in sh.live.values())
                   and sh.frontend.depth() == 0 for sh in self.shards):
                break
        out: Dict[int, List[int]] = {}
        for sh in self.shards:
            out.update({rid: g.out_tokens for rid, g in sh.live.items()})
        return out
