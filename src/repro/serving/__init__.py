from repro.serving.engine import (GenRequest, ServeEngine,  # noqa: F401
                                  ServePool)
