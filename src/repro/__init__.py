"""repro: Longhorn-engine-inspired distributed block storage for LLM state,
reimagined for TPU pods in JAX — paged DBS KV pools, slot-array scheduling,
multi-queue admission and replicated checkpoint volumes (see DESIGN.md)."""

__version__ = "1.0.0"
