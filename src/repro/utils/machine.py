"""Machine roofline profile: detected-or-overridable peak numbers.

The dry-run/roofline analysis used to hardcode one TPU generation's peaks,
so bytes/s-vs-peak fractions were silently wrong on any other box. One
``machine_profile()`` now feeds every consumer (``launch/dryrun.py``,
``benchmarks/roofline.py``, the ladder's kernel gate), resolved in priority
order: explicit values (CLI flags) > ``REPRO_PEAK_FLOPS`` /
``REPRO_HBM_BW`` / ``REPRO_LINK_BW`` env vars > the jax device kind >
the v5e assignment-brief defaults (flagged ``assumed=True`` so reports can
say so).
"""
from __future__ import annotations

import os
from dataclasses import asdict, dataclass
from typing import Optional


@dataclass(frozen=True)
class MachineProfile:
    name: str
    peak_flops: float       # peak matmul flops/s per chip (bf16)
    hbm_bw: float           # HBM bytes/s per chip
    link_bw: float          # ICI bytes/s per link
    assumed: bool = False   # True when nothing was detected or overridden

    def to_dict(self) -> dict:
        return asdict(self)


# the assignment brief's v5e numbers — the old hardcoded constants
V5E = MachineProfile("tpu-v5e", 197e12, 819e9, 50e9)

# device_kind (prefix-matched, case-insensitive) -> published peaks
_KNOWN = {
    "tpu v5 lite": V5E,
    "tpu v5e": V5E,
    "tpu v5p": MachineProfile("tpu-v5p", 459e12, 2765e9, 100e9),
    "tpu v5": MachineProfile("tpu-v5p", 459e12, 2765e9, 100e9),
    "tpu v4": MachineProfile("tpu-v4", 275e12, 1228e9, 50e9),
    "tpu v6 lite": MachineProfile("tpu-v6e", 918e12, 1640e9, 100e9),
    "tpu v6e": MachineProfile("tpu-v6e", 918e12, 1640e9, 100e9),
}


def _detect() -> Optional[MachineProfile]:
    try:
        import jax
        kind = jax.devices()[0].device_kind.lower()
    except Exception:
        return None
    for prefix, prof in _KNOWN.items():
        if kind.startswith(prefix):
            return prof
    return None


def _env(name: str) -> Optional[float]:
    v = os.environ.get(name)
    return float(v) if v else None


def machine_profile(peak_flops: Optional[float] = None,
                    hbm_bw: Optional[float] = None,
                    link_bw: Optional[float] = None) -> MachineProfile:
    """Resolve the machine's roofline peaks (module docstring priority)."""
    peak_flops = peak_flops if peak_flops is not None else \
        _env("REPRO_PEAK_FLOPS")
    hbm_bw = hbm_bw if hbm_bw is not None else _env("REPRO_HBM_BW")
    link_bw = link_bw if link_bw is not None else _env("REPRO_LINK_BW")
    base = _detect()
    assumed = base is None and not (peak_flops and hbm_bw and link_bw)
    base = base or V5E
    name = base.name if base is not V5E or not assumed else "tpu-v5e-assumed"
    if peak_flops or hbm_bw or link_bw:
        name += "+overrides"
    return MachineProfile(
        name=name,
        peak_flops=peak_flops if peak_flops is not None else base.peak_flops,
        hbm_bw=hbm_bw if hbm_bw is not None else base.hbm_bw,
        link_bw=link_bw if link_bw is not None else base.link_bw,
        assumed=assumed)
