"""Post-SPMD HLO analysis: loop-aware collective traffic accounting.

``cost_analysis()`` does not expose collective traffic, and XLA:CPU's cost
analysis counts ``while`` (scan) bodies once rather than trip-count times. We
therefore parse the compiled module text ourselves:

- split into computations,
- per computation: sum collective payload bytes (result shapes) and record
  calls (``while`` bodies with trip counts recovered from their condition
  computations, ``call``/``conditional``/fusion subcomputations),
- DFS from ENTRY multiplying by trip counts.

Payload convention: we count the *result* bytes of each collective (for
all-reduce this equals the operand; for all-gather it is the gathered size,
an upper bound of ~G/(G-1) on wire traffic; reduce-scatter the scattered
result, a lower bound). This is the collective_bytes fed to the roofline's
``collective_bytes / (chips * link_bw)`` term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\), condition=(%?[\w.\-]+), body=(%?[\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|branch_computations)=\{?(%?[\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def result_bytes(line: str) -> int:
    """Bytes of the op's result: shapes between '=' and the op name."""
    eq = line.find("=")
    if eq < 0:
        return 0
    # result type is everything between '=' and the opcode token
    m = re.match(r"\s*((?:\([^)]*\))|(?:[a-z0-9_\[\],{}/ ]+?))\s+[a-z\-]+\(",
                 line[eq + 1:])
    seg = m.group(1) if m else line[eq + 1: eq + 160]
    return sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(seg))


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for l in cond_lines for c in _CONST_RE.findall(l)]
    return max(consts) if consts else 1


def collective_stats(text: str) -> Dict[str, Dict[str, float]]:
    raw = _split_raw(text)
    entry = raw.pop("__entry_name__", None)
    if entry is None:
        entry = max(raw, key=lambda k: len(raw[k][1]), default=None)
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0})
    seen_stack = []

    def walk(name: str, mult: float):
        if name not in raw or name in seen_stack or mult <= 0:
            return
        seen_stack.append(name)
        for line in raw[name][1]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(raw[cond][1] if cond in raw else [])
                walk(body, mult * trips)
                continue
            for kind in COLLECTIVES:
                if f" {kind}(" in line or f"{kind}-start(" in line:
                    if "-done(" in line:
                        continue
                    stats[kind]["count"] += mult
                    stats[kind]["bytes"] += result_bytes(line) * mult
                    break
            else:
                cm = _CALL_RE.search(line)
                if cm and ("call(" in line or "conditional(" in line):
                    walk(cm.group(1), mult)
        seen_stack.pop()

    if entry:
        walk(entry, 1.0)
    return dict(stats)


def split_computations(text: str):
    raw = _split_raw(text)
    raw.pop("__entry_name__", None)
    return {k: v[1] for k, v in raw.items()}


def total_collective_bytes(text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(text).values())


# ---------------------------------------------------------------------------
# loop-aware module costs (flops / bytes) — XLA:CPU cost_analysis counts scan
# bodies once, so we re-derive costs from the module text ourselves and
# multiply while bodies by their trip counts. Validated against cost_analysis
# on fully-unrolled lowerings (see EXPERIMENTS.md §Roofline methodology).
# ---------------------------------------------------------------------------
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")
_OPC_RE = re.compile(r"=\s*(?:\([^()]*\)|[a-z0-9_\[\],{}/ ]+?)\s+([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|[a-z0-9_\[\],{}/ ]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "after-all", "reshape", "copy-start", "copy-done", "partition-id",
             "replica-id", "iota", "opt-barrier"}
_RECURSE_OPS = {"call", "conditional", "while"}


def _dims_of(seg: str) -> List[Tuple[str, List[int]]]:
    return [(d, [int(x) for x in dims.split(",")] if dims.strip() else [])
            for d, dims in _SHAPE_RE.findall(seg)]


def _bytes_of_seg(seg: str) -> int:
    return sum(shape_bytes(d, ",".join(map(str, dims)))
               for d, dims in _dims_of(seg))


class _Comp:
    def __init__(self, header: str, lines: List[str]):
        self.lines = lines
        self.symbols: Dict[str, str] = {}      # %name -> result type segment
        # parameters from the header
        hdr_args = header[header.find("(") + 1: header.rfind("->")]
        for pm in re.finditer(r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])", hdr_args):
            self.symbols["%" + pm.group(1)] = pm.group(2)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            eq = line.find("=")
            om = _OPC_RE.search(line)
            end = om.start(1) if om else eq + 120
            self.symbols[dm.group(1)] = line[eq + 1:end]

    def sym_bytes(self, name: str) -> int:
        return _bytes_of_seg(self.symbols.get(name, ""))


def module_costs(text: str) -> Dict[str, float]:
    """Loop-aware {flops, bytes, collective_bytes, collective_count}."""
    raw = _split_raw(text)
    entry = raw.pop("__entry_name__", None)
    comps = {name: _Comp(header, lines)
             for name, (header, lines) in raw.items()}
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k].lines), default=None)

    totals = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
              "collective_count": 0.0}
    stack = []

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in stack or mult <= 0:
            return
        stack.append(name)
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            om = _OPC_RE.search(line)
            opc = om.group(1) if om else ""
            if opc in _FREE_OPS:
                continue
            if opc == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    cond = comps.get(wm.group(1))
                    trips = _trip_count(cond.lines if cond else [])
                    walk(wm.group(2), mult * trips)
                continue
            if opc in ("call", "conditional"):
                cm = _CALL_RE.search(line)
                if cm:
                    walk(cm.group(1), mult)
                continue
            # --- accountable op -------------------------------------------
            eq = line.find("=")
            res_seg = line[eq + 1: om.start(1)] if om else ""
            res_bytes = _bytes_of_seg(res_seg)
            arg_str = _args_of(line, om.end(1) if om else eq)
            operand_bytes = sum(comp.sym_bytes(o)
                                for o in _OPERAND_RE.findall(arg_str))
            totals["bytes"] += (res_bytes + operand_bytes) * mult
            is_coll = any(opc.startswith(c) for c in COLLECTIVES)
            if is_coll and not opc.endswith("-done"):
                totals["collective_bytes"] += res_bytes * mult
                totals["collective_count"] += mult
            if opc == "dot":
                res_elems = sum(
                    _prod(dims) for _, dims in _dims_of(res_seg))
                lhs = _OPERAND_RE.search(arg_str)
                cdims = _CDIMS_RE.search(line)
                k = 1
                if lhs and cdims and cdims.group(1).strip():
                    lhs_dims = _dims_of(comp.symbols.get(lhs.group(1), ""))
                    if lhs_dims:
                        for ci in cdims.group(1).split(","):
                            idx = int(ci)
                            if idx < len(lhs_dims[0][1]):
                                k *= lhs_dims[0][1][idx]
                totals["flops"] += 2.0 * res_elems * k * mult
            elif opc in ("fusion", "reduce", "convert", "add", "multiply",
                         "exponential", "divide", "subtract", "rsqrt",
                         "tanh", "custom-call", "select", "compare", "maximum"):
                res_elems = sum(_prod(dims) for _, dims in _dims_of(res_seg))
                totals["flops"] += float(res_elems) * mult
        stack.pop()

    if entry:
        walk(entry, 1.0)
    return totals


def _prod(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _args_of(line: str, start: int) -> str:
    args = line[start:]
    depth, end = 0, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return args[:end + 1]


def _split_raw(text: str):
    out: Dict[str, Tuple[str, List[str]]] = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("->" in line) and \
                line.rstrip().endswith("{"):
            stripped = line.replace("ENTRY ", "").strip()
            m = _HEADER_RE.match(stripped)
            name = m.group(1) if m else stripped.split()[0]
            out[name] = (line, [])
            cur = name
            if "ENTRY" in line:
                out["__entry_name__"] = name  # type: ignore
        elif cur is not None:
            out[cur][1].append(line)
    return out


def count_ops(text: str, names=("fusion", "dot", "custom-call")) -> Dict[str, int]:
    out = {}
    for n in names:
        out[n] = len(re.findall(rf"= [^=]*?\b{n}\b", text))
    return out
