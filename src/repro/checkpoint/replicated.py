"""Mirrored checkpoint stores across failure domains (paper §III semantics).

Writes go to every healthy replica and the save completes only when all
acked; restore reads from the replica with the newest valid version
(round-robin among ties); ``rebuild`` restores a lost replica by STREAMING
the donor's committed volumes block-by-block through both stores' public
read/write paths (``repro.durability.export.stream_store`` — the export
plane's chunked FETCH_PAGES/PUSH_PAGES analogue, with transport-style
accounting) — the engine-level replica rebuild, applied to the checkpoint
plane. The last rebuild's traffic is kept on ``last_rebuild``.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.store import CheckpointStore


class ReplicatedCheckpoint:
    def __init__(self, dirs: List[str], *, capacity_bytes: int = 1 << 30):
        self.paths = [os.path.join(d, "ckpt.dbs") for d in dirs]
        self.capacity = capacity_bytes
        self.stores: List[Optional[CheckpointStore]] = []
        for p in self.paths:
            try:
                self.stores.append(CheckpointStore(p, capacity_bytes=capacity_bytes))
            except Exception:
                self.stores.append(None)
        self._rr = 0

    def healthy(self) -> List[int]:
        return [i for i, s in enumerate(self.stores) if s is not None]

    def save(self, name: str, step: int, tree: Any, keep_last: int = 2):
        """Write-to-all: completes when every healthy replica acked."""
        if not self.healthy():
            raise IOError("no healthy checkpoint replica")
        for i in self.healthy():
            self.stores[i].save(name, step, tree, keep_last=keep_last)

    def restore(self, name: str, like: Any, shardings: Any = None
                ) -> Tuple[int, Any]:
        """Read from the newest valid replica, round-robin among ties."""
        best: Tuple[int, int] = (-1, -1)      # (step, idx)
        order = self.healthy()
        order = order[self._rr % len(order):] + order[:self._rr % len(order)]
        self._rr += 1
        for i in order:
            try:
                steps = self.stores[i].steps(name)
                if steps and steps[0] > best[0]:
                    best = (steps[0], i)
            except Exception:
                continue
        if best[1] < 0:
            raise IOError(f"no replica holds a valid checkpoint {name!r}")
        return self.stores[best[1]].restore(name, like, shardings)

    def fail(self, idx: int) -> None:
        """Simulate a node loss: close and drop the replica's device."""
        if self.stores[idx] is not None:
            try:
                self.stores[idx].close()
            except Exception:
                pass
        self.stores[idx] = None
        if os.path.exists(self.paths[idx]):
            os.remove(self.paths[idx])

    def rebuild(self, idx: int) -> Dict[str, Any]:
        """Rebuild a lost replica from the first healthy donor: create a
        FRESH store at the replica's path (``fail`` removed the file) and
        stream every committed checkpoint volume into it through the public
        block paths — no device-file copying. Returns the stream summary
        ({"volumes": {name: blocks}, "counters": ...})."""
        donors = self.healthy()
        if not donors:
            raise IOError("no donor replica")
        from repro.durability.export import stream_store
        donor = self.stores[donors[0]]
        donor.dev.f.flush()
        os.makedirs(os.path.dirname(self.paths[idx]) or ".", exist_ok=True)
        self.stores[idx] = CheckpointStore(self.paths[idx],
                                           capacity_bytes=self.capacity)
        self.last_rebuild = stream_store(donor, self.stores[idx])
        return self.last_rebuild

    def consistent(self) -> bool:
        revs = {self.stores[i].dev.revision for i in self.healthy()}
        return len(revs) <= 1

    def close(self):
        for s in self.stores:
            if s is not None:
                s.close()
