"""Checkpoint volumes on the on-disk DBS.

A checkpoint series = one DBS volume. Each ``save`` overwrites the volume's
blocks (copy-on-write against the previous version) and then freezes a
snapshot — so the snapshot chain is the retained version history, crash
consistency falls out of DBS semantics (a torn save only dirties the live
head; every frozen snapshot stays readable), and storage is incremental:
unchanged blocks are shared between versions through the chain.

Restore targets any mesh: leaves are stored unsharded and re-placed with the
target NamedSharding — that is the elastic-restart path (data-parallel width
can change between runs).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbs_host import DBSHost

BS = 4096          # block size
EB = 32            # blocks per extent (paper layout)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _manifest(leaves, treedef, step) -> bytes:
    entries = []
    off = 0
    for leaf in leaves:
        arr = np.asarray(leaf)
        nbytes = arr.nbytes
        entries.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                        "offset": off, "nbytes": nbytes})
        off += math.ceil(nbytes / BS) * BS
    m = {"step": int(step), "treedef": str(treedef), "entries": entries,
         "total": off}
    return json.dumps(m).encode()


class CheckpointStore:
    """One DBS device file holding checkpoint volumes."""

    def __init__(self, path: str, *, capacity_bytes: int = 1 << 30):
        n_extents = max(64, math.ceil(capacity_bytes / (BS * EB)))
        if os.path.exists(path):
            self.dev = DBSHost.open(path)
        else:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self.dev = DBSHost.create(
                path, n_extents=n_extents, extent_blocks=EB, block_size=BS,
                max_pages=n_extents)
        self.path = path

    # ------------------------------------------------------------------ save
    def save(self, name: str, step: int, tree: Any,
             keep_last: int = 2) -> int:
        leaves, treedef = _flatten(jax.device_get(tree))
        man = _manifest(leaves, treedef, step)
        man_blocks = math.ceil((len(man) + 16) / BS)
        header = json.dumps({"manifest_blocks": man_blocks,
                             "digest": hashlib.sha256(man).hexdigest()[:16]}
                            ).encode().ljust(BS, b"\x00")
        if name not in self.dev.volumes:
            self.dev.create_volume(name)
        # data blocks first, manifest+header last (commit record ordering)
        data_base = (1 + man_blocks) * BS
        off = 0
        for leaf in leaves:
            arr = np.ascontiguousarray(np.asarray(leaf))
            raw = arr.tobytes()
            pad = (-len(raw)) % BS
            self.dev.write(name, data_base + off, raw + b"\x00" * pad)
            off += len(raw) + pad
        self.dev.write(name, BS, man + b"\x00" * ((-len(man)) % BS))
        self.dev.write(name, 0, header)
        frozen = self.dev.snapshot(name)       # version committed
        self._gc(name, keep_last)
        return frozen

    def _gc(self, name: str, keep_last: int) -> None:
        """Merge-delete old snapshots beyond the retention window."""
        chain = self.dev._chain(self.dev.volumes[name])
        # chain[0] = live head; keep `keep_last` frozen snapshots after it
        deletable = chain[1 + keep_last:]
        for sid in reversed(deletable):
            if self.dev.snapshots[sid].parent < 0 and \
                    len(deletable) == len(chain) - 1 - keep_last:
                pass
            try:
                self.dev.delete_snapshot(sid)
            except ValueError:
                break                           # fork point: stop GC here

    # --------------------------------------------------------------- restore
    def restore(self, name: str, like: Any = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Returns (step, tree). ``like`` provides the treedef (required);
        ``shardings`` (optional pytree of NamedSharding) re-places leaves for
        the current mesh — the elastic-restart path."""
        blob = self._read_valid(name)
        man = blob["manifest"]
        leaves_like, treedef = _flatten(like)
        if len(man["entries"]) != len(leaves_like):
            raise ValueError("checkpoint/tree structure mismatch")
        data_base = (1 + blob["manifest_blocks"]) * BS
        out = []
        for ent in man["entries"]:
            raw = self.dev.read(blob["volume"], data_base + ent["offset"],
                                math.ceil(ent["nbytes"] / BS) * BS)
            arr = np.frombuffer(raw[:ent["nbytes"]],
                                dtype=np.dtype(ent["dtype"]))
            out.append(arr.reshape(ent["shape"]))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                tree, shardings)
        return man["step"], tree

    def _read_valid(self, name: str) -> Dict:
        """Validate the live head; fall back to the newest intact snapshot."""
        candidates = [name]
        chain = self.dev._chain(self.dev.volumes[name])
        for sid in chain[1:]:
            candidates.append(("@snap", sid))
        for cand in candidates:
            vol = name
            tmp = None
            try:
                if isinstance(cand, tuple):
                    tmp = f"__restore_{cand[1]}"
                    if tmp in self.dev.volumes:
                        self.dev.delete_volume(tmp)
                    self.dev.clone(name, tmp, snapshot_id=cand[1])
                    vol = tmp
                hdr = json.loads(self.dev.read(vol, 0, BS).split(b"\x00")[0])
                man_raw = self.dev.read(vol, BS, hdr["manifest_blocks"] * BS)
                man_raw = man_raw[:man_raw.rfind(b"}") + 1]
                if hashlib.sha256(man_raw).hexdigest()[:16] != hdr["digest"]:
                    raise IOError("digest mismatch")
                return {"volume": vol, "manifest": json.loads(man_raw),
                        "manifest_blocks": hdr["manifest_blocks"]}
            except Exception:
                if tmp and tmp in self.dev.volumes:
                    self.dev.delete_volume(tmp)
                continue
        raise IOError(f"no valid checkpoint for {name!r}")

    def steps(self, name: str) -> List[int]:
        try:
            return [self._read_valid(name)["manifest"]["step"]]
        except Exception:
            return []

    def close(self):
        self.dev.close()
