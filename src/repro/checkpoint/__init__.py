from repro.checkpoint.replicated import ReplicatedCheckpoint  # noqa: F401
from repro.checkpoint.store import CheckpointStore  # noqa: F401
