from repro.models.model import (decode_step, default_block_tables, forward,
                                init_cache, init_params, mtp_hidden,
                                param_count_actual, prefill,
                                with_block_tables)  # noqa: F401
