"""Attention implementations (XLA paths).

Three execution tiers, selected by the ExecutionPlan / call site:

- ``dense_attention``     : materializes (Sq, Skv) scores — oracle & tiny smokes.
- ``chunked_attention``   : FlashAttention algorithm in pure XLA — ``lax.scan``
                            over KV chunks with an online-softmax carry; O(S)
                            memory under grad via ``jax.checkpoint`` per chunk.
- ``banded_attention``    : sliding-window layers — scan over Q chunks, each
                            attending to a static (window + chunk) KV band
                            (HBM traffic O(S·W) instead of O(S²)).

Decode-side cores (single new token against a cache) live here too, including
the split-KV partial/merge pair used by the shard_map paged-DBS decode path
(pages striped over the "model" axis, FlashDecoding-style log-sum-exp merge —
see DESIGN.md §4).

The Pallas TPU kernels in ``repro.kernels`` implement the same contracts and
are validated against ``dense_attention`` oracles.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope  # noqa: F401 (re-export)
from repro.models.layers import softcap as _softcap

NEG_INF = -1e30


def _gqa_expand(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B,S,H,hd) -> (B,S,KV,G,hd) grouping query heads per KV head."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int) -> jnp.ndarray:
    """Causal (+ optional sliding window) mask: (B, Sq, Sk) booleans."""
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    if window and window > 0:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m


# ---------------------------------------------------------------------------
# dense (oracle)
# ---------------------------------------------------------------------------
def dense_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                    logit_cap: float = 0.0, scale: Optional[float] = None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd); *_pos: (B,S*) absolute positions."""
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _gqa_expand(q, n_kv)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = _softcap(logits, logit_cap)
    mask = _mask(q_pos, k_pos, window)[:, None, None]          # (B,1,1,Sq,Sk)
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked flash (global layers, train/prefill)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                      logit_cap: float = 0.0, scale: Optional[float] = None,
                      chunk: int = 1024, remat_chunks: bool = True,
                      unroll: bool = False):
    b, sq, h, d = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if sk % chunk:
        chunk = math.gcd(sk, chunk) or sk
    n_chunks = sk // chunk
    qg = _gqa_expand(q, n_kv).astype(jnp.float32)              # (B,KV,G,...) below
    qg = jnp.moveaxis(qg, 1, 3)                                # (B,KV,G,Sq,d)

    k_c = k.reshape(b, n_chunks, chunk, n_kv, k.shape[-1])
    v_c = v.reshape(b, n_chunks, chunk, n_kv, v.shape[-1])
    kp_c = k_pos.reshape(b, n_chunks, chunk)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, kp = xs                                        # (B,chunk,KV,d)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qg, kc.astype(jnp.float32)) * scale
        logits = _softcap(logits, logit_cap)
        mask = _mask(q_pos, kp, window)[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    if remat_chunks:
        body = jax.checkpoint(body)
    g = h // n_kv
    dv = v.shape[-1]
    init = (jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, n_kv, g, sq), jnp.float32),
            jnp.zeros((b, n_kv, g, sq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0), jnp.moveaxis(kp_c, 1, 0)),
        unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# banded sliding-window (local layers, train/prefill)
# ---------------------------------------------------------------------------
def banded_attention(q, k, v, q_pos, k_pos, *, window: int,
                     logit_cap: float = 0.0, scale: Optional[float] = None,
                     q_chunk: int = 1024, remat_chunks: bool = True,
                     unroll: bool = False):
    """Sliding-window attention reading only a (window + q_chunk) KV band per
    query chunk: HBM traffic O(S·W), the XLA analogue of a banded kernel."""
    b, sq, h, d = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if sq % q_chunk:
        q_chunk = math.gcd(sq, q_chunk) or sq
    band = window + q_chunk
    if band >= sk:  # band covers everything: fall back
        return chunked_attention(q, k, v, q_pos, k_pos, window=window,
                                 logit_cap=logit_cap, scale=scale,
                                 remat_chunks=remat_chunks, unroll=unroll)
    n_q = sq // q_chunk
    qg = jnp.moveaxis(_gqa_expand(q, n_kv).astype(jnp.float32), 1, 3)  # B,KV,G,Sq,d
    qg = qg.reshape(b, n_kv, h // n_kv, n_q, q_chunk, d)

    def body(carry, qi):
        del carry
        start = jnp.clip(qi * q_chunk + q_chunk - band, 0, sk - band)
        ks = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, start, band, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * q_chunk, q_chunk, axis=1)
        qb = qg[:, :, :, qi]                                   # (B,KV,G,qc,d)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qb, ks.astype(jnp.float32)) * scale
        logits = _softcap(logits, logit_cap)
        mask = _mask(qp, kp, window)[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        ob = jnp.einsum("bkgqs,bskd->bkgqd", w, vs.astype(jnp.float32))
        return None, ob

    if remat_chunks:
        body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, jnp.arange(n_q),
                           unroll=unroll)                      # (n_q,B,KV,G,qc,dv)
    dv = v.shape[-1]
    out = jnp.moveaxis(outs, 0, 3)                             # B,KV,G,n_q,qc,dv
    out = out.reshape(b, n_kv, h // n_kv, sq, dv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode cores
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, q_pos, k_pos, *, window: int = 0,
                     logit_cap: float = 0.0, scale: Optional[float] = None):
    """Single-step decode against a dense cache.

    q: (B,1,H,hd); caches: (B,S,KV,hd); q_pos: (B,1); k_pos: (B,S) with
    out-of-range slots marked by k_pos > q_pos (they mask off naturally).
    """
    o, m, l = decode_partial(q, k_cache, v_cache, q_pos, k_pos,
                             window=window, logit_cap=logit_cap, scale=scale)
    return finish_partial(o, m, l).astype(q.dtype)


def decode_partial(q, k_cache, v_cache, q_pos, k_pos, *, window: int = 0,
                   logit_cap: float = 0.0, scale: Optional[float] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Split-KV partial attention: returns unnormalized (o, m, l).

    This is the per-shard piece of the distributed paged-DBS read: each
    "model" shard holds a stripe of the volume's pages, computes its partial
    and the stripes merge with :func:`merge_partials` (psum form in
    ``repro.distributed.collectives``).
    """
    b, sq, h, d = q.shape
    n_kv = k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # native-dtype matmuls with fp32 accumulation (MXU bf16xbf16->f32): no
    # fp32 materialization of the gathered KV (§Perf iteration A3)
    qg = _gqa_expand(q, n_kv).astype(k_cache.dtype)            # (B,1,KV,G,d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, logit_cap)
    mask = _mask(q_pos, k_pos, window)[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                               # (B,KV,G,1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)                                # kill all-masked row exp(0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o, m, l


def merge_partials(o_parts, m_parts, l_parts):
    """Merge split-KV partials (stacked on axis 0) -> normalized output."""
    m_star = jnp.max(m_parts, axis=0)
    corr = jnp.exp(m_parts - m_star)
    l_star = jnp.sum(l_parts * corr, axis=0)
    o_star = jnp.sum(o_parts * corr[..., None], axis=0)
    return o_star / jnp.maximum(l_star[..., None], 1e-30)


def finish_partial(o, m, l):
    """(B,KV,G,1,d) unnormalized -> (B,1,H,d) normalized output."""
    b, kv, g, sq, d = o.shape
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, kv * g, sq, d).swapaxes(1, 2)


# ---------------------------------------------------------------------------
# paged decode (XLA gather path — the DBS read through the block table)
# ---------------------------------------------------------------------------
def paged_gather(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """pool: (E, page, ...); block_table: (B, P) int32 -> (B, P*page, ...).

    The gather *is* DBS's in-memory extent map lookup: O(1) per page and
    independent of snapshot-chain length (the paper's key DBS property)."""
    g = pool[block_table]                                      # (B,P,page,...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_decode_attention(q, pool_k, pool_v, block_table, q_pos, *,
                           window: int = 0, logit_cap: float = 0.0,
                           scale: Optional[float] = None,
                           page_owner_stride: int = 1, owner_rank: int = 0,
                           stripe_slice: bool = True):
    """Decode attention reading KV through DBS block tables.

    pool_k/pool_v: (E, page, KV, hd); block_table: (B, P_max) local extent ids
    (entries for pages this shard does not own are ignored via masking);
    page ``p`` of a sequence is owned by shard ``p % page_owner_stride``.
    Returns unnormalized partials (o, m, l) ready for the model-axis merge;
    single-shard callers normalize via :func:`finish_partial`.

    ``stripe_slice`` (§Perf iteration A2): gather only the P/stride pages this
    shard owns instead of gathering everything and masking — a stride-fold
    reduction in gather traffic. Falls back to gather+mask when P does not
    divide by the stride.
    """
    b, p_max = block_table.shape
    page = pool_k.shape[1]
    stride = page_owner_stride
    if stripe_slice and stride > 1 and p_max % stride == 0:
        # page p (global) = local column l*stride + rank
        bt = block_table.reshape(b, p_max // stride, stride)
        bt = jnp.take(bt, owner_rank, axis=2)                  # (B, P/stride)
        k = paged_gather(pool_k, bt)                           # owned pages only
        v = paged_gather(pool_v, bt)
        l_idx = jnp.arange(p_max // stride, dtype=jnp.int32)
        pos = ((l_idx * stride + owner_rank)[:, None] * page
               + jnp.arange(page, dtype=jnp.int32)[None, :])
        k_pos = jnp.broadcast_to(pos.reshape(-1), k.shape[:2])
        return decode_partial(q, k, v, q_pos, k_pos, window=window,
                              logit_cap=logit_cap, scale=scale)
    k = paged_gather(pool_k, block_table)                      # (B, P*page, KV, hd)
    v = paged_gather(pool_v, block_table)
    # absolute positions of gathered slots
    page_idx = jnp.arange(p_max, dtype=jnp.int32)
    owner_ok = (page_idx % page_owner_stride) == owner_rank    # (P,)
    pos = (page_idx[:, None] * page + jnp.arange(page, dtype=jnp.int32)[None, :])
    k_pos = jnp.broadcast_to(pos.reshape(-1), (b, p_max * page))
    # non-owned pages pushed out of causal range
    k_pos = jnp.where(jnp.repeat(owner_ok, page)[None, :], k_pos,
                      jnp.iinfo(jnp.int32).max)
    return decode_partial(q, k, v, q_pos, k_pos, window=window,
                          logit_cap=logit_cap, scale=scale)
