"""Model assembly: init / forward / prefill / decode over the layer schedule."""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ExecutionPlan, ATTN_GLOBAL, MLP_DENSE
from repro.models import blocks as B
from repro.models.layers import (Params, embed_tokens, init_embeddings,
                                 lm_logits, rms_norm)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    schedule = B.layer_schedule(cfg)
    keys = jax.random.split(key, len(schedule) + 3)
    p: Params = {"embed": init_embeddings(keys[0], cfg)}
    segs = []
    for si, seg in enumerate(schedule):
        seg_keys = jax.random.split(keys[si + 1], seg.count * len(seg.sigs))
        seg_keys = seg_keys.reshape(seg.count, len(seg.sigs), 2)
        seg_p = {}
        for pi, sig in enumerate(seg.sigs):
            seg_p[f"pos{pi}"] = jax.vmap(
                lambda k, s=sig: B.init_layer(k, cfg, s))(seg_keys[:, pi])
        segs.append(seg_p)
    p["segments"] = segs
    p["final_norm"] = (jnp.zeros((cfg.d_model,)) if cfg.name.startswith("gemma")
                       else jnp.ones((cfg.d_model,)))
    if cfg.mtp_depth:
        sig = B.LayerSig(cfg.layer_kind(cfg.n_layers - 1), 0, MLP_DENSE)
        p["mtp"] = {
            "block": B.init_layer(keys[-1], cfg, sig),
            "proj": jax.random.normal(keys[-2], (2 * cfg.d_model, cfg.d_model))
                    * 0.02,
            "norm": jnp.ones((cfg.d_model,)),
        }
    return p


def param_count_actual(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (train): scan over segments
# ---------------------------------------------------------------------------
def forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
            plan: ExecutionPlan, positions: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S[,K]) -> (final hidden states (B,S,D), aux_loss scalar)."""
    dtype = jnp.dtype(plan.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cfg, dtype)
    bsz, seq = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (bsz, seq))
    ctx = B.BlockCtx(mode="train", q_pos=positions, k_pos=positions,
                     attn_impl=plan.attn_impl, chunk=1024)
    schedule = B.layer_schedule(cfg)
    aux = jnp.zeros((), jnp.float32)
    for seg, seg_p in zip(schedule, params["segments"]):
        x, aux = _run_segment(cfg, seg, seg_p, x, aux, ctx, plan)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps,
                 gemma_style=cfg.name.startswith("gemma"))
    return h, aux


def _run_segment(cfg, seg: B.Segment, seg_p: Params, x, aux, ctx: B.BlockCtx,
                 plan: ExecutionPlan):
    def apply_one(x, aux, layer_p):
        for pi, sig in enumerate(seg.sigs):
            x, _, a = B.apply_block(cfg, sig, layer_p[f"pos{pi}"], x, ctx)
            aux = aux + a
        return x, aux

    if seg.count == 1 or not plan.scan_layers:
        for step in range(seg.count):
            lp = jax.tree.map(lambda a: a[step], seg_p)
            fn = apply_one
            if plan.remat != "none":
                fn = jax.checkpoint(fn)
            x, aux = fn(x, aux, lp)
        return x, aux

    def body(carry, layer_p):
        x, aux = carry
        return apply_one(x, aux, layer_p), None

    if plan.remat != "none":
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, aux), seg_p)
    return x, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               paged: bool = True, dtype=jnp.bfloat16,
               page_owner_stride: int = 1) -> List[Params]:
    """Per-layer cache list (global layer order)."""
    caches = []
    for sig in B.layer_sigs(cfg):
        caches.append(B.init_layer_cache(
            cfg, sig, batch, max_len, paged=paged, dtype=dtype,
            page_owner_stride=page_owner_stride))
    return caches


def default_block_tables(cfg: ArchConfig, batch: int, max_len: int,
                         page_owner_stride: int = 1,
                         batch_shards: int = 1) -> jnp.ndarray:
    """Identity page layout matching init_layer_cache's striped pool:
    page ``p`` of (locally-indexed) sequence ``b_loc`` lives at local extent
    ``b_loc * K + p // stride`` on stripe ``p % stride``.

    The serving engine replaces this with DBS-allocated tables; dry-runs and
    smoke tests use the identity layout.
    """
    import math as _m
    stride = max(page_owner_stride, 1)
    n_pages = _m.ceil(max_len / cfg.page_blocks)
    k_per = _m.ceil(n_pages / stride)
    b_local = jnp.arange(batch, dtype=jnp.int32) % max(batch // max(batch_shards, 1), 1)
    p = jnp.arange(n_pages, dtype=jnp.int32)
    return (p // stride)[None, :] + (b_local * k_per)[:, None]


def with_block_tables(caches: List[Params], bt: jnp.ndarray) -> List[Params]:
    out = []
    for c in caches:
        if c is not None and "block_table" in c:
            c = dict(c)
            c["block_table"] = bt[:, : c["block_table"].shape[1]]
        out.append(c)
    return out


# ---------------------------------------------------------------------------
# prefill / decode (unrolled layers, heterogeneous caches)
# ---------------------------------------------------------------------------
def _iter_layers(cfg, params):
    """Yields (global_layer_idx, sig, layer_params)."""
    if "layers_unstacked" in params:
        for li, (sig, lp) in enumerate(zip(B.layer_sigs(cfg),
                                           params["layers_unstacked"])):
            yield li, sig, lp
        return
    schedule = B.layer_schedule(cfg)
    li = 0
    for seg, seg_p in zip(schedule, params["segments"]):
        for step in range(seg.count):
            for pi, sig in enumerate(seg.sigs):
                lp = jax.tree.map(lambda a: a[step], seg_p[f"pos{pi}"])
                yield li, sig, lp
                li += 1


def unstack_params(params: Params, cfg: ArchConfig) -> Params:
    """Per-layer parameter trees for the decode path (§Perf iteration A4).

    Stacked segments are right for the training scan, but slicing them
    per-layer inside the decode step makes every layer's weight read charge
    (and on some backends copy) the whole stack. Serving engines therefore
    hold weights unstacked; this converts once, outside the step.
    """
    out = {k: v for k, v in params.items() if k != "segments"}
    out["layers_unstacked"] = [lp for _, _, lp in _iter_layers(cfg, params)]
    return out


def prefill(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
            plan: ExecutionPlan, caches: List[Params],
            positions: Optional[jnp.ndarray] = None,
            paged_decode_fn=None, page_owner_stride: int = 1,
            owner_rank: int = 0) -> Tuple[jnp.ndarray, List[Params]]:
    """Full-sequence forward that also fills the caches.

    Returns (logits of last position (B,V[,K->(B,K,V)]), caches)."""
    dtype = jnp.dtype(plan.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cfg, dtype)
    bsz, seq = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (bsz, seq))
    new_caches = list(caches)
    for li, sig, lp in _iter_layers(cfg, params):
        def run(x_, lp_, cache_, sig=sig):
            ctx = B.BlockCtx(mode="prefill", q_pos=positions, k_pos=positions,
                             cache=cache_, attn_impl=plan.attn_impl,
                             chunk=1024, paged_decode_fn=paged_decode_fn,
                             page_owner_stride=page_owner_stride,
                             owner_rank=owner_rank)
            out, nc, _ = B.apply_block(cfg, sig, lp_, x_, ctx)
            return out, nc
        if plan.remat != "none":
            run = jax.checkpoint(run)
        x, new_caches[li] = run(x, lp, caches[li])
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps,
                 gemma_style=cfg.name.startswith("gemma"))
    logits = lm_logits(params["embed"], h, cfg)
    return logits[:, 0], new_caches


def decode_step(params: Params, tokens: jnp.ndarray, pos: jnp.ndarray,
                cfg: ArchConfig, plan: ExecutionPlan, caches: List[Params],
                paged_decode_fn=None, page_owner_stride: int = 1,
                owner_rank: int = 0) -> Tuple[jnp.ndarray, List[Params]]:
    """One decode step. tokens: (B,) or (B,K); pos: (B,) current positions.

    Returns (logits (B,V) or (B,K,V), updated caches)."""
    dtype = jnp.dtype(plan.compute_dtype)
    tok = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
    x = embed_tokens(params["embed"], tok, cfg, dtype)          # (B,1,D)
    q_pos = pos[:, None].astype(jnp.int32)
    new_caches = list(caches)
    for li, sig, lp in _iter_layers(cfg, params):
        ctx = B.BlockCtx(mode="decode", q_pos=q_pos, cache=caches[li],
                         attn_impl=plan.attn_impl,
                         paged_decode_fn=paged_decode_fn,
                         page_owner_stride=page_owner_stride,
                         owner_rank=owner_rank)
        x, new_caches[li], _ = B.apply_block(cfg, sig, lp, x, ctx)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps,
                 gemma_style=cfg.name.startswith("gemma"))
    logits = lm_logits(params["embed"], h, cfg)
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# deepseek MTP (multi-token prediction) auxiliary hidden states
# ---------------------------------------------------------------------------
def mtp_hidden(params: Params, h: jnp.ndarray, tokens: jnp.ndarray,
               cfg: ArchConfig, plan: ExecutionPlan) -> jnp.ndarray:
    """DeepSeek-V3 MTP: combine h_t with emb(token_{t+1}) and run one extra
    block; the caller computes the t+2 loss on the result. h: (B,S,D)."""
    mtp = params["mtp"]
    dtype = h.dtype
    emb_next = embed_tokens(params["embed"], tokens[:, 1:], cfg, dtype)
    h_in = jnp.concatenate([
        rms_norm(h[:, :-1], mtp["norm"], cfg.norm_eps), emb_next], axis=-1)
    h_in = h_in @ mtp["proj"].astype(dtype)
    bsz, seq = h_in.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (bsz, seq))
    ctx = B.BlockCtx(mode="train", q_pos=positions, k_pos=positions,
                     attn_impl=plan.attn_impl)
    sig = B.LayerSig(cfg.layer_kind(cfg.n_layers - 1), 0, MLP_DENSE)
    out, _, _ = B.apply_block(cfg, sig, mtp["block"], h_in, ctx)
    return out
