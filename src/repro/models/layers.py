"""Shared layer primitives: norms, RoPE, MLPs, MoE, embeddings.

Everything is a pure function over explicit parameter pytrees (no flax/haiku
dependency): ``init_*`` builds params, ``apply`` style functions consume them.
Compute dtype is controlled by the caller (params are cast at the call site).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------
def _split(key, n):
    return list(jax.random.split(key, n))


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             *, gemma_style: bool = False) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    out = x * (1.0 + w) if gemma_style else x * w
    return out.astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma2-style tanh logit soft-capping; no-op when cap == 0."""
    if cap and cap > 0.0:
        return jnp.tanh(x / cap) * cap
    return x


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu_tanh":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    freqs = rope_frequencies(x.shape[-1], theta)                  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                                 # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = _split(key, 3)
    p = {"wi": dense_init(ks[0], d, f), "wo": dense_init(ks[1], f, d)}
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], d, f)
    return p


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    act = activation_fn(cfg.activation)
    h = x @ p["wi"].astype(x.dtype)
    h = act(h) * (x @ p["wg"].astype(x.dtype)) if "wg" in p else act(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE — token-dropless routing via sort + ragged_dot (MegaBlocks-on-TPU style)
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ArchConfig) -> Params:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.e_total
    ks = _split(key, 6)
    p: Params = {
        "router": dense_init(ks[0], d, e),
        "wi": jax.random.normal(ks[1], (e, d, f)) / math.sqrt(d),
        "wo": jax.random.normal(ks[2], (e, f, d)) / math.sqrt(f),
    }
    if cfg.gated_mlp:
        p["wg"] = jax.random.normal(ks[3], (e, d, f)) / math.sqrt(d)
    if mo.router_aux_free:
        p["router_bias"] = jnp.zeros((e,), jnp.float32)
    if mo.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=mo.n_shared * mo.d_ff_shared)
    return p


def apply_moe(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Dropless top-k MoE.

    Tokens are flattened, replicated top_k times, sorted by expert id and fed
    through ``jax.lax.ragged_dot`` (grouped GEMM, the TPU analogue of
    MegaBlocks' block-sparse GEMM). No capacity, no dropping: FLOPs are
    6*N_active*D, which is what the roofline accounting assumes.
    """
    mo = cfg.moe
    act = activation_fn(cfg.activation)
    orig_shape = x.shape
    xf = x.reshape(-1, cfg.d_model)
    t = xf.shape[0]

    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)  # (T, E)
    if mo.n_experts_padded > mo.n_experts:
        # padded experts exist only for even expert-parallel sharding; the
        # router never selects them
        dead = jnp.arange(mo.e_total) >= mo.n_experts
        logits = jnp.where(dead[None, :], -1e30, logits)
    if mo.router_aux_free:
        gates = jax.nn.sigmoid(logits)
        _, top_idx = jax.lax.top_k(gates + p["router_bias"], mo.top_k)
        top_gate = jnp.take_along_axis(gates, top_idx, axis=-1)
        top_w = top_gate / (jnp.sum(top_gate, -1, keepdims=True) + 1e-9)
    else:
        top_logits, top_idx = jax.lax.top_k(logits, mo.top_k)
        top_w = jax.nn.softmax(top_logits, axis=-1)

    flat_ids = top_idx.reshape(-1)                            # (T*k,)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_ids)                             # stable
    inv_order = jnp.argsort(order)
    sorted_ids = flat_ids[order]
    token_of = order // mo.top_k                              # (T*k,)
    xs = xf[token_of]                                         # (T*k, D) sorted by expert
    group_sizes = jnp.bincount(sorted_ids, length=mo.e_total)

    h = jax.lax.ragged_dot(xs, p["wi"].astype(xs.dtype), group_sizes)
    if "wg" in p:
        g = jax.lax.ragged_dot(xs, p["wg"].astype(xs.dtype), group_sizes)
        h = act(h) * g
    else:
        h = act(h)
    ys = jax.lax.ragged_dot(h, p["wo"].astype(xs.dtype), group_sizes)  # (T*k, D)

    ys = ys[inv_order] * flat_w[:, None].astype(ys.dtype)
    out = jnp.sum(ys.reshape(t, mo.top_k, cfg.d_model), axis=1)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], xf, cfg)

    # Switch-style load-balance aux loss (skipped for aux-free routing).
    if mo.router_aux_free:
        aux = jnp.zeros((), jnp.float32)
    else:
        probs = jax.nn.softmax(logits, -1)
        counts = jnp.zeros((mo.e_total,), jnp.float32).at[flat_ids].add(1.0)
        aux = mo.n_experts * jnp.sum(
            (counts / jnp.maximum(counts.sum(), 1.0)) * probs.mean(0))
    return out.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
# Embeddings / output head
# ---------------------------------------------------------------------------
def init_embeddings(key, cfg: ArchConfig) -> Params:
    ks = _split(key, 3)
    k_cb = cfg.n_codebooks
    shape = (k_cb, cfg.vocab_size, cfg.d_model) if k_cb > 1 else (cfg.vocab_size, cfg.d_model)
    p: Params = {"tokens": jax.random.normal(ks[0], shape) * 0.02}
    if not cfg.tie_embeddings:
        hshape = (k_cb, cfg.d_model, cfg.vocab_size) if k_cb > 1 else (cfg.d_model, cfg.vocab_size)
        p["lm_head"] = jax.random.normal(ks[1], hshape) * 0.02
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                 dtype=jnp.bfloat16) -> jnp.ndarray:
    """tokens: (B, S) or (B, S, K) for multi-codebook archs."""
    emb = p["tokens"].astype(dtype)
    if cfg.n_codebooks > 1:
        # sum the K codebook embeddings (musicgen)
        out = 0.0
        for k in range(cfg.n_codebooks):
            out = out + emb[k][tokens[..., k]]
    else:
        out = emb[tokens]
    if cfg.post_norms or cfg.activation == "gelu_tanh":
        # gemma normalizes embeddings by sqrt(d_model)
        if cfg.name.startswith("gemma"):
            out = out * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return out


def lm_logits(p: Params, h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """h: (..., D) -> logits (..., V) or (..., K, V)."""
    if cfg.tie_embeddings:
        table = p["tokens"].astype(h.dtype)
        if cfg.n_codebooks > 1:
            out = jnp.einsum("...d,kvd->...kv", h, table)
        else:
            out = h @ table.T
    else:
        head = p["lm_head"].astype(h.dtype)
        if cfg.n_codebooks > 1:
            out = jnp.einsum("...d,kdv->...kv", h, head)
        else:
            out = h @ head
    return softcap(out, cfg.final_logit_softcap)
