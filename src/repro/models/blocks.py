"""Transformer/SSM blocks, the layer schedule and KV-cache structures.

A model is a sequence of *segments*; each segment is ``count`` repetitions of
a static tuple of layer signatures. Segments with ``count > 1`` execute as a
``lax.scan`` over stacked parameters (train/prefill), while decode unrolls
layers and threads heterogeneous per-layer caches (paged DBS pools for global
attention, ring buffers for sliding-window layers, O(1) recurrent states for
Mamba/RWKV).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, ATTN_GLOBAL, ATTN_HYBRID,
                                ATTN_LOCAL, ATTN_MLA, ATTN_RWKV, MLP_DENSE,
                                MLP_MOE)
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (Params, _split, apply_mlp, apply_moe,
                                 dense_init, init_mlp, init_moe, rms_norm)

INT32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# layer schedule
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerSig:
    attn: str          # global | local | mla | hybrid | rwkv6
    window: int        # 0 = full attention
    mlp: str           # dense | moe


@dataclass(frozen=True)
class Segment:
    sigs: Tuple[LayerSig, ...]
    count: int
    first_layer: int   # global index of the segment's first layer


def layer_sigs(cfg: ArchConfig) -> List[LayerSig]:
    out = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        window = 0
        if kind == ATTN_LOCAL:
            window = cfg.sliding_window
        elif kind == ATTN_HYBRID:
            window = 0 if i in cfg.global_layer_indices else cfg.sliding_window
        out.append(LayerSig(kind, window, cfg.mlp_kind(i)))
    return out


def layer_schedule(cfg: ArchConfig) -> List[Segment]:
    sigs = layer_sigs(cfg)
    n = len(sigs)
    # try a small repeating unit (gemma2: LG, gemma3: LLLLLG)
    for u in range(1, 9):
        reps, tail = divmod(n, u)
        if reps < 2:
            break
        unit = tuple(sigs[:u])
        if tuple(sigs) == (unit * (reps + 1))[:n]:
            segs = [Segment(unit, reps, 0)]
            if tail:
                segs.append(Segment(tuple(sigs[reps * u:]), 1, reps * u))
            return segs
    # fallback: run-length segments (hymba, deepseek)
    segs: List[Segment] = []
    i = 0
    while i < n:
        j = i
        while j < n and sigs[j] == sigs[i]:
            j += 1
        segs.append(Segment((sigs[i],), j - i, i))
        i = j
    return segs


# ---------------------------------------------------------------------------
# per-layer parameter init
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ArchConfig, sig: LayerSig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = _split(key, 12)
    p: Params = {"ln1": jnp.zeros((d,)) if _gemma(cfg) else jnp.ones((d,)),
                 "ln2": jnp.zeros((d,)) if _gemma(cfg) else jnp.ones((d,))}
    if sig.attn == ATTN_RWKV:
        p["tmix_cmix"] = ssm.init_rwkv6(ks[0], cfg)
        return p
    if sig.attn == ATTN_MLA:
        m = cfg.mla
        qh = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
        p.update({
            "q_a": dense_init(ks[0], d, m.q_lora_rank),
            "q_a_norm": jnp.ones((m.q_lora_rank,)),
            "q_b": dense_init(ks[1], m.q_lora_rank, qh),
            "kv_a": dense_init(ks[2], d, m.kv_lora_rank + m.rope_head_dim),
            "kv_a_norm": jnp.ones((m.kv_lora_rank,)),
            "kv_b": dense_init(ks[3], m.kv_lora_rank,
                               cfg.n_heads * (m.nope_head_dim + m.v_head_dim)),
            "o": dense_init(ks[4], cfg.n_heads * m.v_head_dim, d),
        })
    else:
        p.update({
            "q": dense_init(ks[0], d, cfg.n_heads * hd),
            "k": dense_init(ks[1], d, cfg.n_kv_heads * hd),
            "v": dense_init(ks[2], d, cfg.n_kv_heads * hd),
            "o": dense_init(ks[3], cfg.n_heads * hd, d),
        })
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((hd,))
            p["k_norm"] = jnp.ones((hd,))
        if sig.attn == ATTN_HYBRID:
            p["mamba"] = ssm.init_mamba(ks[5], cfg)
            p["fuse_norm_attn"] = jnp.ones((d,))
            p["fuse_norm_ssm"] = jnp.ones((d,))
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((d,)) if _gemma(cfg) else jnp.ones((d,))
        p["ln2_post"] = jnp.zeros((d,)) if _gemma(cfg) else jnp.ones((d,))
    p["mlp"] = init_moe(ks[6], cfg) if sig.mlp == MLP_MOE else init_mlp(ks[6], cfg)
    return p


def _gemma(cfg: ArchConfig) -> bool:
    return cfg.name.startswith("gemma")


def _norm(cfg):
    def f(x, w):
        return rms_norm(x, w, cfg.norm_eps, gemma_style=_gemma(cfg))
    return f


# ---------------------------------------------------------------------------
# cache structures
# ---------------------------------------------------------------------------
def init_layer_cache(cfg: ArchConfig, sig: LayerSig, batch: int, max_len: int,
                     *, paged: bool, dtype=jnp.bfloat16,
                     page_owner_stride: int = 1) -> Params:
    """Cache pytree for one layer; shapes only — dryrun uses eval_shape."""
    hd = cfg.resolved_head_dim
    page = cfg.page_blocks
    if sig.attn == ATTN_RWKV:
        st = ssm.rwkv6_init_state(cfg, batch, dtype)
        return {"rwkv": st}
    c: Params = {}
    if sig.attn == ATTN_HYBRID:
        e = cfg.ssm.expand * cfg.d_model
        c["mamba"] = (jnp.zeros((batch, cfg.ssm.conv_kernel - 1, e), dtype),
                      jnp.zeros((batch, e, cfg.ssm.state_dim), jnp.float32))
    if sig.attn == ATTN_MLA:
        m = cfg.mla
        kd, vd = m.kv_lora_rank + m.rope_head_dim, m.kv_lora_rank
        n_kv = 1
    else:
        kd = vd = hd
        n_kv = cfg.n_kv_heads
    if sig.window:  # sliding-window ring buffer
        w = min(sig.window, max_len)
        c["ring_k"] = jnp.zeros((batch, w, n_kv, kd), dtype)
        c["ring_v"] = jnp.zeros((batch, w, n_kv, vd), dtype)
        c["ring_pos"] = jnp.full((batch, w), INT32_MAX, jnp.int32)
    elif paged:
        stride = max(page_owner_stride, 1)
        n_pages = math.ceil(max_len / page)
        padded = math.ceil(n_pages / stride) * stride
        # global pool: one extent per (sequence, padded page); stripe r of the
        # extent dim holds pages p with p % stride == r.
        n_ext = max(stride, batch * padded)
        c["pool_k"] = jnp.zeros((n_ext, page, n_kv, kd), dtype)
        c["pool_v"] = jnp.zeros((n_ext, page, n_kv, vd), dtype)
        c["block_table"] = jnp.zeros((batch, n_pages), jnp.int32)
    else:
        c["k"] = jnp.zeros((batch, max_len, n_kv, kd), dtype)
        c["v"] = jnp.zeros((batch, max_len, n_kv, vd), dtype)
    return c


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
@dataclass
class BlockCtx:
    """Everything a block needs besides params and the hidden state."""
    mode: str                              # train | prefill | decode
    q_pos: jnp.ndarray                     # (B, Sq) absolute positions
    k_pos: Optional[jnp.ndarray] = None    # (B, Sk) for train/prefill
    cache: Optional[Params] = None
    attn_impl: str = "chunked"             # dense | chunked | pallas
    chunk: int = 1024
    ssm_chunk: int = 256
    unroll: bool = False                   # unroll inner scans (accounting)
    paged_decode_fn: Optional[Callable] = None  # distributed override
    page_owner_stride: int = 1
    owner_rank: int = 0


def _project_qkv(cfg, p, h):
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    q = (h @ p["q"].astype(h.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (h @ p["k"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ p["v"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, gemma_style=_gemma(cfg))
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, gemma_style=_gemma(cfg))
    return q, k, v


def _project_mla(cfg, p, h, ctx):
    """Returns (q_eff, k_new, v_new, scale) in the *absorbed* latent basis.

    q_eff: (B,S,H,kv_rank+rope); k_new: (B,S,1,kv_rank+rope); v_new = latent
    (B,S,1,kv_rank). Works for train/prefill/decode uniformly — attention runs
    with one shared KV "head" and H query groups (GQA with n_kv=1).
    """
    m = cfg.mla
    b, s, _ = h.shape
    nope, rope, vd = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    qa = rms_norm(h @ p["q_a"].astype(h.dtype), p["q_a_norm"], cfg.norm_eps)
    q = (qa @ p["q_b"].astype(h.dtype)).reshape(b, s, cfg.n_heads, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = attn.apply_rope(q_rope, ctx.q_pos, cfg.rope_theta)

    kv = h @ p["kv_a"].astype(h.dtype)                         # (B,S,rank+rope)
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:].reshape(b, s, 1, rope)
    k_rope = attn.apply_rope(k_rope, ctx.q_pos, cfg.rope_theta)

    # absorb the k-part of kv_b into q:  q_lat = q_nope @ W_k^T (per head)
    w = p["kv_b"].astype(h.dtype).reshape(m.kv_lora_rank, cfg.n_heads, nope + vd)
    w_k = w[..., :nope]                                        # (rank, H, nope)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_k)
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)
    k_new = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
    v_new = c_kv[:, :, None, :]
    scale = 1.0 / math.sqrt(nope + rope)
    return q_eff, k_new, v_new, scale


def _mla_output(cfg, p, o_lat):
    """o_lat: (B,S,H,kv_rank) -> (B,S,D) via the absorbed v-part of kv_b."""
    m = cfg.mla
    w = p["kv_b"].astype(o_lat.dtype).reshape(
        m.kv_lora_rank, cfg.n_heads, m.nope_head_dim + m.v_head_dim)
    w_v = w[..., m.nope_head_dim:]                             # (rank, H, vd)
    o = jnp.einsum("bshr,rhv->bshv", o_lat, w_v)
    b, s = o.shape[:2]
    return o.reshape(b, s, cfg.n_heads * m.v_head_dim) @ p["o"].astype(o.dtype)


def _full_attention(cfg, sig, q, k, v, ctx, scale=None):
    """train/prefill attention dispatch (q,k,v already rope'd)."""
    kwargs = dict(window=sig.window, logit_cap=cfg.attn_logit_softcap,
                  scale=scale)
    if ctx.attn_impl == "dense":
        return attn.dense_attention(q, k, v, ctx.q_pos, ctx.k_pos, **kwargs)
    if ctx.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, ctx.q_pos, ctx.k_pos, **kwargs)
    if sig.window and sig.window > 0:
        return attn.banded_attention(q, k, v, ctx.q_pos, ctx.k_pos,
                                     window=sig.window,
                                     logit_cap=cfg.attn_logit_softcap,
                                     scale=scale, q_chunk=ctx.chunk,
                                     unroll=ctx.unroll)
    return attn.chunked_attention(q, k, v, ctx.q_pos, ctx.k_pos,
                                  chunk=ctx.chunk, unroll=ctx.unroll, **kwargs)


def _decode_attention(cfg, sig, p, q, k_new, v_new, ctx, cache, scale=None):
    """Single-token decode: read cache (+write the new KV), all cache kinds."""
    b = q.shape[0]
    pos = ctx.q_pos[:, 0]                                      # (B,)
    new_cache = dict(cache)
    cap = cfg.attn_logit_softcap
    if "ring_k" in cache:
        w = cache["ring_k"].shape[1]
        slot = pos % w
        rk = cache["ring_k"].at[jnp.arange(b), slot].set(k_new[:, 0])
        rv = cache["ring_v"].at[jnp.arange(b), slot].set(v_new[:, 0])
        rp = cache["ring_pos"].at[jnp.arange(b), slot].set(pos)
        new_cache.update(ring_k=rk, ring_v=rv, ring_pos=rp)
        out = attn.decode_attention(q, rk, rv, ctx.q_pos, rp,
                                    window=sig.window, logit_cap=cap, scale=scale)
    elif "pool_k" in cache:
        # write (into the owner's stripe) + paged read, both inside the
        # paged fn — distributed callers wrap it in shard_map so extent ids
        # stay local to their stripe (see distributed/collectives.py).
        fn = ctx.paged_decode_fn or _local_paged_decode
        out, pk, pv = fn(q, k_new, v_new, cache["pool_k"], cache["pool_v"],
                         cache["block_table"], ctx.q_pos,
                         window=sig.window, logit_cap=cap, scale=scale)
        new_cache.update(pool_k=pk, pool_v=pv)
    else:
        s_max = cache["k"].shape[1]
        kc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0)
                      )(cache["k"], k_new, pos)
        vc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0)
                      )(cache["v"], v_new, pos)
        new_cache.update(k=kc, v=vc)
        k_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32), (b, s_max))
        out = attn.decode_attention(q, kc, vc, ctx.q_pos, k_pos,
                                    window=sig.window, logit_cap=cap, scale=scale)
    return out, new_cache


def paged_write_local(pool_k, pool_v, block_table, pos, k_new, v_new,
                      stride: int = 1, rank=0):
    """Scatter one new token's K/V into the owner stripe's pool (local ids)."""
    b = pos.shape[0]
    page = pool_k.shape[1]
    page_idx = pos // page
    ext = block_table[jnp.arange(b), page_idx]
    off = pos % page
    # hole lanes (extent -1) must drop, not wrap to the pool's last row via
    # negative indexing — same sentinel the DBS read path masks
    owned = ((page_idx % stride) == rank) & (ext >= 0)
    ext_w = jnp.where(owned, ext, -1)
    pk = pool_k.at[ext_w, off].set(
        jnp.where(owned[:, None, None], k_new[:, 0], pool_k[ext_w, off]),
        mode="drop")
    pv = pool_v.at[ext_w, off].set(
        jnp.where(owned[:, None, None], v_new[:, 0], pool_v[ext_w, off]),
        mode="drop")
    return pk, pv


def _local_paged_decode(q, k_new, v_new, pool_k, pool_v, block_table, q_pos,
                        *, window=0, logit_cap=0.0, scale=None):
    pool_k, pool_v = paged_write_local(pool_k, pool_v, block_table,
                                       q_pos[:, 0], k_new, v_new)
    o, m, l = attn.paged_decode_attention(
        q, pool_k, pool_v, block_table, q_pos, window=window,
        logit_cap=logit_cap, scale=scale)
    return attn.finish_partial(o, m, l).astype(q.dtype), pool_k, pool_v


def _write_prefill_cache(cfg, sig, cache, k, v, ctx):
    """Store prefill K/V into the layer cache (ring / paged / dense)."""
    new_cache = dict(cache)
    b, s = k.shape[:2]
    if "ring_k" in cache:
        w = cache["ring_k"].shape[1]
        take = min(w, s)
        # slot = pos % w, the same rule decode uses — ring stays coherent for
        # any prefill length.
        slots = ctx.k_pos[:, -take:] % w                       # (B, take)
        rows = jnp.arange(b)[:, None]
        new_cache["ring_k"] = cache["ring_k"].at[rows, slots].set(k[:, -take:])
        new_cache["ring_v"] = cache["ring_v"].at[rows, slots].set(v[:, -take:])
        new_cache["ring_pos"] = cache["ring_pos"].at[rows, slots].set(
            ctx.k_pos[:, -take:])
    elif "pool_k" in cache:
        page = cache["pool_k"].shape[1]
        n_pages = s // page
        ext = cache["block_table"][:, :n_pages]                # (B,P)
        kp = k.reshape(b, n_pages, page, *k.shape[2:])
        vp = v.reshape(b, n_pages, page, *v.shape[2:])
        new_cache["pool_k"] = cache["pool_k"].at[ext].set(kp)
        new_cache["pool_v"] = cache["pool_v"].at[ext].set(vp)
    else:
        new_cache["k"] = cache["k"].at[:, :s].set(k)
        new_cache["v"] = cache["v"].at[:, :s].set(v)
    return new_cache


def apply_block(cfg: ArchConfig, sig: LayerSig, p: Params, x: jnp.ndarray,
                ctx: BlockCtx
                ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """One block. Returns (hidden, new_cache-or-None, aux_loss scalar)."""
    norm = _norm(cfg)
    new_cache = ctx.cache
    aux = jnp.zeros((), jnp.float32)

    # ---------------- token mixer ------------------------------------------
    if sig.attn == ATTN_RWKV:
        tp = p["tmix_cmix"]
        st = (ctx.cache or {}).get("rwkv") if ctx.cache else None
        if st is None:
            st = ssm.rwkv6_init_state(cfg, x.shape[0], x.dtype)
        h = norm(x, p["ln1"])
        y, st_t = ssm.rwkv6_time_mix(tp, h, st, cfg, chunk=ctx.ssm_chunk,
                                     unroll=ctx.unroll)
        x = x + y
        h2 = norm(x, p["ln2"])
        y2, st_c = ssm.rwkv6_channel_mix(tp, h2, st)
        x = x + y2
        if ctx.cache is not None:
            new_cache = {"rwkv": {**st, **st_t, **st_c}}
        return x, new_cache, aux

    resid = x
    h = norm(x, p["ln1"])
    scale = None
    if sig.attn == ATTN_MLA:
        q_eff, k_new, v_new, scale = _project_mla(cfg, p, h, ctx)
        q, k, v = q_eff, k_new, v_new
    else:
        q, k, v = _project_qkv(cfg, p, h)
        q = attn.apply_rope(q, ctx.q_pos, cfg.rope_theta)
        k = attn.apply_rope(k, ctx.q_pos, cfg.rope_theta)

    if ctx.mode == "decode":
        o, att_cache = _decode_attention(cfg, sig, p, q, k, v, ctx,
                                         ctx.cache, scale=scale)
        new_cache = att_cache
    else:
        o = _full_attention(cfg, sig, q, k, v, ctx, scale=scale)
        if ctx.mode == "prefill":
            new_cache = _write_prefill_cache(cfg, sig, ctx.cache, k, v, ctx)

    if sig.attn == ATTN_MLA:
        att_out = _mla_output(cfg, p, o)
    else:
        b, s = o.shape[:2]
        att_out = o.reshape(b, s, -1) @ p["o"].astype(o.dtype)

    if sig.attn == ATTN_HYBRID:
        mstate = (ctx.cache or {}).get("mamba") if ctx.cache else None
        if ctx.mode == "decode":
            m_out, m_state = ssm.mamba_step(p["mamba"], h, mstate)
        else:
            m_out, m_state = ssm.mamba_forward(p["mamba"], h, mstate,
                                               chunk=ctx.ssm_chunk,
                                               unroll=ctx.unroll)
        att_out = 0.5 * (norm(att_out, p["fuse_norm_attn"])
                         + norm(m_out, p["fuse_norm_ssm"]))
        if ctx.cache is not None:
            new_cache = dict(new_cache or {})
            new_cache["mamba"] = m_state

    if cfg.post_norms:
        att_out = norm(att_out, p["ln1_post"])
    x = resid + att_out

    # ---------------- MLP ---------------------------------------------------
    resid = x
    h = norm(x, p["ln2"])
    if sig.mlp == MLP_MOE:
        mlp_out, aux = apply_moe(p["mlp"], h, cfg)
    else:
        mlp_out = apply_mlp(p["mlp"], h, cfg)
    if cfg.post_norms:
        mlp_out = norm(mlp_out, p["ln2_post"])
    x = resid + mlp_out
    from repro.distributed.runtime import constrain
    x = constrain(x)
    return x, new_cache, aux
