"""State-space / linear-recurrence token mixers: Mamba (hymba branch), RWKV-6.

Both use the chunked formulation: sequence processed in fixed chunks with an
O(1) carried state, quadratic-within-chunk math — the same schedule the Pallas
``rwkv6_scan`` kernel implements on TPU (VMEM-resident chunk, state in VREGs).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rms_norm, _split

Params = Dict[str, Any]


# ===========================================================================
# Mamba branch (hymba hybrid heads)
# ===========================================================================
def init_mamba(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    e = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    kconv = cfg.ssm.conv_kernel
    dt_rank = max(16, d // 16)
    ks = _split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * e),
        "conv": jax.random.normal(ks[1], (kconv, e)) / math.sqrt(kconv),
        "w_bc": dense_init(ks[2], e, 2 * n),
        "w_dt1": dense_init(ks[3], e, dt_rank),
        "w_dt2": dense_init(ks[4], dt_rank, e),
        "dt_bias": jnp.full((e,), -4.6),          # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (e, 1))),
        "d_skip": jnp.ones((e,)),
        "out_proj": dense_init(ks[5], e, d),
    }


def _mamba_inner(p: Params, xz: jnp.ndarray, conv_state, ssm_state,
                 chunk: int = 256, unroll: bool = False):
    """Shared train/prefill core. xz: (B,S,2E) pre-activation projections.

    conv_state: (B,K-1,E) trailing inputs; ssm_state: (B,E,N).
    Returns (y (B,S,E), conv_state', ssm_state').
    """
    b, s, _ = xz.shape
    e = xz.shape[-1] // 2
    n = p["a_log"].shape[-1]
    x, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time with carried state
    kconv = p["conv"].shape[0]
    xin = jnp.concatenate([conv_state, x], axis=1)             # (B, K-1+S, E)
    new_conv_state = xin[:, -(kconv - 1):] if kconv > 1 else conv_state
    xc = sum(xin[:, i:i + s] * p["conv"][i].astype(x.dtype) for i in range(kconv))
    xc = jax.nn.silu(xc)

    bc = xc @ p["w_bc"].astype(x.dtype)                        # (B,S,2N)
    b_t, c_t = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = jax.nn.softplus(
        (xc @ p["w_dt1"].astype(x.dtype)) @ p["w_dt2"].astype(x.dtype)
        + p["dt_bias"].astype(x.dtype)).astype(jnp.float32)    # (B,S,E)
    a = -jnp.exp(p["a_log"])                                   # (E,N)
    xf = xc.astype(jnp.float32)

    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks

    def chunk_body(h, xs):
        xcb, dtb, bb, cb = xs                                  # (B,C,E) / (B,C,N)
        decay = jnp.exp(dtb[..., None] * a)                    # (B,C,E,N)
        inp = (dtb * xcb)[..., None] * bb[:, :, None, :]       # (B,C,E,N)

        def assoc(el1, el2):
            a1, b1 = el1
            a2, b2 = el2
            return a1 * a2, b1 * a2 + b2

        a_sc, b_sc = jax.lax.associative_scan(assoc, (decay, inp), axis=1)
        hs = a_sc * h[:, None] + b_sc                          # (B,C,E,N)
        y = jnp.einsum("bcen,bcn->bce", hs, cb)
        return hs[:, -1], y

    xs = tuple(t.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
               for t in (xf, dt, b_t, c_t))
    new_ssm, ys = jax.lax.scan(jax.checkpoint(chunk_body), ssm_state, xs,
                               unroll=unroll)
    y = ys.swapaxes(0, 1).reshape(b, s, e)
    y = y + xf * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype), new_conv_state, new_ssm


def mamba_forward(p: Params, x: jnp.ndarray, state=None, chunk: int = 256,
                  unroll: bool = False):
    """x: (B,S,D) -> (y (B,S,D), state). state=(conv (B,K-1,E), ssm (B,E,N))."""
    b, s, _ = x.shape
    if state is None:
        state = mamba_init_state(p, b, x.dtype)
    conv_state, ssm_state = state
    xz = x @ p["in_proj"].astype(x.dtype)
    y, cs, ss = _mamba_inner(p, xz, conv_state, ssm_state, chunk=chunk,
                             unroll=unroll)
    return y @ p["out_proj"].astype(x.dtype), (cs, ss)


def mamba_init_state(p: Params, batch: int, dtype=jnp.bfloat16):
    e = p["in_proj"].shape[-1] // 2
    n = p["a_log"].shape[-1]
    kconv = p["conv"].shape[0]
    return (jnp.zeros((batch, kconv - 1, e), dtype),
            jnp.zeros((batch, e, n), jnp.float32))


def mamba_step(p: Params, x: jnp.ndarray, state):
    """Single-token decode. x: (B,1,D)."""
    y, state = mamba_forward(p, x, state, chunk=1)
    return y, state


# ===========================================================================
# RWKV-6 (Finch): data-dependent decay linear recurrence
# ===========================================================================
def init_rwkv6(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    hd = cfg.ssm.rwkv_head_dim if cfg.ssm else 64
    h = d // hd
    lora = 32
    ks = _split(key, 12)
    return {
        # time-mix
        "mu": jax.random.uniform(ks[0], (5, d)),               # r,k,v,g,w shifts
        "w_r": dense_init(ks[1], d, d),
        "w_k": dense_init(ks[2], d, d),
        "w_v": dense_init(ks[3], d, d),
        "w_g": dense_init(ks[4], d, d),
        "w_o": dense_init(ks[5], d, d),
        "w0": jnp.full((d,), -6.0),                            # decay base
        "w_lora1": dense_init(ks[6], d, lora),
        "w_lora2": dense_init(ks[7], lora, d) * 0.1,
        "u": jax.random.normal(ks[8], (h, hd)) * 0.1,          # bonus
        "ln_x": jnp.ones((d,)),                                # per-head groupnorm
        # channel-mix
        "mu_c": jax.random.uniform(ks[9], (2, d)),
        "c_k": dense_init(ks[10], d, cfg.d_ff),
        "c_v": dense_init(ks[11], cfg.d_ff, d),
        "c_r": dense_init(ks[0], d, d),
    }


def rwkv6_init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    hd = cfg.ssm.rwkv_head_dim if cfg.ssm else 64
    h = d // hd
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, d), dtype),               # time-mix x_{t-1}
        "shift_c": jnp.zeros((batch, d), dtype),               # channel-mix x_{t-1}
    }


def _rwkv_chunk(r, k, v, logw, u, s_in):
    """One chunk of the RWKV6 recurrence (all fp32).

    r,k,v: (B,C,H,hd); logw: (B,C,H,hd) (log decay, <= 0); u: (H,hd);
    s_in: (B,H,hd,hd).  Returns (y (B,C,H,hd), s_out).
    """
    cum = jnp.cumsum(logw, axis=1)                             # inclusive
    cum_excl = cum - logw                                      # exclusive
    # inter-chunk: y_i += (r_i * exp(cum_excl_i)) @ S_in
    r_dec = r * jnp.exp(cum_excl)
    y = jnp.einsum("bchk,bhkv->bchv", r_dec, s_in)
    # intra-chunk: s < i term with decay exp(cum_excl_i - cum_s)
    att = jnp.einsum("bchk,bshk->bhcs", r_dec, k * jnp.exp(-cum))
    c_len = r.shape[1]
    tri = jnp.tril(jnp.ones((c_len, c_len), bool), k=-1)
    att = jnp.where(tri[None, None], att, 0.0)
    y = y + jnp.einsum("bhcs,bshv->bchv", att, v)
    # diagonal bonus term: y_i += (r_i . (u * k_i)) v_i
    y = y + jnp.sum(r * (u[None, None] * k), axis=-1, keepdims=True) * v
    # state update: S_out = diag(exp(cum_C)) S_in + sum_s (k_s exp(cum_C-cum_s))^T v_s
    total = cum[:, -1][:, None]                                # (B,1,H,hd)
    k_dec = k * jnp.exp(total - cum)
    s_out = jnp.exp(total[:, 0])[..., None] * s_in + jnp.einsum(
        "bshk,bshv->bhkv", k_dec, v)
    return y, s_out


def rwkv6_time_mix(p: Params, x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                   cfg: ArchConfig, chunk: int = 64, unroll: bool = False):
    """x: (B,S,D) -> (y, new_state pieces). Handles S==1 (decode) too."""
    b, s, d = x.shape
    hd = cfg.ssm.rwkv_head_dim if cfg.ssm else 64
    h = d // hd
    x_prev = jnp.concatenate([state["shift_t"][:, None], x[:, :-1]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x * mu[i] + x_prev * (1 - mu[i]) for i in range(5))
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(b, s, h, hd).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(b, s, h, hd).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(b, s, h, hd).astype(jnp.float32)
    g = xg @ p["w_g"].astype(x.dtype)
    logw = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + ((xw @ p["w_lora1"].astype(x.dtype)) @ p["w_lora2"].astype(x.dtype))
        .astype(jnp.float32)).reshape(b, s, h, hd)

    n_chunks = max(1, s // chunk)
    c = s // n_chunks

    def body(s_carry, xs):
        rc, kc, vc, wc = xs
        y, s_new = _rwkv_chunk(rc, kc, vc, wc, p["u"].astype(jnp.float32), s_carry)
        return s_new, y

    xs = tuple(t.reshape(b, n_chunks, c, h, hd).swapaxes(0, 1)
               for t in (r, k, v, logw))
    s_out, ys = jax.lax.scan(jax.checkpoint(body), state["wkv"], xs,
                             unroll=unroll)
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    # per-head group norm + gate + out proj
    y = y.reshape(b, s, h, hd)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-5)
    y = (y.reshape(b, s, d) * p["ln_x"]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = y @ p["w_o"].astype(x.dtype)
    return out, {"wkv": s_out, "shift_t": x[:, -1]}


def rwkv6_channel_mix(p: Params, x: jnp.ndarray, state: Dict[str, jnp.ndarray]):
    x_prev = jnp.concatenate([state["shift_c"][:, None], x[:, :-1]], axis=1)
    mu = p["mu_c"].astype(x.dtype)
    xk = x * mu[0] + x_prev * (1 - mu[0])
    xr = x * mu[1] + x_prev * (1 - mu[1])
    k = jnp.square(jax.nn.relu(xk @ p["c_k"].astype(x.dtype)))
    v = k @ p["c_v"].astype(x.dtype)
    r = jax.nn.sigmoid(xr @ p["c_r"].astype(x.dtype))
    return r * v, {"shift_c": x[:, -1]}
