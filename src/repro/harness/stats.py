"""Latency/tail statistics + transport counters for harness runs.

Two latency lanes, deliberately different clocks:

- **pump ticks** — the ``Request.latency`` lane every backend fills at
  completion (PR 4): how many engine iterations an op spent in flight.
  The harness records each op's fan-out max (``IOFuture.latency()``); the
  percentiles here are what the BENCH ``trace`` key reports per scenario.
- **wait ticks** — the controller-side ``_Waiter.wait_ticks`` counter
  (core/replication.py): *simulated-network* time the controller spent
  waiting on replica links. Wall time barely separates read/write
  policies on a simulated link (ticking is host-cheap); wait ticks are
  the quantity the policies actually trade, so the straggler tail gates
  are expressed in them.

Percentiles use the nearest-rank method on the sorted sample — exact,
deterministic, no interpolation surprises at tiny sample sizes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on an empty sample."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = max(1, -(-len(s) * q // 100))        # ceil(n*q/100), min 1
    return float(s[int(rank) - 1])


def summarize(values: Sequence[float]) -> Dict[str, float]:
    """count/mean/p50/p99/p999/max of a sample (all 0.0 when empty)."""
    if not values:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                "p999": 0.0, "max": 0.0}
    return {
        "count": len(values),
        "mean": float(sum(values)) / len(values),
        "p50": percentile(values, 50),
        "p99": percentile(values, 99),
        "p999": percentile(values, 99.9),
        "max": float(max(values)),
    }


def transport_counters(storage: Any) -> Optional[Dict[str, Any]]:
    """Aggregate the per-link transport counters (core/transport.py) of a
    replica-group storage: messages sent per opcode, deliveries,
    retransmits and rebuild-stream pages moved. None when the backend has
    no transports (upstream/host/chained/null)."""
    transports = getattr(storage, "transports", None)
    if not transports:
        return None
    sent: Dict[str, int] = {}
    for t in transports:
        for op, n in t.sent.items():
            sent[op] = sent.get(op, 0) + int(n)
    return {
        "sent": dict(sorted(sent.items())),
        "delivered": sum(t.delivered for t in transports),
        "retransmits": sum(t.retransmits for t in transports),
        "pages_moved": sum(t.pages_moved for t in transports),
        "per_link_retransmits": [int(t.retransmits) for t in transports],
    }


def wait_ticks(storage: Any) -> Optional[int]:
    """The controller's accumulated wait-tick counter, when the storage is
    a policy object (``_Waiter``); None otherwise."""
    wt = getattr(storage, "wait_ticks", None)
    return int(wt) if wt is not None else None


def latency_lanes(per_kind: Dict[str, List[float]]) -> Dict[str, Any]:
    """Summaries per op kind plus the pooled sample."""
    pooled: List[float] = []
    out: Dict[str, Any] = {}
    for kind, vals in sorted(per_kind.items()):
        out[kind] = summarize(vals)
        pooled.extend(vals)
    out["all"] = summarize(pooled)
    return out
