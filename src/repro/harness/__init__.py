"""Trace-driven chaos harness: deterministic load + fault schedules + gates.

The paper evaluates the optimized engine under steady fio-style load; real
SDS engines diverge from their averages in the *tail*, under failure. This
package drives the public ``VolumeManager`` API (core/blockdev.py) with a
seeded, replayable op stream while a chaos scheduler injects replica
failures, quorum loss, rebuilds, link degradation and mid-trace control
ops — every run reproducible from ``(trace_seed, chaos_seed)``:

- ``traces``  — the fio-style trace generator: seq/rand mixes, read
  fraction, burst arrivals, zipf-hot volumes and pages, aligned and
  unaligned byte spans,
- ``chaos``   — the chaos scheduler: trace-indexed fault/control events,
- ``oracle``  — the shadow bytearray oracle: mirrors every acked write,
  checks byte equivalence on every read and, at end of trace, on every
  surviving replica,
- ``stats``   — latency percentiles (P50/P99/P999 in pump ticks via the
  ``Request.latency`` lane) + controller wait-tick tails + transport
  counters,
- ``runner``  — ``run(...)``: one harness execution; the named scenario
  catalog (``SCENARIOS``/``run_scenario``); ``run_matrix`` +
  ``check_trace_gates`` — the BENCH ``trace`` key and its CI gates.

Tests, the benchmark ladder (``run_trace``) and the ``chaos-smoke`` CI
step (``python -m repro.harness``) all drive the same ``run()`` entry
point. See docs/ARCHITECTURE.md ("Chaos harness").
"""
from repro.harness.chaos import ChaosConfig, ChaosEvent, schedule_chaos
from repro.harness.oracle import ByteOracle, OracleMismatch
from repro.harness.runner import (SCENARIOS, HarnessResult, check_trace_gates,
                                  run, run_matrix, run_scenario)
from repro.harness.stats import percentile, summarize
from repro.harness.traces import TraceConfig, TraceOp, generate_trace

__all__ = [
    "ChaosConfig", "ChaosEvent", "schedule_chaos",
    "ByteOracle", "OracleMismatch",
    "SCENARIOS", "HarnessResult", "check_trace_gates", "run", "run_matrix",
    "run_scenario",
    "percentile", "summarize",
    "TraceConfig", "TraceOp", "generate_trace",
]
