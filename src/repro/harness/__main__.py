"""CLI for the chaos harness: ``python -m repro.harness``.

Runs the scenario matrix (plus the determinism replay) and writes the
BENCH-style ``trace`` document — the same shape ``benchmarks/ladder.py``
embeds under its ``trace`` key. ``--check`` applies ``check_trace_gates``
and exits non-zero on any violation; the CI ``chaos-smoke`` step runs
``--smoke --check`` and uploads the json next to the bench artifact.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.harness.runner import SCENARIOS, check_trace_gates, run_matrix


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.harness",
        description="trace-driven chaos harness: scenario matrix + gates")
    ap.add_argument("--smoke", action="store_true",
                    help="short traces (CI-sized)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME", choices=sorted(SCENARIOS),
                    help="run only this scenario (repeatable)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the trace json document here")
    ap.add_argument("--check", action="store_true",
                    help="apply the harness gates; exit 1 on violation")
    args = ap.parse_args(argv)

    trace = run_matrix(smoke=args.smoke, trace_seed=args.trace_seed,
                       chaos_seed=args.chaos_seed, scenarios=args.scenario)
    for name, doc in trace.items():
        if name == "determinism":
            print(f"  determinism[{doc['scenario']}]: "
                  f"match={doc['match']} digest={doc['digest_a'][:12]}")
            continue
        lat = doc["latency"]["all"]
        print(f"  {name}: ops={doc['n_ops']} oracle_ok={doc['oracle_ok']} "
              f"checked_reads={doc['checked_reads']} "
              f"events={doc['events_applied']}+{doc['events_skipped']}skip "
              f"lat p50/p99/p999={lat['p50']:g}/{lat['p99']:g}/"
              f"{lat['p999']:g} ticks")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"trace": trace}, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if args.check:
        problems = check_trace_gates(trace)
        if problems:
            print("HARNESS GATES FAILED:")
            for p in problems:
                print(f"  - {p}")
            return 1
        print("harness gates: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
