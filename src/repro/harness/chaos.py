"""The chaos scheduler: seeded, trace-indexed fault and control events.

A *chaos schedule* is the failure half of a harness run: a list of
``ChaosEvent`` records, fully determined by ``(chaos_seed, ChaosConfig,
n_ops, n_replicas, n_volumes)``, each pinned to a trace index — the runner
fires every event whose ``index`` equals the next op's, *before*
submitting that op. Because events are indexed into the op stream (not
wall time), a replay hits each fault at exactly the same point in the
load, which is what makes ``(trace_seed, chaos_seed)`` replay
byte-identically.

Event vocabulary (the scenario catalog in ``runner.py`` composes these):

- ``fail`` / ``rebuild``   — replica failure and streamed delta rebuild
  (the controller's ``fail``/``rebuild`` control verbs; rebuilds while
  earlier write-behind traffic is still in flight are the point),
- ``quorum_loss``          — fail every replica but one (writes continue
  degraded under the quorum/async policies),
- ``recover``              — rebuild every failed replica (back-to-back
  delta rebuilds from the lone survivor after a quorum loss),
- ``snapshot`` / ``clone`` / ``discard`` — mid-trace control ops racing
  the data stream (and any in-flight rebuild traffic),
- ``straggler`` / ``heal`` — degrade one simnet link's latency mid-trace /
  restore it,
- ``drop_on`` / ``drop_off`` — raise one simnet link's loss rate / clear it,
- ``crash``               — kill the engine at a pump boundary and recover
  it from the durability journal (repro/durability); scheduled by
  ``ChaosConfig.crash_every`` at fixed trace indices (not by weight — a
  crash must land at predictable pump boundaries), with every second crash
  also tearing a partial record onto the journal tail first (``arg=1``) to
  exercise torn-tail truncation.

The scheduler tracks simulated replica health while generating, so it
emits schedules that are *mostly* valid by construction; the runner still
guards every application (e.g. never failing the last healthy replica)
and counts deterministic skips instead of crashing — an invalid event
must replay as the same skip.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

# action -> default weight (ChaosConfig.weights overrides)
DEFAULT_WEIGHTS: Dict[str, float] = {
    "fail": 3.0, "rebuild": 3.0, "quorum_loss": 1.0, "recover": 2.0,
    "snapshot": 2.0, "clone": 1.0, "discard": 2.0,
    "straggler": 1.0, "heal": 1.0, "drop_on": 1.0, "drop_off": 1.0,
}


@dataclass(frozen=True)
class ChaosConfig:
    """Shape of the schedule. ``n_events`` events are spread uniformly over
    the trace; ``weights`` reweights (or, with zero, disables) actions —
    e.g. link actions are meaningless off simnet, so pure-local scenarios
    zero them out."""

    n_events: int = 8
    weights: Tuple[Tuple[str, float], ...] = ()
    straggler_latency: int = 8
    drop_rate: float = 0.2
    crash_every: int = 0    # >0: crash-and-recover every N trace ops
                            # (journal-enabled runs only); every 2nd crash
                            # tears a partial record onto the tail first


@dataclass(frozen=True)
class ChaosEvent:
    index: int          # fires before trace op `index` is submitted
    action: str
    replica: int = -1   # fail/rebuild/straggler/heal/drop_* target
    vol: int = -1       # snapshot/clone/discard target (trace-local index)
    off: int = 0        # discard span
    nbytes: int = 0
    arg: float = 0.0    # straggler latency / drop rate


def schedule_chaos(chaos_seed: int, cfg: ChaosConfig, *, n_ops: int,
                   n_replicas: int, n_volumes: int,
                   capacity: int = 0) -> List[ChaosEvent]:
    """Generate the event list for one run (module docstring). Sorted by
    ``index``; deterministic in every argument."""
    rng = np.random.default_rng(chaos_seed)
    weights = dict(DEFAULT_WEIGHTS)
    weights.update(dict(cfg.weights))
    if n_replicas < 2:      # no replica to lose -> no replica-fault events
        for a in ("fail", "rebuild", "quorum_loss", "recover"):
            weights[a] = 0.0
    actions = [a for a, w in weights.items() if w > 0]
    w = np.asarray([weights[a] for a in actions], np.float64)
    w /= w.sum()
    n_events = min(cfg.n_events, max(n_ops - 1, 1))
    indices = np.sort(rng.choice(np.arange(1, n_ops), size=n_events,
                                 replace=n_events >= n_ops - 1))
    healthy = [True] * n_replicas       # simulated controller health view
    events: List[ChaosEvent] = []
    for idx in indices:
        action = actions[int(rng.choice(len(actions), p=w))]
        ev = None
        if action == "fail":
            up = [r for r, h in enumerate(healthy) if h]
            if len(up) > 1:
                r = int(up[int(rng.integers(len(up)))])
                healthy[r] = False
                ev = ChaosEvent(int(idx), "fail", replica=r)
        elif action == "rebuild":
            down = [r for r, h in enumerate(healthy) if not h]
            if down:
                r = int(down[int(rng.integers(len(down)))])
                healthy[r] = True
                ev = ChaosEvent(int(idx), "rebuild", replica=r)
        elif action == "quorum_loss":
            up = [r for r, h in enumerate(healthy) if h]
            if len(up) > 1:
                keep = int(up[int(rng.integers(len(up)))])
                for r in up:
                    healthy[r] = r == keep
                ev = ChaosEvent(int(idx), "quorum_loss", replica=keep)
        elif action == "recover":
            if not all(healthy):
                for r in range(n_replicas):
                    healthy[r] = True
                ev = ChaosEvent(int(idx), "recover")
        elif action in ("snapshot", "clone"):
            ev = ChaosEvent(int(idx), action,
                            vol=int(rng.integers(n_volumes)))
        elif action == "discard":
            off = int(rng.integers(max(capacity, 1)))
            nbytes = int(rng.integers(1, max(capacity // 4, 2)))
            ev = ChaosEvent(int(idx), "discard",
                            vol=int(rng.integers(n_volumes)), off=off,
                            nbytes=min(nbytes, max(capacity - off, 1)))
        elif action == "straggler":
            ev = ChaosEvent(int(idx), "straggler",
                            replica=int(rng.integers(n_replicas)),
                            arg=float(cfg.straggler_latency))
        elif action == "heal":
            ev = ChaosEvent(int(idx), "heal",
                            replica=int(rng.integers(n_replicas)))
        elif action == "drop_on":
            ev = ChaosEvent(int(idx), "drop_on",
                            replica=int(rng.integers(n_replicas)),
                            arg=float(cfg.drop_rate))
        elif action == "drop_off":
            ev = ChaosEvent(int(idx), "drop_off",
                            replica=int(rng.integers(n_replicas)))
        if ev is not None:
            events.append(ev)
    if cfg.crash_every > 0:
        # fixed-index crash points (trace-indexed pump boundaries), torn
        # tail on every second one; merged in index order with the rest
        for k, idx in enumerate(range(cfg.crash_every, n_ops,
                                      cfg.crash_every)):
            events.append(ChaosEvent(int(idx), "crash", arg=float(k % 2)))
        events.sort(key=lambda e: e.index)
    return events
