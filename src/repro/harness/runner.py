"""The harness runner: replay a trace under a chaos schedule, check bytes.

``run()`` is THE entry point — tests, the benchmark ladder's ``run_trace``
workload and the ``chaos-smoke`` CI step (``python -m repro.harness``) all
drive it, so every consumer stresses the same engine code path: the public
``VolumeManager`` byte API over a registered backend, transport and
write/read policy.

One run:

1. generate the op stream from ``(trace_seed, TraceConfig, geometry)`` and
   the event list from ``(chaos_seed, ChaosConfig)`` — or take both
   pre-built (the edge-case tests hand-craft ``ChaosEvent`` lists),
2. replay: submit each burst asynchronously, firing due chaos events
   before the op they are pinned to; flush at burst boundaries; check
   every read against the shadow oracle (expected bytes captured at
   submission — the API's ordering point) and assert **no hung
   ``IOFuture``** (every future a chaos run hands out must resolve),
3. verify: drain the transports (write-behind stragglers land), rebuild
   every still-failed replica, read every volume end-to-end through the
   normal path, then — on host-dispatch replica groups — force the read
   path onto EACH replica in turn (fail the others, read, rebuild) so a
   stale rebuilt copy cannot hide behind a healthy peer,
4. report: pump-tick latency percentiles, controller wait-tick tails,
   transport counters, and a replay ``digest`` (sha1 over per-op
   completion ticks, the verification read-back bytes and the retransmit
   counters) — two runs with identical seeds/config MUST produce identical
   digests, the determinism gate CI enforces.

**Seed threading (replay determinism).** The harness owns the one seed
rule: on ``transport="simnet"`` it threads ``chaos_seed`` into the
transport's ``seed`` opt unless the caller pinned one, so the simulated
network's drop/reorder decisions replay with the run — identical
``(trace_seed, chaos_seed, transport_opts)`` is byte-identical end to end
(``tests/test_harness.py::test_replay_determinism``).

``SCENARIOS`` is the named catalog the ladder/CI matrix runs; adding a
scenario = adding one entry (docs/ARCHITECTURE.md walks through it).
"""
from __future__ import annotations

import hashlib
import os
import shutil
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.blockdev import IOFuture, Volume, VolumeManager
from repro.harness import stats
from repro.harness.chaos import ChaosConfig, ChaosEvent, schedule_chaos
from repro.harness.oracle import ByteOracle, OracleMismatch
from repro.harness.traces import (TraceConfig, TraceOp, generate_trace,
                                  payload_bytes)

# default tiny geometry: big enough for multi-page spans and CoW pressure,
# small enough that the full-capacity per-replica verification reads stay
# cheap on a CPU smoke box
GEOMETRY = dict(block_bytes=16, page_blocks=4, n_pages=32, batch=16,
                n_extents=2048, max_volumes=12, n_queues=4, n_slots=256)


@dataclass
class HarnessResult:
    """Everything one run measured (module docstring, step 4)."""

    n_ops: int
    completed: int                      # engine SQE completions
    checked_reads: int
    oracle_failures: List[str]
    harness_failures: List[str]         # hung futures / bad statuses
    events_applied: List[str]
    events_skipped: List[str]
    completion_ticks: List[int]         # per trace op, in pump ticks
    latency: Dict[str, Any]             # pump-tick percentiles per kind
    wait: Dict[str, Any]                # wait-tick percentiles (1-op bursts)
    counters: Optional[Dict[str, Any]]  # transport counters (None w/o links)
    wall_s: float
    digest: str
    compute_checked: int = 0            # COMPUTE SQEs checked vs mirrors
    crashes: int = 0                    # crash-and-recover events applied

    @property
    def ok(self) -> bool:
        return not self.oracle_failures and not self.harness_failures

    def raise_if_failed(self) -> None:
        if not self.ok:
            raise OracleMismatch(
                "harness run failed:\n  "
                + "\n  ".join((self.oracle_failures
                               + self.harness_failures)[:20]))

    def to_dict(self) -> Dict[str, Any]:
        """The BENCH-json shape (compact: tick lists become the digest)."""
        return {
            "n_ops": self.n_ops, "completed": self.completed,
            "checked_reads": self.checked_reads,
            "compute_checked": self.compute_checked,
            "crashes": self.crashes,
            "oracle_ok": self.ok,
            "failures": (self.oracle_failures + self.harness_failures)[:5],
            "events_applied": len(self.events_applied),
            "events_skipped": len(self.events_skipped),
            "latency": self.latency, "wait": self.wait,
            "counters": self.counters, "wall_s": self.wall_s,
            "ops_per_s": (self.n_ops / self.wall_s if self.wall_s else 0.0),
            "digest": self.digest,
        }


def _healthy_replicas(storage) -> Optional[List[int]]:
    """Indices of healthy replicas when the storage is a host-dispatch
    ``ReplicaGroup`` (the plane replica-level chaos targets); None on
    backends whose health lives elsewhere (sharded masks — see
    ``_Run.sharded`` — or no replicas at all)."""
    reps = getattr(storage, "replicas", None)
    if reps is None:
        return None
    return [i for i, r in enumerate(reps) if r.healthy]


class _Run:
    """One harness execution's mutable state (``run()`` drives it)."""

    def __init__(self, mgr: VolumeManager, oracle: ByteOracle,
                 trace_seed: int, journal_path: Optional[str] = None,
                 mgr_kwargs: Optional[Dict[str, Any]] = None):
        self.mgr = mgr
        self.oracle = oracle
        self.trace_seed = trace_seed
        self.journal_path = journal_path    # crash events need the WAL...
        self.mgr_kwargs = mgr_kwargs or {}  # ...and the geometry to recover
        self.crashes = 0
        self.storage = mgr.engine.backend
        # sharded replica plane: health is a dense (S, R) mask, not a list
        # of Replica objects — replica chaos mirrors each verb across ALL
        # shards so the S slices stay in lock-step. Gated strictly on
        # comm="sharded": the ring backend stacks the same storage but
        # serves control in-band, and its scenario digest must not move.
        self.sharded = mgr.engine.cfg.comm == "sharded"
        self.vols: List[Volume] = []
        self.clones: List[Volume] = []
        # (op-or-None, future, expected-bytes-or-None) awaiting the flush
        self.pending: List[Tuple[Optional[TraceOp], IOFuture,
                                 Optional[bytes]]] = []
        # (context, future, expected (value, status, aux)) COMPUTE calls
        self.pending_compute: List[Tuple[str, IOFuture, tuple]] = []
        self.compute_checked = 0
        self._n_comp = 0
        self.latency: Dict[str, List[float]] = {"read": [], "write": []}
        self.wait: Dict[str, List[float]] = {"read": [], "write": []}
        self.completion_ticks: List[int] = []
        self.harness_failures: List[str] = []
        self.applied: List[str] = []
        self.skipped: List[str] = []
        self._base_latency: Dict[int, int] = {}
        self._base_drop: Dict[int, float] = {}

    # -- chaos event application (guarded; a skip replays as a skip) --------
    def _simnet_link(self, replica: int):
        ts = getattr(self.storage, "transports", None)
        if ts is None or not 0 <= replica < len(ts):
            return None
        t = ts[replica]
        return t if hasattr(t, "latency") else None   # simnet links only

    def _sharded_repl_event(self, ev: ChaosEvent) -> bool:
        """Apply one replica-plane event across every shard of a sharded
        group (all-shard mirror keeps health uniform). Returns True when
        the event applied, False for a deterministic skip."""
        ctl = self.mgr.engine.control
        g = self.storage
        S, R = g.n_shards, g.n_replicas
        h = g.healthy                                   # (S, R) bool
        if ev.action == "fail":
            if (not 0 <= ev.replica < R
                    or not bool(h[:, ev.replica].all())
                    or int(h.sum(axis=1).min()) < 2):
                return False
            for s in range(S):
                ctl("fail", shard=s, replica=ev.replica)
        elif ev.action == "rebuild":
            if not 0 <= ev.replica < R or bool(h[:, ev.replica].any()):
                return False
            for s in range(S):
                ctl("rebuild", shard=s, replica=ev.replica)
        elif ev.action == "quorum_loss":
            up = [r for r in range(R) if bool(h[:, r].all())]
            if len(up) < 2:
                return False
            keep = ev.replica if ev.replica in up else up[0]
            for r in up:
                if r != keep:
                    for s in range(S):
                        ctl("fail", shard=s, replica=r)
        else:                                           # recover
            if bool(h.all()):
                return False
            for s in range(S):
                for r in range(R):
                    if not h[s, r]:
                        ctl("rebuild", shard=s, replica=r)
        return True

    def _crash(self, torn: bool) -> None:
        """Kill the engine at a pump boundary and recover it from the WAL.

        The crash point is a pump boundary by construction: every pending
        future is flushed and checked first (exactly the state the journal's
        last seal covers), then the journal is fsynced and the manager is
        ABANDONED — never closed, like a dead process. With ``torn`` a
        half-written record is appended to the journal file first (a crash
        mid-group-commit), which recovery must detect and truncate. The
        recovered manager, storage and volume handles replace the dead
        ones and the trace keeps replaying into them; a recovery that
        diverges (``RecoveryError`` / id mismatch) aborts the run — unlike
        guarded chaos verbs, a bad recovery must never replay as a skip."""
        from repro.core.transport import MSG_WRITE, WireMsg
        from repro.durability.journal import encode_record
        from repro.durability.recovery import recover
        import numpy as np
        self.flush_burst(None)                  # settle + check in-flight
        self.mgr.flush(durable=True)            # seal + fsync the WAL
        dead, jpath = self.mgr, self.journal_path
        if torn:
            rec = encode_record(10 ** 9, WireMsg(
                op=MSG_WRITE, volume=0,
                pages=np.asarray([0], np.int32),
                blocks=np.asarray([0], np.int32),
                payload=np.zeros((1, 4), np.float32)))
            with open(jpath, "ab") as f:        # crash mid-append: half a
                f.write(rec[:len(rec) // 2])    # record past the last seal
        del dead                                # abandoned, not closed
        new = recover(jpath, **self.mgr_kwargs)
        self.mgr = new
        self.storage = new.engine.backend
        self.vols = [new.open(v.vid) for v in self.vols]
        self.clones = [new.open(v.vid) for v in self.clones]
        self.crashes += 1

    def apply_event(self, ev: ChaosEvent) -> None:
        name = f"@{ev.index} {ev.action}"
        if ev.action == "crash":
            if self.journal_path is None:
                self.skipped.append(name + " (no journal)")
                return
            torn = ev.arg >= 1.0
            self._crash(torn)
            self.applied.append(name + (" torn" if torn else ""))
            return
        ctl = self.mgr.engine.control
        healthy = _healthy_replicas(self.storage)
        try:
            if ev.action in ("fail", "rebuild", "quorum_loss", "recover"):
                if healthy is None:
                    if self.sharded:
                        if self._sharded_repl_event(ev):
                            self.applied.append(name)
                        else:
                            self.skipped.append(name)
                        return
                    self.skipped.append(name + " (no replica plane)")
                    return
                if ev.action == "fail":
                    if ev.replica not in healthy or len(healthy) < 2:
                        self.skipped.append(name)
                        return
                    ctl("fail", replica=ev.replica)
                elif ev.action == "rebuild":
                    n = len(self.storage.replicas)
                    if ev.replica in healthy or not 0 <= ev.replica < n:
                        self.skipped.append(name)
                        return
                    ctl("rebuild", replica=ev.replica)
                elif ev.action == "quorum_loss":
                    keep = (ev.replica if ev.replica in healthy
                            else healthy[0])
                    for r in healthy:
                        if r != keep:
                            ctl("fail", replica=r)
                else:                                   # recover
                    n = len(self.storage.replicas)
                    for r in range(n):
                        if r not in healthy:
                            ctl("rebuild", replica=r)
            elif ev.action == "snapshot":
                self.mgr.snapshot(self.vols[ev.vol % len(self.vols)])
            elif ev.action == "clone":
                src = self.vols[ev.vol % len(self.vols)]
                child = self.mgr.clone(src)
                if child is None:                       # volume table full
                    self.skipped.append(name + " (table full)")
                    return
                self.oracle.clone(src.vid, child.vid)
                self.clones.append(child)
            elif ev.action == "discard":
                v = self.vols[ev.vol % len(self.vols)]
                fut = v.discard(ev.off, ev.nbytes)
                self.oracle.discard(v.vid, ev.off, ev.nbytes)
                self.pending.append((None, fut, None))
            elif ev.action in ("straggler", "heal", "drop_on", "drop_off"):
                link = self._simnet_link(ev.replica)
                if link is None:
                    self.skipped.append(name + " (no simnet link)")
                    return
                if ev.action == "straggler":
                    self._base_latency.setdefault(ev.replica, link.latency)
                    link.latency = max(int(ev.arg), 1)
                elif ev.action == "heal":
                    link.latency = self._base_latency.get(ev.replica,
                                                          link.latency)
                elif ev.action == "drop_on":
                    self._base_drop.setdefault(ev.replica, link.drop)
                    link.drop = float(ev.arg)
                else:
                    link.drop = self._base_drop.get(ev.replica, 0.0)
            else:
                self.skipped.append(name + " (unknown action)")
                return
        except (RuntimeError, ValueError, IndexError) as e:
            # a guarded-but-still-invalid event must replay as the same
            # deterministic skip, never abort the run
            self.skipped.append(name + f" ({e})")
            return
        self.applied.append(name)

    # -- burst replay -------------------------------------------------------
    def submit(self, op: TraceOp) -> None:
        v = self.vols[op.vol]
        if op.kind == "write":
            data = payload_bytes(self.trace_seed, op.index, op.nbytes)
            fut = v.pwrite(op.off, data)
            self.oracle.write(v.vid, op.off, data)
            self.pending.append((op, fut, None))
        else:
            expected = self.oracle.expected(v.vid, op.off, op.nbytes)
            fut = v.pread(op.off, op.nbytes)
            self.pending.append((op, fut, expected))

    # -- in-band compute mixing ---------------------------------------------
    # deterministic rotation through the built-ins: every ``compute_every``
    # trace ops one COMPUTE SQE rides the same volume's queue, its expected
    # (value, status, payload) captured at submission by running the
    # entry's pure-Python mirror against the byte-oracle shadow — the same
    # ordering point the read/write oracle uses. compare_and_write's mirror
    # mutates the shadow on match, so subsequent reads check against the
    # CAS-committed bytes.
    _FN_CYCLE = ("checksum", "scan_count", "filter_pages",
                 "verify_on_read", "compare_and_write")

    def submit_compute(self, op: TraceOp) -> None:
        from repro.compute import make_storage_fn
        from repro.compute.functions import py_blocksum, py_i32
        mgr = self.mgr
        i = self._n_comp
        self._n_comp += 1
        fn = self._FN_CYCLE[i % len(self._FN_CYCLE)]
        entry = make_storage_fn(fn)
        v = self.vols[op.vol]
        shadow = self.oracle.shadow[v.vid]
        pby, bb = mgr.page_bytes, mgr.block_bytes
        n_pages = mgr.capacity // pby
        arg, data = 0, None
        if entry.scope == "range":
            p0 = (i * 5 + self.trace_seed) % n_pages
            cnt = n_pages - p0
            off, nbytes = p0 * pby, cnt * pby
            page, count = p0, cnt
            if fn != "checksum":
                arg = -1 if i % 7 == 0 else (self.trace_seed * 31
                                             + i * 17) % 256
            expected = entry.mirror(shadow, pby, bb, page, count, arg, None)
        else:
            ab = (i * 13 + self.trace_seed) % (mgr.capacity // bb)
            off, nbytes = ab * bb, bb
            page, block = ab // mgr.page_blocks, ab % mgr.page_blocks
            cur = py_blocksum(shadow[off:off + bb])
            if fn == "compare_and_write":
                data = payload_bytes(self.trace_seed, 100_000 + i, bb)
                # alternate matching and stale expectations: both the
                # committed and the ST_MISMATCH path replay under chaos
                arg = cur if i % 2 == 0 else py_i32((cur + 1) & 0xFFFFFFFF)
            else:                          # verify_on_read
                arg = cur if i % 2 == 0 else 0
            expected = entry.mirror(shadow, pby, bb, page, block, arg, data)
        fut = v.compute(fn, off, nbytes, arg=arg, data=data)
        self.pending_compute.append(
            (f"compute {fn}@{i} vol{v.vid}[{off}:{off + nbytes}]",
             fut, expected))

    def _check_computes(self) -> None:
        for ctx, fut, (e_val, e_stt, e_aux) in self.pending_compute:
            if not fut.done():
                self.harness_failures.append(
                    f"{ctx}: IOFuture hung after a full flush")
                continue
            try:
                res = fut.result()
            except OSError as e:
                self.harness_failures.append(f"{ctx}: {e}")
                continue
            self.compute_checked += 1
            if (res.value, res.status) != (int(e_val), int(e_stt)):
                self.oracle.failures.append(
                    f"{ctx}: (value, status) = ({res.value}, {res.status}), "
                    f"mirror expected ({int(e_val)}, {int(e_stt)})")
            elif e_aux is not None:
                got = (res.pages() if res.fn == "filter_pages"
                       else res.data())
                want = (list(e_aux) if res.fn == "filter_pages"
                        else bytes(e_aux))
                if got != want:
                    self.oracle.failures.append(
                        f"{ctx}: payload {got!r} != mirror {want!r}")
        self.pending_compute.clear()

    def flush_burst(self, wait_before: Optional[int]) -> None:
        self.mgr.flush()
        wait_after = stats.wait_ticks(self.storage)
        trace_ops = [p for p in self.pending if p[0] is not None]
        for op, fut, expected in self.pending:
            if not fut.done():
                self.harness_failures.append(
                    f"op {op.index if op else '(chaos)'}: IOFuture hung "
                    "after a full flush")
                continue
            try:
                val = fut.result()
            except OSError as e:
                self.harness_failures.append(
                    f"op {op.index if op else '(chaos)'}: {e}")
                continue
            if expected is not None and op is not None:
                v = self.vols[op.vol]
                self.oracle.check(
                    val, expected,
                    f"op {op.index} read vol{v.vid}[{op.off}:"
                    f"{op.off + op.nbytes}]")
            if op is not None:
                self.latency[op.kind].append(float(fut.latency()))
                self.completion_ticks.append(fut.completion_tick())
        if (wait_before is not None and wait_after is not None
                and len(trace_ops) == 1):
            # singleton burst: the controller wait-tick delta is THIS op's
            # (the clock the straggler tail gates are expressed in)
            self.wait[trace_ops[0][0].kind].append(
                float(wait_after - wait_before))
        self.pending.clear()
        if self.pending_compute:
            self._check_computes()

    # -- end-of-trace verification ------------------------------------------
    def verify(self) -> bytes:
        """Final oracle sweep (module docstring, step 3). Returns the
        concatenated read-back bytes (digest input)."""
        mgr, oracle = self.mgr, self.oracle
        mgr.flush()
        if hasattr(self.storage, "drain_transports"):
            self.storage.drain_transports()
        ctl = mgr.engine.control
        healthy = _healthy_replicas(self.storage)
        if healthy is not None:
            for r in range(len(self.storage.replicas)):
                if r not in healthy:
                    ctl("rebuild", replica=r)           # final rebuild
        elif self.sharded:
            h = self.storage.healthy
            for s in range(self.storage.n_shards):
                for r in range(self.storage.n_replicas):
                    if not h[s, r]:
                        ctl("rebuild", shard=s, replica=r)
        volumes = self.vols + self.clones
        blob = bytearray()

        def read_all(tag: str) -> None:
            for v in volumes:
                got = v.read(0, mgr.capacity)
                blob.extend(got)
                oracle.check(got, oracle.expected(v.vid, 0, mgr.capacity),
                             f"{tag} vol{v.vid}")

        read_all("end-of-trace")
        if healthy is not None:
            n = len(self.storage.replicas)
        elif self.sharded:
            n = self.storage.n_replicas
        else:
            n = 0
        if n > 1 and not mgr.engine.cfg.null_storage:
            # force the read path onto EACH surviving replica in turn
            # (every shard at once on the sharded plane)
            def repl_ctl(kind: str, r: int) -> None:
                if self.sharded:
                    for s in range(self.storage.n_shards):
                        ctl(kind, shard=s, replica=r)
                else:
                    ctl(kind, replica=r)

            for serve in range(n):
                others = [r for r in range(n) if r != serve]
                for r in others:
                    repl_ctl("fail", r)
                read_all(f"replica {serve}")
                for r in others:
                    repl_ctl("rebuild", r)
        return bytes(blob)


def run(*, trace_seed: int = 0, chaos_seed: int = 0,
        trace: Optional[TraceConfig] = None,
        chaos: Optional[ChaosConfig] = None,
        trace_ops: Optional[List[TraceOp]] = None,
        chaos_events: Optional[List[ChaosEvent]] = None,
        backend: str = "slots", n_replicas: int = 2, n_shards: int = 1,
        kernel: str = "auto", transport: str = "local",
        write_policy: str = "all", read_policy: str = "rr",
        transport_opts: Optional[Dict[str, Any]] = None,
        geometry: Optional[Dict[str, int]] = None,
        verify_replicas: bool = True, strict: bool = False,
        compute_every: int = 0, journal: bool = False) -> HarnessResult:
    """One harness execution (module docstring). ``trace_ops`` /
    ``chaos_events`` bypass the generators (hand-crafted tests); otherwise
    both derive from the seeds. ``strict=True`` raises ``OracleMismatch``
    at the end instead of returning a failed result. ``compute_every=N``
    mixes one COMPUTE SQE (rotating through the built-in storage
    functions) into the stream every N trace ops, each checked against
    its pure-Python mirror over the oracle shadow; 0 (the default) leaves
    the stream — and the replay digest — untouched. ``journal=True``
    attaches a write-ahead journal (repro/durability) in a temp dir —
    required by ``crash`` chaos events (``ChaosConfig.crash_every``),
    which abandon the manager mid-trace and recover it from the WAL."""
    trace = trace or TraceConfig()
    geo = dict(GEOMETRY)
    geo.update(geometry or {})
    if transport == "simnet":
        # THE seed rule: the simulated network's drop/reorder stream is part
        # of the replay identity — derive it from chaos_seed unless pinned
        transport_opts = dict(transport_opts or {})
        transport_opts.setdefault("seed", chaos_seed)
    mgr_kwargs = dict(
        backend=backend, n_shards=n_shards, n_replicas=n_replicas,
        payload_elems=geo["block_bytes"], page_blocks=geo["page_blocks"],
        max_pages=geo["n_pages"], n_extents=geo["n_extents"],
        max_volumes=geo["max_volumes"], n_queues=geo["n_queues"],
        n_slots=geo["n_slots"], batch=geo["batch"], kernel=kernel,
        transport=transport, write_policy=write_policy,
        read_policy=read_policy, transport_opts=transport_opts)
    jdir = journal_path = None
    if journal:
        jdir = tempfile.mkdtemp(prefix="repro-harness-wal-")
        journal_path = os.path.join(jdir, "wal.dbsj")
    mgr = VolumeManager(journal=journal_path, **mgr_kwargs)
    oracle = ByteOracle(mgr.capacity)
    st = _Run(mgr, oracle, trace_seed, journal_path=journal_path,
              mgr_kwargs=mgr_kwargs)
    if trace_ops is None:
        trace_ops = generate_trace(
            trace_seed, trace, block_bytes=geo["block_bytes"],
            page_blocks=geo["page_blocks"], n_pages=geo["n_pages"])
    if chaos_events is None:
        chaos_events = [] if chaos is None else schedule_chaos(
            chaos_seed, chaos, n_ops=len(trace_ops) or 1,
            n_replicas=n_replicas, n_volumes=trace.n_volumes,
            capacity=mgr.capacity)
    by_index: Dict[int, List[ChaosEvent]] = {}
    for ev in chaos_events:
        by_index.setdefault(ev.index, []).append(ev)
    for _ in range(trace.n_volumes):
        oracle.add_volume(mgr.create().vid)
    st.vols = [mgr.open(vid) for vid in sorted(oracle.shadow)]
    t0 = time.perf_counter()
    wait_before = stats.wait_ticks(st.storage)
    try:
        for op in trace_ops:
            for ev in by_index.pop(op.index, ()):
                st.apply_event(ev)
            st.submit(op)
            if compute_every and (op.index + 1) % compute_every == 0:
                st.submit_compute(op)
            if op.last_in_burst:
                st.flush_burst(wait_before)
                wait_before = stats.wait_ticks(st.storage)
        for idx in sorted(by_index):                    # post-trace events
            for ev in by_index[idx]:
                st.apply_event(ev)
        st.flush_burst(wait_before)
        blob = st.verify() if verify_replicas else b""
        counters = stats.transport_counters(st.storage)
        wall = time.perf_counter() - t0
        h = hashlib.sha1()
        h.update(b"ticks:" + ",".join(
            map(str, st.completion_ticks)).encode())
        h.update(b"|bytes:" + blob)
        if counters is not None:
            h.update(b"|retx:" + ",".join(
                map(str, counters["per_link_retransmits"])).encode())
        result = HarnessResult(
            n_ops=len(trace_ops), completed=st.mgr.engine.completed,
            checked_reads=oracle.checked_reads,
            compute_checked=st.compute_checked, crashes=st.crashes,
            oracle_failures=list(oracle.failures),
            harness_failures=st.harness_failures,
            events_applied=st.applied, events_skipped=st.skipped,
            completion_ticks=st.completion_ticks,
            latency=stats.latency_lanes(st.latency),
            wait=stats.latency_lanes(st.wait),
            counters=counters, wall_s=wall, digest=h.hexdigest())
    finally:
        st.mgr.close()          # a crash may have replaced the manager
        if jdir is not None:
            shutil.rmtree(jdir, ignore_errors=True)
    if strict:
        result.raise_if_failed()
    return result


# ---------------------------------------------------------------------------
# the scenario catalog (docs/ARCHITECTURE.md "Chaos harness" documents how
# to add one: name -> run() kwargs; run_matrix sizes n_ops per mode)
# ---------------------------------------------------------------------------
_CTRL_ONLY = (("fail", 0.0), ("rebuild", 0.0), ("quorum_loss", 0.0),
              ("recover", 0.0), ("straggler", 0.0), ("heal", 0.0),
              ("drop_on", 0.0), ("drop_off", 0.0))

STRAGGLER_LATENCY = 8
_STRAGGLER = dict(
    backend="slots", n_replicas=3, transport="simnet",
    write_policy="quorum",
    trace=TraceConfig(n_ops=160, n_volumes=4, read_frac=0.75, seq_frac=0.3,
                      unaligned_frac=0.0, mean_burst=1),
    transport_opts=dict(latency=[1, 1, STRAGGLER_LATENCY], window=64),
    chaos=None)

SCENARIOS: Dict[str, Dict[str, Any]] = {
    # clean replay on the default transport: the oracle must hold with no
    # faults at all before chaos results mean anything
    "steady/local": dict(
        backend="slots", n_replicas=2, transport="local",
        trace=TraceConfig(n_ops=200, n_volumes=4, read_frac=0.4,
                          unaligned_frac=0.15),
        chaos=None),
    # the adversarial core: quorum writes over a lossy simulated network
    # with replica fails, quorum loss, rebuilds, link degradation and
    # mid-trace control ops
    "chaos/simnet": dict(
        backend="slots", n_replicas=3, transport="simnet",
        write_policy="quorum",
        trace=TraceConfig(n_ops=200, n_volumes=4, read_frac=0.4,
                          unaligned_frac=0.1),
        chaos=ChaosConfig(n_events=10),
        transport_opts=dict(latency=2, window=16, drop=0.05)),
    # write-behind: acked-at-post writes racing fails/rebuilds
    "chaos/async": dict(
        backend="slots", n_replicas=3, transport="simnet",
        write_policy="async",
        trace=TraceConfig(n_ops=160, n_volumes=4, read_frac=0.3),
        chaos=ChaosConfig(n_events=8),
        transport_opts=dict(latency=2, window=16)),
    # the in-program plane: snapshot/clone/discard chaos riding the ring's
    # in-band control path (replica chaos is host-dispatch-only)
    "control/ring": dict(
        backend="ring", n_shards=2, n_replicas=2,
        trace=TraceConfig(n_ops=160, n_volumes=4, read_frac=0.4,
                          unaligned_frac=0.1),
        chaos=ChaosConfig(n_events=8, weights=_CTRL_ONLY),
        verify_replicas=True),
    # the tail-latency pair: one straggler link, singleton bursts (per-op
    # wait ticks), rr vs latency-weighted reads — the P99/P999 gates
    "straggler/rr": dict(read_policy="rr", **_STRAGGLER),
    "straggler/latency": dict(read_policy="latency", **_STRAGGLER),
    # the serving shape (PR 8): KV-append traffic over the sharded pool —
    # write-heavy, sequential, zipf-hot volumes (prompt-prefix sharing),
    # block-aligned like the decode scatter; clone-boosted chaos (session
    # fork) plus all-shard replica fail/rebuild mid-stream — a replica
    # dying mid-decode must not corrupt any session's bytes. Link actions
    # are zeroed (stacked endpoints ride the device transport, no simnet).
    "serve/steady": dict(
        backend="sharded", n_shards=2, n_replicas=2,
        trace=TraceConfig(n_ops=160, n_volumes=6, read_frac=0.25,
                          seq_frac=0.9, unaligned_frac=0.0, zipf_a=1.2),
        chaos=ChaosConfig(n_events=8,
                          weights=(("clone", 3.0), ("straggler", 0.0),
                                   ("heal", 0.0), ("drop_on", 0.0),
                                   ("drop_off", 0.0))),
        verify_replicas=True),
    # computational storage (repro/compute): COMPUTE SQEs — rotating
    # through all five built-ins, including committed and mismatching
    # compare_and_write — mixed into ring traffic under snapshot/clone/
    # discard chaos, every result checked against the pure-Python mirror
    # over the oracle shadow at submission time
    "compute/steady": dict(
        backend="ring", n_shards=2, n_replicas=2,
        trace=TraceConfig(n_ops=160, n_volumes=4, read_frac=0.4,
                          unaligned_frac=0.1),
        chaos=ChaosConfig(n_events=8, weights=_CTRL_ONLY),
        compute_every=5, verify_replicas=True),
    # the durability plane (repro/durability): a write-ahead journal rides
    # the run and the engine is KILLED at fixed pump boundaries (every
    # second crash first tears a half-written record onto the WAL tail),
    # recovered by journal replay, and the trace keeps going — snapshot/
    # clone/discard chaos and mutating COMPUTE SQEs ride along so replay
    # exercises the id-asserting control path and OP_COMPUTE records too.
    # The end-of-trace sweep proves every recovered volume byte-identical
    # to the shadow oracle. Replica and link actions are zeroed: recovery
    # rebuilds an all-healthy plane, so mid-trace health chaos would just
    # skip nondeterministically relative to the crash points.
    "crash/journal": dict(
        backend="slots", n_replicas=2, transport="local",
        trace=TraceConfig(n_ops=160, n_volumes=4, read_frac=0.4,
                          unaligned_frac=0.15),
        chaos=ChaosConfig(n_events=6, crash_every=40,
                          weights=(("fail", 0.0), ("rebuild", 0.0),
                                   ("quorum_loss", 0.0), ("recover", 0.0),
                                   ("straggler", 0.0), ("heal", 0.0),
                                   ("drop_on", 0.0), ("drop_off", 0.0))),
        journal=True, compute_every=8),
}

# the replay-determinism gate re-runs this scenario and compares digests
DETERMINISM_SCENARIO = "chaos/simnet"


def run_scenario(name: str, *, trace_seed: int = 0, chaos_seed: int = 0,
                 n_ops: Optional[int] = None, **overrides) -> HarnessResult:
    """Run one catalog scenario; ``n_ops`` rescales its trace (smoke)."""
    kw = dict(SCENARIOS[name])
    kw.update(overrides)
    if n_ops is not None:
        from dataclasses import replace
        kw["trace"] = replace(kw["trace"], n_ops=n_ops)
    return run(trace_seed=trace_seed, chaos_seed=chaos_seed, **kw)


def run_matrix(*, smoke: bool = True, trace_seed: int = 0,
               chaos_seed: int = 0,
               scenarios: Optional[List[str]] = None) -> Dict[str, Any]:
    """Run the scenario matrix + the determinism replay — the BENCH
    ``trace`` key (``check_trace_gates`` gates it)."""
    names = scenarios or list(SCENARIOS)
    n_ops = 120 if smoke else None
    out: Dict[str, Any] = {}
    results: Dict[str, HarnessResult] = {}
    for name in names:
        res = run_scenario(name, trace_seed=trace_seed,
                           chaos_seed=chaos_seed, n_ops=n_ops)
        results[name] = res
        out[name] = res.to_dict()
    if DETERMINISM_SCENARIO in results:
        first = results[DETERMINISM_SCENARIO]
        again = run_scenario(DETERMINISM_SCENARIO, trace_seed=trace_seed,
                             chaos_seed=chaos_seed, n_ops=n_ops)
        out["determinism"] = {
            "scenario": DETERMINISM_SCENARIO,
            "digest_a": first.digest, "digest_b": again.digest,
            "ticks_match": first.completion_ticks == again.completion_ticks,
            "match": (first.digest == again.digest
                      and first.completion_ticks == again.completion_ticks),
        }
    return out


# straggler-scenario tail bounds, in controller wait ticks: the latency-
# weighted policy must keep P99 under half the straggler's link latency
# (it reads the fast links, ~1-2 ticks) and P999 inside 2x the straggler
# (a bounded worst case even while the ewma is still learning)
P99_BOUND = STRAGGLER_LATENCY / 2
P999_BOUND = 2 * STRAGGLER_LATENCY


def check_trace_gates(trace: Dict[str, Any]) -> List[str]:
    """The harness CI gates (ISSUE 6 acceptance): every scenario's oracle
    clean, the determinism replay digest-identical, and the straggler
    tail bounded — latency-weighted reads beat rr at P99 and stay under
    ``P99_BOUND``/``P999_BOUND`` wait ticks."""
    problems = []
    for name, doc in trace.items():
        if name == "determinism":
            continue
        if not doc.get("oracle_ok", False):
            problems.append(
                f"trace {name}: oracle violations {doc.get('failures')}")
    det = trace.get("determinism")
    if det is not None and not det["match"]:
        problems.append(
            f"trace determinism: {det['scenario']} replayed to a different "
            f"digest ({det['digest_a'][:12]} vs {det['digest_b'][:12]}, "
            f"ticks_match={det['ticks_match']})")
    rr = trace.get("straggler/rr")
    lat = trace.get("straggler/latency")
    if rr is not None and lat is not None:
        rr_p99 = rr["wait"]["read"]["p99"]
        lat_p99 = lat["wait"]["read"]["p99"]
        lat_p999 = lat["wait"]["read"]["p999"]
        if lat_p99 >= rr_p99:
            problems.append(
                f"trace straggler: latency-weighted read P99 ({lat_p99:g} "
                f"wait ticks) does not beat rr ({rr_p99:g})")
        if lat_p99 > P99_BOUND:
            problems.append(
                f"trace straggler: latency-weighted read P99 {lat_p99:g} "
                f"wait ticks > bound {P99_BOUND:g}")
        if lat_p999 > P999_BOUND:
            problems.append(
                f"trace straggler: latency-weighted read P999 {lat_p999:g} "
                f"wait ticks > bound {P999_BOUND:g}")
    return problems
