"""The shadow byte oracle: ground truth for every acked read and replica.

One ``bytearray`` per volume mirrors what the engine is REQUIRED to serve:
the ``VolumeManager`` contract makes per-volume submission order execution
order, so the oracle applies each write at *submission* time and captures
each read's expected bytes at submission time too — a read submitted
between two overlapping writes must observe exactly the first. Discards
zero their span (TRIM reads back as zeros); clones copy the source shadow
(``VolumeManager.clone`` flushes before forking, so the shadow at the
clone point is the exact CoW image).

Mismatches are collected as strings (not raised mid-run) so one corrupted
read doesn't hide the next hundred; ``OracleMismatch`` is what strict
callers (``run(strict=True)``, the default) raise at the end of the run
with every failure attached.
"""
from __future__ import annotations

from typing import Dict, List


class OracleMismatch(AssertionError):
    """A harness run observed bytes diverging from the shadow oracle."""


class ByteOracle:
    """Shadow bytearrays, one per volume id (module docstring)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.shadow: Dict[int, bytearray] = {}
        self.failures: List[str] = []
        self.checked_reads = 0

    def add_volume(self, vid: int) -> None:
        self.shadow[vid] = bytearray(self.capacity)

    def clone(self, src_vid: int, dst_vid: int) -> None:
        self.shadow[dst_vid] = bytearray(self.shadow[src_vid])

    def delete(self, vid: int) -> None:
        self.shadow.pop(vid, None)

    def write(self, vid: int, off: int, data: bytes) -> None:
        self.shadow[vid][off:off + len(data)] = data

    def discard(self, vid: int, off: int, nbytes: int) -> None:
        self.shadow[vid][off:off + nbytes] = bytes(nbytes)

    def expected(self, vid: int, off: int, nbytes: int) -> bytes:
        """The bytes a read of this span must return, as of NOW (call at
        submission time — that is the ordering point the API guarantees)."""
        return bytes(self.shadow[vid][off:off + nbytes])

    def check(self, got: bytes, expected: bytes, context: str) -> bool:
        """Record one comparison; returns True when it matched."""
        self.checked_reads += 1
        if got == expected:
            return True
        diff = next((i for i, (g, e) in enumerate(zip(got, expected))
                     if g != e), min(len(got), len(expected)))
        self.failures.append(
            f"{context}: first divergence at byte {diff} "
            f"(got {got[diff:diff + 8].hex()!r}, "
            f"expected {expected[diff:diff + 8].hex()!r}, "
            f"lengths {len(got)}/{len(expected)})")
        return False

    @property
    def ok(self) -> bool:
        return not self.failures

    def raise_if_failed(self) -> None:
        if self.failures:
            raise OracleMismatch(
                f"{len(self.failures)} oracle mismatch(es):\n  "
                + "\n  ".join(self.failures[:20]))
