"""Seeded fio-style trace generation: a replayable byte-addressed op stream.

A *trace* is the load half of a harness run: a fixed list of ``TraceOp``
records, fully determined by ``(trace_seed, TraceConfig, geometry)``, that
the runner replays against the public ``VolumeManager`` API. The knobs
mirror the fio axes the paper benchmarks with (§IV) plus the ones it
doesn't:

- **read fraction** (``read_frac``) — fio's ``rwmixread``,
- **seq/rand mix** (``seq_frac``) — each volume keeps a sequential cursor;
  with probability ``seq_frac`` an op continues it, otherwise it jumps to
  a zipf-hot random page,
- **zipf hotness** (``zipf_a``) — page *and* volume popularity follow a
  zipf law (rank weights ``1/rank^a``), with a per-volume page permutation
  so hot sets differ across volumes; ``zipf_a=0`` is uniform,
- **burst arrivals** (``mean_burst``) — ops arrive in geometric-length
  bursts; the runner submits a whole burst asynchronously and flushes at
  the burst boundary (``last_in_burst``), so queue depth varies the way
  open-loop arrival processes make it vary,
- **span sizes** (``max_span_blocks``, ``unaligned_frac``) — multi-block
  byte spans, a fraction of them deliberately NOT block-aligned so the
  in-API read-modify-write path stays under load.

Write payloads are a pure function of ``(trace_seed, op index)``
(``payload_bytes``) so the oracle never stores them twice and a replay is
byte-identical by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class TraceConfig:
    """The fio-style workload axes (module docstring). Geometry — block
    size, page size, page count — is the runner's, passed to
    ``generate_trace`` separately so one config drives many geometries."""

    n_ops: int = 200
    n_volumes: int = 4
    read_frac: float = 0.4
    seq_frac: float = 0.3
    unaligned_frac: float = 0.1
    zipf_a: float = 1.1
    mean_burst: int = 8
    max_span_blocks: int = 4


@dataclass(frozen=True)
class TraceOp:
    """One replayable op. ``vol`` is a trace-local volume index (the runner
    maps it to the ``Volume`` handle it created); ``off``/``nbytes`` are
    byte-addressed; ``last_in_burst`` marks the flush boundary."""

    index: int
    kind: str          # "write" | "read"
    vol: int
    off: int
    nbytes: int
    last_in_burst: bool


def zipf_weights(n: int, a: float) -> np.ndarray:
    """Normalized zipf rank weights ``1/rank^a`` (uniform at ``a=0``)."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return w / w.sum()


def payload_bytes(trace_seed: int, index: int, nbytes: int) -> bytes:
    """The write payload of op ``index`` — a cheap deterministic pattern
    (mod a prime so every byte stays 0..250, distinguishable from the
    zero-fill holes/discards produce)."""
    base = (trace_seed * 7919 + index * 131) % 251
    return bytes((base + i * 7) % 251 for i in range(nbytes))


def generate_trace(trace_seed: int, cfg: TraceConfig, *, block_bytes: int,
                   page_blocks: int, n_pages: int) -> List[TraceOp]:
    """Generate the replayable op stream for one harness run.

    Deterministic in ``(trace_seed, cfg, geometry)``: the same inputs give
    the same list, which is what makes ``(trace_seed, chaos_seed)`` a full
    run identifier (the replay-determinism gate relies on it)."""
    rng = np.random.default_rng(trace_seed)
    page_bytes = block_bytes * page_blocks
    capacity = n_pages * page_bytes
    vol_w = zipf_weights(cfg.n_volumes, cfg.zipf_a)
    page_w = zipf_weights(n_pages, cfg.zipf_a)
    # per-volume page permutation: volume v's hottest page is perms[v][0]
    perms = [rng.permutation(n_pages) for _ in range(cfg.n_volumes)]
    cursors = [0] * cfg.n_volumes          # sequential byte cursors
    ops: List[TraceOp] = []
    burst_left = int(rng.geometric(1.0 / max(cfg.mean_burst, 1)))
    for i in range(cfg.n_ops):
        vol = int(rng.choice(cfg.n_volumes, p=vol_w))
        kind = "read" if rng.random() < cfg.read_frac else "write"
        nblocks = int(rng.integers(1, cfg.max_span_blocks + 1))
        nbytes = nblocks * block_bytes
        if rng.random() < cfg.seq_frac:
            off = cursors[vol]
        else:
            page = int(perms[vol][int(rng.choice(n_pages, p=page_w))])
            off = page * page_bytes + int(
                rng.integers(0, page_blocks)) * block_bytes
        if rng.random() < cfg.unaligned_frac:
            off += int(rng.integers(1, block_bytes))
            nbytes = max(1, nbytes - int(rng.integers(1, block_bytes)))
        if off + nbytes > capacity:        # wrap instead of clipping spans
            off = 0
        cursors[vol] = (off + nbytes) % max(capacity - nbytes, 1)
        burst_left -= 1
        last = burst_left <= 0 or i == cfg.n_ops - 1
        if last:
            burst_left = int(rng.geometric(1.0 / max(cfg.mean_burst, 1)))
        ops.append(TraceOp(index=i, kind=kind, vol=vol, off=off,
                           nbytes=nbytes, last_in_burst=last))
    return ops
