"""Ambient distribution context for model code.

Model functions are mesh-agnostic; when a launcher wants to pin the residual
stream's sharding (killing GSPMD's speculative resharding all-reduces,
§Perf iteration C2) it installs a NamedSharding here around tracing.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

import jax

_ACT_SHARDING: ContextVar = ContextVar("activation_sharding", default=None)


def get_activation_sharding():
    return _ACT_SHARDING.get()


@contextlib.contextmanager
def activation_sharding(ns):
    tok = _ACT_SHARDING.set(ns)
    try:
        yield
    finally:
        _ACT_SHARDING.reset(tok)


def constrain(x):
    ns = get_activation_sharding()
    if ns is None:
        return x
    spec = tuple(ns.spec) if hasattr(ns, "spec") else ()
    full = spec + (None,) * (x.ndim - len(spec))
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ns.mesh, P(*full[:x.ndim])))
