"""Mesh collectives: distributed paged-DBS decode, hierarchical reductions,
gradient compression.

``make_sharded_paged_decode`` is the distributed form of the DBS read path:
a volume's pages are striped round-robin across the "model" axis (and across
all axes when the batch itself cannot shard), every shard gathers only its
local extents, computes a split-KV partial and the stripes merge with the
FlashDecoding log-sum-exp rule — psum/pmax over the stripe axes. This is the
Longhorn controller's scatter/gather across replicas, as a collective.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn


def make_sharded_paged_decode(mesh: Mesh, batch_shardable: bool,
                              stripe_slice: bool = True):
    """Returns fn(q, pool_k, pool_v, block_table, q_pos, **kw) -> (B,1,H,dv).

    Layouts (global): q (B,1,H,hd) batch-sharded (or replicated), pools
    (E, page, KV, hd) extent-striped over model (and batch axes when the
    batch is unshardable), block_table (B,P) local extent ids, q_pos (B,1).
    """
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    stripe = ("model",) if batch_shardable else baxes + ("model",)
    stride = math.prod(mesh.shape[a] for a in stripe)
    bspec = P(baxes) if batch_shardable else P()

    def local(q, k_new, v_new, pool_k, pool_v, bt, q_pos, *, window,
              logit_cap, scale):
        from repro.models.blocks import paged_write_local
        rank = jnp.zeros((), jnp.int32)
        for a in stripe:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        pool_k, pool_v = paged_write_local(pool_k, pool_v, bt, q_pos[:, 0],
                                           k_new, v_new, stride, rank)
        o, m, l = attn.paged_decode_attention(
            q, pool_k, pool_v, bt, q_pos, window=window, logit_cap=logit_cap,
            scale=scale, page_owner_stride=stride, owner_rank=rank,
            stripe_slice=stripe_slice)
        # FlashDecoding merge across the stripe axes
        m_star = jax.lax.pmax(m, stripe)
        corr = jnp.exp(m - m_star)
        l_star = jax.lax.psum(l * corr, stripe)
        o_star = jax.lax.psum(o * corr[..., None], stripe)
        out = o_star / jnp.maximum(l_star[..., None], 1e-30)
        b, kv, g, sq, dv = out.shape
        out = out.reshape(b, kv * g, sq, dv).swapaxes(1, 2).astype(q.dtype)
        return out, pool_k, pool_v

    pool_spec = P(baxes + ("model",))   # extent dim striped over all shards

    def fn(q, k_new, v_new, pool_k, pool_v, block_table, q_pos, *, window=0,
           logit_cap=0.0, scale=None):
        mapped = shard_map(
            partial(local, window=window, logit_cap=logit_cap, scale=scale),
            mesh=mesh,
            in_specs=(bspec, bspec, bspec, pool_spec, pool_spec, bspec, bspec),
            out_specs=(bspec, pool_spec, pool_spec),
            check_vma=False)
        return mapped(q, k_new, v_new, pool_k, pool_v, block_table, q_pos)

    return fn


# ---------------------------------------------------------------------------
# hierarchical gradient reduction (pod-aware) + int8 compression
# ---------------------------------------------------------------------------
def hierarchical_psum(x: jnp.ndarray, inner: str = "data", outer: str = "pod"):
    """reduce inside the pod first (fast ICI), then across pods (DCI)."""
    x = jax.lax.psum(x, inner)
    try:
        return jax.lax.psum(x, outer)
    except NameError:
        return x


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization for cross-pod all-reduce."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_mean(grads, axis: str = "pod",
                              error_feedback=None):
    """int8 all-reduce across pods with error feedback (EF-SGD style).

    grads: pytree already reduced inside the pod. Returns (mean_grads, new_ef).
    """
    n = jax.lax.axis_size(axis)

    def one(g, ef):
        g32 = g.astype(jnp.float32) + (0.0 if ef is None else ef)
        q, s = compress_int8(g32)
        approx = decompress_int8(q, s)
        new_ef = g32 - approx
        total = jax.lax.psum(approx, axis)
        return (total / n).astype(g.dtype), new_ef

    if error_feedback is None:
        error_feedback = jax.tree.map(lambda _: None, grads,
                                      is_leaf=lambda x: x is None)
    out = jax.tree.map(one, grads, error_feedback)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return mean, ef
