"""Sharding planner: DP/TP/FSDP/EP/SP assignment with divisibility fallbacks.

The planner maps every parameter / activation / cache leaf to a
PartitionSpec over the production mesh axes ("pod", "data", "model"). A dim
is sharded on an axis group only when evenly divisible; otherwise the next
candidate spec is tried, ending at full replication — this is what lets one
rule set cover all ten assigned architectures (gemma2's 8 heads, granite's
49155 vocab, granite-moe's 40 experts, rwkv's 40 heads, ... all fall back
gracefully; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ExecutionPlan, ShapeSpec

Spec = P


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def fits(mesh: Mesh, shape: Sequence[int], spec: P) -> bool:
    for dim, entry in zip(shape, tuple(spec)):
        n = _axis_size(mesh, entry)
        if n > 1 and (dim % n):
            return False
    return True


def pick(mesh: Mesh, shape: Sequence[int], candidates: List[P]) -> P:
    """First candidate whose sharded dims divide evenly; else replicate."""
    for c in candidates:
        c_full = P(*(tuple(c) + (None,) * (len(shape) - len(tuple(c)))))
        if fits(mesh, shape, c_full):
            return c_full
    return P(*([None] * len(shape)))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


class Planner:
    def __init__(self, mesh: Mesh, cfg: ArchConfig, plan: ExecutionPlan):
        self.mesh = mesh
        self.cfg = cfg
        self.plan = plan
        self.batch = batch_axes(mesh)           # ("pod","data") | ("data",)
        self.fsdp = "data" if (plan.fsdp and "data" in mesh.shape) else None

    # -- generic leaf rules ---------------------------------------------------
    def param_spec(self, path: str, shape: Sequence[int]) -> P:
        """Spec for a parameter leaf. ``path`` is the flattened key path;
        stacked segment leaves have a leading layer dim (never sharded)."""
        m, f = "model", self.fsdp
        mesh = self.mesh
        lead: Tuple = ()
        if re.search(r"segments|mtp/block", path):
            if re.search(r"segments", path):
                lead, shape = (None,), shape[1:]      # (count, ...) stack

        def done(spec_tail: P) -> P:
            return pick(mesh, (1,) * len(lead) + tuple(shape),
                        [P(*(lead + tuple(spec_tail)))])

        def cands(cands_tail: List[Tuple]) -> P:
            full = [P(*(lead + t)) for t in cands_tail]
            return pick(mesh, (1,) * len(lead) + tuple(shape), full)

        # ---- embeddings / head ---------------------------------------------
        if "embed/tokens" in path or "embed/lm_head" in path:
            if len(shape) == 3:   # codebooks (K, V, D) / (K, D, V)
                return cands([(None, m, f), (None, f, m), (None, None, m)])
            return cands([(m, f), (f, m), (None, m)])
        # ---- norms / scalars / small vectors --------------------------------
        # (shape has already been stripped of the stacked-layer lead dim)
        if len(shape) <= 1 or re.search(
                r"ln|norm|bias|mu|u$|d_skip|dt_bias|a_log|first", path):
            return cands([tuple([None] * len(shape))])
        # ---- MoE experts -----------------------------------------------------
        if re.search(r"mlp/(wi|wg|wo)", path) and len(shape) == 3 and \
                self.cfg.moe is not None:
            # (E, D, F) / (E, F, D): expert-parallel if E divides, else TP on F
            if "wo" in path:
                return cands([(m, f, None), (None, m, f), (None, m, None)])
            return cands([(m, f, None), (None, f, m), (None, None, m)])
        if "router" in path:
            return cands([(f, None)])
        # ---- attention projections ------------------------------------------
        if re.search(r"/(q|k|v|q_b|kv_b|w_r|w_k|w_v|w_g|c_r|c_k|in_proj|w_bc|w_dt1)$", path):
            return cands([(f, m), (None, m)])             # column parallel
        if re.search(r"/(o|out_proj|w_o|c_v|w_dt2)$", path):
            return cands([(m, f), (m, None)])             # row parallel
        if re.search(r"/(q_a|kv_a)$", path):
            return cands([(f, m), (None, m)])
        if re.search(r"/(wi|wg)$", path):
            return cands([(f, m), (None, m)])
        if re.search(r"/wo$", path):
            return cands([(m, f), (m, None)])
        if re.search(r"conv|lora|proj$", path):
            return cands([tuple([None] * (len(shape) - len(lead)))])
        # default: replicate
        return cands([tuple([None] * (len(shape) - len(lead)))])

    # -- trees ----------------------------------------------------------------
    def tree_specs(self, tree) -> Any:
        def leaf(path, x):
            p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
            return self.param_spec(p, x.shape)
        return jax.tree_util.tree_map_with_path(leaf, tree)

    def shardings(self, tree) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.tree_specs(tree))

    def opt_specs(self, param_specs, param_shapes, optimizer: str):
        if optimizer == "adamw":
            return {"m": param_specs, "v": param_specs, "count": P()}
        # adafactor: vr drops the last dim, vc the second-to-last
        def slot(spec, shp):
            spec_t = tuple(spec)
            if len(shp.shape) >= 2 and shp.shape[-1] > 1 and shp.shape[-2] > 1:
                return {"vr": P(*spec_t[:-1]),
                        "vc": P(*(spec_t[:-2] + spec_t[-1:]))}
            return {"v": P(*spec_t)}
        slots = jax.tree.map(slot, param_specs, param_shapes,
                             is_leaf=lambda x: isinstance(x, P))
        return {"slots": slots, "count": P()}

    # -- activations / batch ---------------------------------------------------
    def data_spec(self, shape: Sequence[int]) -> P:
        """Batch tensors: shard dim0 over ("pod","data") when divisible."""
        return pick(self.mesh, shape,
                    [P(self.batch), P(self.batch[-1:]), P()])

    def cache_spec(self, key: str, shape: Sequence[int]) -> P:
        b = self.batch
        mesh = self.mesh
        if "pool" in key:
            # DBS pool: extents striped over (batch-axes x model) — the
            # distributed extent map (SP for the KV state).
            return pick(mesh, shape, [P(b + ("model",)), P("model"), P()])
        if "block_table" in key:
            return pick(mesh, shape, [P(b), P()])
        if key in ("k", "v"):      # dense cache: (B, S, KV, hd) — split-KV SP
            return pick(mesh, shape,
                        [P(b, "model"), P(b), P()])
        if "ring" in key:
            return pick(mesh, shape, [P(b), P()])
        if "wkv" in key or "mamba" in key or "shift" in key or "ssm" in key:
            return pick(mesh, shape, [P(b), P()])
        return pick(mesh, shape, [P(b), P()])

    def cache_specs(self, cache_tree) -> Any:
        def leaf(path, x):
            keys = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
            key = keys[-1] if keys else ""
            if "mamba" in keys:
                key = "mamba"
            return self.cache_spec(key, x.shape)
        return jax.tree_util.tree_map_with_path(leaf, cache_tree)


# ---------------------------------------------------------------------------
# page ownership helpers (distributed DBS stripes)
# ---------------------------------------------------------------------------
def pool_stride(mesh: Mesh, batch_shardable: bool) -> int:
    """Number of shards the extent dim of pools is striped over."""
    n = mesh.shape["model"]
    if not batch_shardable:
        for a in ("pod", "data"):
            if a in mesh.shape:
                n *= mesh.shape[a]
    return n
