"""Incremental snapshot export on the ``page_rev`` watermarks (pillar 3).

A ``SnapshotExport`` is one versioned on-disk file of append-only
*sections*. Each ``export(mgr)`` call ships

- the full (small) metadata: the replica ``DBSState`` leaves, the volume
  table, the ``page_rev`` watermark array and the manager's open volume
  ids — a section is self-describing for control state, and
- ONLY the delta of the (large) payload pool: the extents backing pages
  whose ``page_rev`` is newer than the *previous section's* watermark row —
  exactly the selection the PR-5 streamed delta rebuild computes
  (``transport._delta_extents``: ``np.unique`` of
  ``table[(page_rev > target) & (table >= 0)]``).

Content an extent carried at an older watermark was shipped by the section
that covered that watermark, so replaying the sections in order (later
rows win) reconstructs every live extent; freed-but-unshipped extents
restore as zeros, which is what the hole-masked read path serves anyway.

**Commit ordering** mirrors checkpoint/store.py: section bytes are
appended and flushed FIRST, then the fixed-size file header (which holds
the committed section count) is rewritten — a torn append leaves the
header pointing at the old, consistent prefix.

``ExportCounters`` mirrors the transport counters (``ReplicaTransport``'s
``sent`` / ``pages_moved``) so tests assert "this export moved exactly the
post-watermark extents" the same way the rebuild tests assert streamed
page counts.

``stream_store`` is the checkpoint-refactor surface: rebuild a lost
``CheckpointStore`` replica by streaming the donor's committed bytes
block-by-block through both stores' public read/write paths — counted like
transport traffic — instead of ``shutil.copyfile``-ing the device file
(checkpoint/replicated.py).
"""
from __future__ import annotations

import collections
import json
import os
import struct
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.compute.functions import py_blocksum

_FILE_MAGIC = b"DBSXPRT1"
_HEADER_BYTES = 512              # fixed header block, rewritten last
_SEC_MAGIC = 0x54435853          # "SXCT"
_FRAME = struct.Struct("<II")    # magic, body_len
_SUM = struct.Struct("<i")


class ExportCounters:
    """Transport-style accounting for the export plane: one ``sent``
    counter per verb plus the extents/bytes actually moved."""

    def __init__(self):
        self.sent = collections.Counter()    # EXPORT / INSTALL / STREAM
        self.extents_moved = 0               # delta extents shipped
        self.pages_moved = 0                 # == extents_moved (one page per
                                             # extent — transport naming)
        self.bytes_moved = 0

    def account(self, verb: str, extents: int, nbytes: int) -> None:
        self.sent[verb] += 1
        self.extents_moved += extents
        self.pages_moved += extents
        self.bytes_moved += nbytes

    def to_dict(self) -> Dict[str, Any]:
        return {"sent": dict(self.sent), "extents_moved": self.extents_moved,
                "pages_moved": self.pages_moved,
                "bytes_moved": self.bytes_moved}


def _pack_section(scalars: Dict[str, Any],
                  arrays: Dict[str, np.ndarray]) -> bytes:
    """One checksummed section frame: json meta + concatenated raw arrays."""
    metas, blobs, off = [], [], 0
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        metas.append({"name": name, "dtype": str(arr.dtype),
                      "shape": list(arr.shape), "offset": off,
                      "nbytes": len(raw)})
        blobs.append(raw)
        off += len(raw)
    head = json.dumps({"scalars": scalars, "arrays": metas}).encode()
    body = struct.pack("<I", len(head)) + head + b"".join(blobs)
    return _FRAME.pack(_SEC_MAGIC, len(body)) + body + _SUM.pack(
        py_blocksum(body))


def _unpack_section(body: bytes) -> Tuple[Dict[str, Any],
                                          Dict[str, np.ndarray]]:
    (hlen, ) = struct.unpack_from("<I", body, 0)
    meta = json.loads(body[4:4 + hlen])
    base = 4 + hlen
    arrays = {}
    for ent in meta["arrays"]:
        off = base + ent["offset"]
        arr = np.frombuffer(body, np.dtype(ent["dtype"]),
                            count=int(np.prod(ent["shape"], dtype=np.int64))
                            if ent["shape"] else 1,
                            offset=off)
        arrays[ent["name"]] = arr.reshape(ent["shape"]).copy()
    return meta["scalars"], arrays


def _flat_group(mgr):
    """The flat ``ReplicaGroup`` behind a slots/loop/fused manager — the
    backends whose device state installs wholesale. Raises on the rest
    (host/sharded/ring recover via full-journal replay instead)."""
    storage = mgr.engine.backend
    if (storage is None or not hasattr(storage, "device_page_revs")
            or hasattr(storage, "states")):       # sharded: stacked axis
        raise ValueError(
            f"backend {mgr.backend_name!r} has no installable flat replica "
            "plane; recovery falls back to full-journal replay")
    if getattr(storage, "null_storage", False):
        raise ValueError("null_storage holds no pool to export")
    return storage


class SnapshotExport:
    """One versioned incremental-export file (module docstring)."""

    def __init__(self, path: str):
        self.path = os.fspath(path)
        self.counters = ExportCounters()
        self._sections: List[Tuple[Dict[str, Any],
                                   Dict[str, np.ndarray]]] = []
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            self._load()

    # ------------------------------------------------------------ file I/O
    def _load(self) -> None:
        with open(self.path, "rb") as f:
            blob = f.read()
        if blob[:len(_FILE_MAGIC)] != _FILE_MAGIC:
            raise IOError(f"{self.path}: not an export file")
        hdr = json.loads(
            blob[len(_FILE_MAGIC):_HEADER_BYTES].split(b"\x00")[0])
        off = _HEADER_BYTES
        self._sections = []
        for _ in range(hdr["sections"]):          # only the committed count
            magic, blen = _FRAME.unpack_from(blob, off)
            end = off + _FRAME.size + blen + _SUM.size
            if magic != _SEC_MAGIC or end > len(blob):
                raise IOError(f"{self.path}: committed section torn")
            body = blob[off + _FRAME.size:end - _SUM.size]
            (want, ) = _SUM.unpack_from(blob, end - _SUM.size)
            if py_blocksum(body) != want:
                raise IOError(f"{self.path}: committed section checksum "
                              "mismatch")
            self._sections.append(_unpack_section(body))
            off = end

    def _commit(self, frame: bytes) -> None:
        """Append the section, flush, THEN rewrite the header — the torn-
        append-safe ordering (a crash between the two keeps the old count)."""
        new = not os.path.exists(self.path) or os.path.getsize(self.path) == 0
        mode = "r+b" if not new else "wb"
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, mode) as f:
            if new:
                f.write(_FILE_MAGIC.ljust(_HEADER_BYTES, b"\x00"))
            f.seek(0, os.SEEK_END)
            f.write(frame)
            f.flush()
            os.fsync(f.fileno())
            hdr = json.dumps({"sections": len(self._sections)}).encode()
            f.seek(0)
            f.write((_FILE_MAGIC + hdr).ljust(_HEADER_BYTES, b"\x00"))
            f.flush()
            os.fsync(f.fileno())

    # ------------------------------------------------------------ export
    @property
    def sections(self) -> int:
        return len(self._sections)

    @property
    def journal_seq(self) -> int:
        """Journal position the newest section covers (0 = none): recovery
        replays only records sealed after this."""
        return (int(self._sections[-1][0]["journal_seq"])
                if self._sections else 0)

    def _last_watermark(self) -> Optional[np.ndarray]:
        return (self._sections[-1][1]["page_rev"]
                if self._sections else None)

    def export(self, mgr, *, journal=None) -> Dict[str, Any]:
        """Ship one incremental section from a flat-replica-plane manager.
        Flushes first (the section covers every acked op), selects the
        post-watermark extents, appends, commits. Returns the section
        summary (``extents_moved`` is THE exactness assertion handle)."""
        mgr.flush()
        storage = _flat_group(mgr)
        state = storage.replicas[storage.healthy_indices()[0]].state
        pool, page_rev = self._device_pool_view(mgr, storage)
        table = np.asarray(jax.device_get(state.table))
        leaves, _ = jax.tree_util.tree_flatten(state)
        leaves = [np.asarray(x) for x in jax.device_get(leaves)]
        last = self._last_watermark()
        target = (np.zeros_like(page_rev) if last is None else last)
        newer = (page_rev > target) & (table >= 0)
        delta = np.unique(table[newer]).astype(np.int32)
        rows = pool[delta] if delta.size else pool[:0]
        scalars = {
            "journal_seq": int(journal.seq) if journal is not None else 0,
            "version": len(self._sections) + 1,
            "vids": sorted(int(v) for v in mgr.volumes),
            "pool_rows": int(pool.shape[0]),
        }
        arrays = {"page_rev": page_rev, "delta_extents": delta,
                  "delta_rows": rows}
        for i, leaf in enumerate(leaves):
            arrays[f"state_{i}"] = leaf
        frame = _pack_section(scalars, arrays)
        self._sections.append((scalars, arrays))
        self._commit(frame)
        self.counters.account("EXPORT", int(delta.size), rows.nbytes)
        return {"version": scalars["version"],
                "extents_moved": int(delta.size),
                "bytes_moved": int(rows.nbytes),
                "journal_seq": scalars["journal_seq"]}

    @staticmethod
    def _device_pool_view(mgr, storage) -> Tuple[np.ndarray, np.ndarray]:
        """Replica 0's pool + page_rev as host arrays. On a tiered fused
        backend the spilled rows are zeros ON DEVICE — their bytes live in
        the tier's host store, so the view reads through the tier."""
        i0 = storage.healthy_indices()[0]
        pool = np.asarray(jax.device_get(storage.replicas[i0].pool))
        page_rev = np.asarray(jax.device_get(storage.replicas[i0].page_rev))
        tier = getattr(mgr.engine.impl, "tier", None)
        if tier is not None:
            pool = tier.read_through(pool)
        return pool, page_rev

    # ------------------------------------------------------------ install
    def install(self, mgr) -> Dict[str, Any]:
        """Reconstruct device state on a FRESH manager of the same geometry:
        metadata from the newest section, pool rows replayed section-by-
        section (later rows win), broadcast to every healthy replica."""
        if not self._sections:
            raise ValueError(f"{self.path}: no committed section to install")
        storage = _flat_group(mgr)
        import jax.numpy as jnp
        idx = storage.healthy_indices()
        cur = storage.replicas[idx[0]].state
        cur_leaves, treedef = jax.tree_util.tree_flatten(cur)
        scalars, arrays = self._sections[-1]
        leaves_np = []
        for i, like in enumerate(cur_leaves):
            got = arrays[f"state_{i}"]
            like_np = np.asarray(like)
            # compare sizes, not shapes: scalar leaves drift between () and
            # (1,) depending on whether the state passed through a jitted
            # step before export
            if got.size != like_np.size:
                raise ValueError(
                    f"export geometry mismatch: state leaf {i} is "
                    f"{tuple(got.shape)} on disk, {tuple(like_np.shape)} "
                    "here")
            leaves_np.append(got.astype(like_np.dtype).reshape(like_np.shape))
        rows_total = int(scalars["pool_rows"])
        pool = np.zeros((rows_total,)
                        + tuple(storage.replicas[idx[0]].pool.shape[1:]),
                        np.float32)
        moved = 0
        for sc, ar in self._sections:
            d, r = ar["delta_extents"], ar["delta_rows"]
            if d.size:
                pool[d] = r
                moved += int(d.size)
        # one DISTINCT device buffer per replica: the fused step donates
        # every replica's state/pool, so replicas must not alias
        storage.set_device_state(
            tuple(jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(l) for l in leaves_np]) for _ in idx),
            tuple(jnp.asarray(pool) for _ in idx))
        storage.set_device_page_revs(
            tuple(jnp.asarray(arrays["page_rev"]) for _ in idx))
        tier = getattr(mgr.engine.impl, "tier", None)
        if tier is not None:
            tier.reset_resident()        # everything device-resident again
        from repro.core.blockdev import Volume
        for vid in scalars["vids"]:
            mgr.volumes.setdefault(int(vid), Volume(mgr, int(vid)))
        self.counters.account("INSTALL", moved, pool.nbytes)
        return {"version": int(scalars["version"]),
                "journal_seq": int(scalars["journal_seq"]),
                "extents_replayed": moved, "vids": list(scalars["vids"])}


# ---------------------------------------------------------------------------
# checkpoint rebuild rides this surface (checkpoint/replicated.py)
# ---------------------------------------------------------------------------
def stream_store(donor, target, *, chunk_blocks: int = 64,
                 counters: Optional[ExportCounters] = None
                 ) -> Dict[str, Any]:
    """Rebuild a checkpoint replica by STREAMING the donor's committed
    volumes through both stores' public block paths — the export-plane
    analogue of the engine's chunked FETCH_PAGES/PUSH_PAGES rebuild — with
    transport-style accounting, replacing the old ``shutil.copyfile``.

    For every donor volume, the valid manifest (header + digest walk,
    ``CheckpointStore._read_valid``) picks the committed version, its data
    blocks are read in ``chunk_blocks`` chunks and written into the target
    store, and the target freezes a snapshot — the same commit ordering
    ``save`` uses, so a crash mid-stream leaves the target's head torn but
    never a frozen version."""
    from repro.checkpoint.store import BS
    counters = counters or ExportCounters()
    streamed: Dict[str, int] = {}
    for name in list(donor.dev.volumes):
        if name.startswith("__restore_"):
            continue
        try:
            blob = donor._read_valid(name)
        except IOError:
            continue
        man = blob["manifest"]
        data_end = (1 + blob["manifest_blocks"]) * BS + man["total"]
        total_blocks = data_end // BS
        if name not in target.dev.volumes:
            target.dev.create_volume(name)
        moved = 0
        for b0 in range(0, total_blocks, chunk_blocks):
            nb = min(chunk_blocks, total_blocks - b0)
            raw = donor.dev.read(blob["volume"], b0 * BS, nb * BS)
            target.dev.write(name, b0 * BS, raw)
            moved += nb
            counters.account("STREAM", nb, nb * BS)
        target.dev.snapshot(name)                 # version committed
        if blob["volume"] != name:                # _read_valid's temp clone
            donor.dev.delete_volume(blob["volume"])
        streamed[name] = moved
    return {"volumes": streamed, "counters": counters.to_dict()}
