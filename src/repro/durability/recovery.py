"""Crash recovery: replay the journal tail on top of the last export.

``recover(journal, ...)`` rebuilds a ``VolumeManager`` after a crash:

1. construct a FRESH manager with the same geometry (journaling detached —
   replay must not re-journal itself),
2. if an export file is given and the backend has an installable flat
   replica plane (slots/loop/fused), install its newest committed section
   — tables, extent pools, ``page_rev`` watermarks, snapshot chains and
   the open volume handles — and remember the journal position it covers;
   backends without wholesale device-state install (host/sharded/ring) or
   a geometry-mismatched export fall back to FULL journal replay,
3. replay every sealed record after that position **through the same
   public submission path the original ops took**: ``MSG_WRITE`` records
   apply their post-RMW block lanes directly (the manager's overlapping-
   block hazard fence re-serializes exactly the spans the original run
   fenced), control records re-execute and ASSERT the engine hands back
   the recorded volume/snapshot ids (allocation is deterministic in
   control order), mutating ``OP_COMPUTE`` records re-run in place,
4. flush and reattach the journal (truncating any torn tail) so the
   recovered manager keeps appending to the same file.

Byte-identity, not extent-identity: replicas re-allocate extents in replay
order, so the recovered *tables* may differ from the crashed run's while
every volume's **bytes** are identical — which is the contract the shadow
oracle checks (tests/test_durability*.py run this at every pump boundary
on host/fused/sharded/ring).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import numpy as np

from repro.core.transport import (MSG_CLONE, MSG_CREATE, MSG_DELETE,
                                  MSG_SNAPSHOT, MSG_UNMAP, MSG_WRITE)
from repro.durability.journal import (OP_COMPUTE, Journal, JournalView,
                                      read_journal)


class RecoveryError(RuntimeError):
    """Replay diverged from the journal (id mismatch / undecodable op)."""


def _replay_control(mgr, msg) -> None:
    meta0 = int(msg.meta[0]) if msg.meta else -1
    if msg.op == MSG_CREATE:
        vid = mgr.create().vid
        if vid != meta0:
            raise RecoveryError(
                f"create replayed to volume {vid}, journal says {meta0}")
    elif msg.op == MSG_SNAPSHOT:
        sid = mgr.snapshot(int(msg.volume))
        got = -1 if sid is None else int(sid)
        if got != meta0:
            raise RecoveryError(
                f"snapshot(vol {msg.volume}) replayed to {got}, journal "
                f"says {meta0}")
    elif msg.op == MSG_CLONE:
        child = mgr.clone(int(msg.volume))
        got = -1 if child is None else child.vid
        if got != meta0:
            raise RecoveryError(
                f"clone(vol {msg.volume}) replayed to {got}, journal "
                f"says {meta0}")
    elif msg.op == MSG_DELETE:
        mgr.delete(int(msg.volume))
    elif msg.op == MSG_UNMAP:
        mgr._unmap_pages(int(msg.volume), [int(p) for p in msg.pages])
    else:
        raise RecoveryError(f"journal holds unknown opcode {msg.op}")


def _replay_compute(mgr, msg) -> None:
    fn = bytes(msg.extents).decode()
    arg = int(msg.meta[0])
    is_range = bool(msg.meta[1])
    page = int(msg.pages[0])
    cnt_or_block = int(msg.blocks[0])
    if is_range:
        off = page * mgr.page_bytes
        nbytes = cnt_or_block * mgr.page_bytes
    else:
        off = (page * mgr.page_blocks + cnt_or_block) * mgr.block_bytes
        nbytes = mgr.block_bytes
    data = bytes(msg.payload) if msg.payload else None
    mgr.compute(int(msg.volume), fn, off, nbytes, arg=arg, data=data)


def replay(mgr, view: JournalView, *, after_seq: int = 0) -> int:
    """Apply every sealed record with ``seq > after_seq`` to ``mgr``;
    returns the record count applied. ``mgr`` must have no journal attached
    (replay would re-log itself)."""
    if mgr._journal is not None:
        raise ValueError("detach the journal before replaying into a "
                         "manager (recovery would re-journal the replay)")
    applied = 0
    for seq, msg in view.records:
        if seq <= after_seq:
            continue
        if msg.op == MSG_WRITE:
            mgr._replay_write(int(msg.volume), np.asarray(msg.pages),
                              np.asarray(msg.blocks),
                              np.asarray(msg.payload, np.float32))
        elif msg.op == OP_COMPUTE:
            _replay_compute(mgr, msg)
        else:
            _replay_control(mgr, msg)
        applied += 1
    mgr.flush()
    return applied


def recover(journal, *, export=None, manager=None, reattach: bool = True,
            **manager_kwargs) -> Any:
    """Rebuild a ``VolumeManager`` from its journal (module docstring).

    ``journal``: the journal path (or an open ``Journal`` — its path is
    read). ``export``: optional export path / ``SnapshotExport`` to install
    first. ``manager``: a pre-built fresh manager to replay into; otherwise
    one is constructed as ``VolumeManager(**manager_kwargs)``. With
    ``reattach`` (default) the recovered manager continues journaling to
    the same file — torn tail truncated, sequence numbers resumed.

    The recovery summary is left on the manager as ``.recovery_info``."""
    from repro.core.blockdev import VolumeManager
    path = journal.path if isinstance(journal, Journal) else os.fspath(
        journal)
    mgr = manager
    if mgr is None:
        manager_kwargs.pop("journal", None)
        mgr = VolumeManager(**manager_kwargs)
    after_seq = 0
    installed: Optional[Dict[str, Any]] = None
    if export is not None:
        from repro.durability.export import SnapshotExport
        exp = (export if isinstance(export, SnapshotExport)
               else SnapshotExport(export))
        if exp.sections:
            try:
                installed = exp.install(mgr)
                after_seq = installed["journal_seq"]
            except ValueError:
                installed = None         # full-replay fallback
                after_seq = 0
    view = read_journal(path)
    applied = replay(mgr, view, after_seq=after_seq)
    if reattach:
        j = journal if isinstance(journal, Journal) else Journal(path)
        mgr.attach_journal(j)
    mgr.recovery_info = {
        "replayed": applied, "after_seq": after_seq,
        "sealed_records": len(view.records), "torn_tail": view.torn,
        "dropped_records": view.dropped, "installed": installed,
    }
    return mgr
