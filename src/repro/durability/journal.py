"""The crash-consistent write-ahead journal (durability pillar 1).

One ``Journal`` is an append-only binary file of ``WireMsg`` records — the
SAME record vocabulary the controller<->replica transport speaks
(core/transport.py): volume control ops reuse ``MSG_CREATE`` /
``MSG_SNAPSHOT`` / ``MSG_CLONE`` / ``MSG_UNMAP`` / ``MSG_DELETE``, data
writes are ``MSG_WRITE`` records of post-RMW block-aligned bytes — replay
applies them directly, no re-merge — with adjacent same-volume writes
coalesced into one record at group commit (``coalesce_writes``), and two
journal-local opcodes extend the range: ``OP_COMPUTE`` (a *mutating*
storage-function call — ``compare_and_write``; read-only functions don't
change state and are not journaled) and ``OP_SEAL`` (the batch commit
record).

**Group commit.** ``VolumeManager`` buffers records as ops are submitted
and appends the whole buffer — records + one seal — as ONE file write at
every pump boundary, *before* the engine applies the batch (write-ahead).
Per-op appends would put a file write on the hot path; the seal makes the
batch the atomicity unit: a crash mid-append tears at most the unsealed
tail, and recovery drops exactly the ops the engine never acked.

**Torn-tail detection.** Every record carries an int32 checksum of its
body computed with the compute registry's rotate/XOR algebra
(``repro.compute.functions.np_blocksum`` — the vectorized twin of the
fold ``checksum`` / ``compare_and_write`` run in-band; bit-identical to
``py_blocksum``, numpy-speed on the group-commit path). The reader stops
at the first short,
mis-tagged or mis-summed record and discards any records after the last
seal; ``Journal.__init__`` truncates that torn tail so the journal is
append-clean after recovery.

Record frame (little-endian)::

    | u32 magic "JRNL" | u32 seq | u32 body_len | body | i32 blocksum(body) |

Body::

    | u8 op | i32 volume | i32 shard | i64 meta0 | i64 meta1 | u16 name_len
    | name | u32 n_pages | pages i32[] | u32 n_blocks | blocks i32[]
    | u32 payload_len | payload bytes |

``Journal.sync()`` is the ``Volume.flush(durable=True)`` barrier: fsync.
"""
from __future__ import annotations

import os
import struct
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.compute.functions import np_blocksum, np_blocksum_many
from repro.core.transport import MSG_WRITE, WireMsg

# journal-local opcodes, outside the transport's MSG_ range (0..12)
OP_COMPUTE = 32    # a mutating storage-function call (volume, page, block,
                   # meta=(arg, scope_is_range), fn name, payload=data bytes)
OP_SEAL = 33       # batch commit record (meta0 = records in the batch)

_FILE_MAGIC = b"DBSJRNL1"
_REC_MAGIC = 0x4C4E524A          # "JRNL"
_FRAME = struct.Struct("<III")   # magic, seq, body_len
_HEAD = struct.Struct("<biiqqH")  # op, volume, shard, meta0, meta1, name_len
_SUM = struct.Struct("<i")


_U32_0 = struct.pack("<I", 0)


def _pack_i32(a) -> bytes:
    """u32 count + i32[] — pure struct on the list-valued capture path (a
    numpy round-trip per tiny array would dominate the encode cost)."""
    if a is None:
        return _U32_0
    if isinstance(a, np.ndarray):
        a = np.ascontiguousarray(a.astype(np.int32, copy=False).reshape(-1))
        return struct.pack("<I", a.size) + a.tobytes()
    return struct.pack(f"<I{len(a)}i", len(a), *a)


def encode_body(msg: WireMsg) -> bytes:
    """The record body alone (no frame, no checksum): ``append_batch``
    checksums a whole batch of bodies in one vectorized pass."""
    meta = tuple(msg.meta) if msg.meta else ()
    meta0 = int(meta[0]) if len(meta) > 0 else 0
    meta1 = int(meta[1]) if len(meta) > 1 else 0
    name = getattr(msg, "extents", None)
    name_b = bytes(name) if isinstance(name, (bytes, bytearray)) else b""
    pages = _pack_i32(msg.pages)
    blocks = _pack_i32(msg.blocks)
    if msg.payload is None:
        pay = b""
    elif isinstance(msg.payload, (bytes, bytearray)):
        pay = bytes(msg.payload)
    else:
        # write lanes hold exact byte values (0..255 — engine payload
        # convention), so they journal as ONE uint8 per lane: 4x smaller
        # records, and the common capture path hands us bytes directly
        pay = np.asarray(msg.payload).astype(np.uint8).tobytes()
    vol = -1 if msg.volume is None else int(msg.volume)
    shard = -1 if msg.shard is None else int(msg.shard)
    return b"".join([
        _HEAD.pack(int(msg.op), vol, shard, meta0, meta1, len(name_b)),
        name_b, pages, blocks,
        struct.pack("<I", len(pay)), pay,
    ])


def encode_record(seq: int, msg: WireMsg) -> bytes:
    """One framed record: header + checksummed body (module docstring)."""
    body = encode_body(msg)
    return (_FRAME.pack(_REC_MAGIC, seq, len(body)) + body
            + _SUM.pack(np_blocksum(body)))


def decode_record(body: bytes) -> WireMsg:
    """Inverse of ``encode_body`` (the frame/checksum are checked by the
    reader). Write payloads come back as (n_pages, -1) float32 lanes
    rebuilt from the journaled uint8 bytes; compute payloads as raw
    bytes."""
    op, vol, shard, meta0, meta1, nlen = _HEAD.unpack_from(body, 0)
    off = _HEAD.size
    name = body[off:off + nlen]
    off += nlen
    (np_, ) = struct.unpack_from("<I", body, off)
    off += 4
    pages = np.frombuffer(body, np.int32, np_, off).copy()
    off += 4 * np_
    (nb, ) = struct.unpack_from("<I", body, off)
    off += 4
    blocks = np.frombuffer(body, np.int32, nb, off).copy()
    off += 4 * nb
    (pl, ) = struct.unpack_from("<I", body, off)
    off += 4
    raw = body[off:off + pl]
    if op == OP_COMPUTE:
        payload = raw
    elif pl and np_:
        payload = np.frombuffer(raw, np.uint8).astype(
            np.float32).reshape(np_, -1)
    else:
        payload = None
    return WireMsg(op=op, volume=vol, pages=pages if np_ else None,
                   blocks=blocks if nb else None, payload=payload,
                   extents=name or None, meta=(meta0, meta1),
                   shard=None if shard < 0 else shard)


@dataclass
class JournalView:
    """What a journal file holds: the sealed records (in append order),
    whether a torn tail was discarded, how many unsealed records it held,
    and the byte offset appends may resume at."""
    records: List[Tuple[int, WireMsg]]
    torn: bool
    dropped: int
    valid_bytes: int
    last_seq: int


def read_journal(path: str) -> JournalView:
    """Parse a journal file, committing records batch-by-batch at each seal
    and DROPPING everything after the last intact seal (torn-tail rule)."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:len(_FILE_MAGIC)] != _FILE_MAGIC:
        raise IOError(f"{path}: not a journal (bad file magic)")
    off = len(_FILE_MAGIC)
    committed: List[Tuple[int, WireMsg]] = []
    pending: List[Tuple[int, WireMsg]] = []
    valid = off
    torn = False
    last_seq = 0
    while True:
        if off + _FRAME.size > len(blob):
            torn = torn or off < len(blob)
            break
        magic, seq, blen = _FRAME.unpack_from(blob, off)
        end = off + _FRAME.size + blen + _SUM.size
        if magic != _REC_MAGIC or end > len(blob):
            torn = True
            break
        body = blob[off + _FRAME.size:end - _SUM.size]
        (want_sum, ) = _SUM.unpack_from(blob, end - _SUM.size)
        if np_blocksum(body) != want_sum:
            torn = True
            break
        msg = decode_record(body)
        if msg.op == OP_SEAL:
            committed.extend(pending)
            pending.clear()
            valid = end
            last_seq = seq
        else:
            pending.append((seq, msg))
        off = end
    return JournalView(records=committed, torn=torn, dropped=len(pending),
                       valid_bytes=valid, last_seq=last_seq)


def coalesce_writes(msgs: Sequence[WireMsg]) -> List[WireMsg]:
    """Merge ADJACENT same-volume ``MSG_WRITE`` records into one.

    The capture path journals one record per ``pwrite`` with list-valued
    pages/blocks and a bytes payload whose k-th block-size chunk belongs
    to the k-th (page, block) pair — so a run of writes to one volume
    concatenates into a single record with identical replay semantics
    (replay applies a record's blocks in order, exactly as the separate
    records would have applied in sequence). A whole 32-write pump then
    encodes as ~one record instead of 32, which is where the group-commit
    encode cost goes. Records in any other shape (ndarray fields, control
    ops, computes) pass through unmerged, in order."""
    out: List[WireMsg] = []
    vol = pages = blocks = pays = None

    def _close():
        nonlocal pages
        if pages is not None:
            out.append(WireMsg(op=MSG_WRITE, volume=vol, pages=pages,
                               blocks=blocks, payload=b"".join(pays)))
            pages = None

    for m in msgs:
        if (m.op == MSG_WRITE and isinstance(m.pages, list)
                and isinstance(m.blocks, list)
                and isinstance(m.payload, (bytes, bytearray))):
            if pages is not None and vol == m.volume:
                pages.extend(m.pages)
                blocks.extend(m.blocks)
                pays.append(m.payload)
                continue
            _close()
            vol, pages = m.volume, list(m.pages)
            blocks, pays = list(m.blocks), [m.payload]
        else:
            _close()
            out.append(m)
    _close()
    return out


class Journal:
    """Append handle over one journal file (module docstring).

    Opening an existing file scans it, truncates any torn tail, and resumes
    the sequence numbering after the last sealed record — so a recovered
    manager reattaches to the same file and keeps appending."""

    def __init__(self, path: str):
        self.path = os.fspath(path)
        self._seq = 0
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            view = read_journal(self.path)
            self._seq = view.last_seq
            with open(self.path, "r+b") as f:
                f.truncate(view.valid_bytes)
            self._f = open(self.path, "ab")
        else:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "wb")
            self._f.write(_FILE_MAGIC)
            self._f.flush()
        self.appends = 0          # group commits (ONE per pump with traffic)
        self.records = 0          # records sealed

    @property
    def seq(self) -> int:
        """Sequence number of the last sealed record (the export cursor)."""
        return self._seq

    @property
    def closed(self) -> bool:
        return self._f.closed

    def append_batch(self, msgs: Sequence[WireMsg]) -> int:
        """Group-commit: encode every buffered record plus ONE seal and
        write them with a single file append. Returns the seal's seq."""
        if not msgs:
            return self._seq
        msgs = coalesce_writes(msgs)
        bodies = [encode_body(m) for m in msgs]
        bodies.append(encode_body(WireMsg(op=OP_SEAL, meta=(len(msgs), 0))))
        sums = np_blocksum_many(bodies)
        first = self._seq + 1
        self._seq += len(bodies)
        self._f.write(b"".join(
            _FRAME.pack(_REC_MAGIC, first + i, len(b)) + b + _SUM.pack(c)
            for i, (b, c) in enumerate(zip(bodies, sums))))
        self._f.flush()
        self.appends += 1
        self.records += len(msgs)
        return self._seq

    def sync(self) -> None:
        """The durable barrier (``Volume.flush(durable=True)``): fsync."""
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __repr__(self):
        return (f"Journal({self.path!r}, seq={self._seq}, "
                f"appends={self.appends})")


def as_journal(journal) -> Optional[Journal]:
    """Coerce a ``journal=`` config value: None | path | Journal."""
    if journal is None or isinstance(journal, Journal):
        return journal
    return Journal(journal)
