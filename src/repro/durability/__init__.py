"""Durability subsystem: journal, crash recovery, incremental export, tiering.

Everything the engine stores today lives in device pools — a process crash
loses all volumes, which is what separates the engine demo from the SDS the
paper's Longhorn actually is. This package adds the missing durability
plane as four cooperating modules, all riding existing surfaces:

- ``journal``  — a crash-consistent write-ahead journal. Every mutating op
  the public API accepts is captured as a PR-5 ``WireMsg`` record (same
  wire format + opcodes as the controller<->replica transport) and
  group-committed — ONE append per pump, not per op — with per-record
  checksums computed with the compute registry's rotate/XOR algebra
  (``py_blocksum``) for torn-tail detection. Exposed as
  ``EngineConfig(journal=...)`` / ``VolumeManager(journal=...)`` and the
  ``Volume.flush(durable=True)`` barrier.
- ``recovery`` — ``recover(...)``: rebuild a ``VolumeManager`` after a
  crash by installing the last export (when one exists) and replaying the
  journal tail through the same public submission path, byte-identical to
  a shadow oracle.
- ``export``   — ``SnapshotExport``: incremental snapshot export built on
  the ``page_rev`` watermarks — each section ships ONLY the extents backing
  pages newer than the previous section's watermark row (the PR-5
  delta-rebuild selection), into a versioned on-disk file with
  header-commits-last ordering. ``ExportCounters`` mirrors the transport
  counters so "moved exactly the delta" is assertable. The replicated
  checkpoint's rebuild (checkpoint/replicated.py) streams through
  ``stream_store`` instead of ``shutil`` file copies.
- ``tier``     — ``ExtentTier``: a capacity tier for the fused engine that
  spills cold extents to host memory and keeps a bounded device-resident
  hot set (clock/second-chance over per-extent access stamps maintained
  IN the fused step), faulting spilled extents back in batched prefetches
  at the pump boundary — the hot path stays one jitted program per pump.

See docs/ARCHITECTURE.md ("Durability & tiering").
"""
from repro.durability.export import (ExportCounters, SnapshotExport,
                                     stream_store)
from repro.durability.journal import (OP_COMPUTE, OP_SEAL, Journal,
                                      JournalView, read_journal)
from repro.durability.recovery import recover
from repro.durability.tier import ExtentTier

__all__ = [
    "Journal", "JournalView", "read_journal", "OP_COMPUTE", "OP_SEAL",
    "SnapshotExport", "ExportCounters", "stream_store",
    "recover",
    "ExtentTier",
]
