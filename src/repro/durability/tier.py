"""Cold-extent spill tier for the fused engine (durability pillar 4).

The DBS extent pool is sized at config time and every extent is device-
resident — capacity is bounded by accelerator memory. ``ExtentTier`` turns
the pool into a HOT SET: a bounded number of extents stay device-resident,
cold extents spill to host memory, and spilled extents fault back in when
a batch touches them. The invariants:

- **Hot path stays one jitted program per pump.** The fused step gains one
  extra donated operand — ``stamps``, an ``(E+1,)`` int32 of per-extent
  access ticks — and stamps every extent a batch resolves (reads, write
  destinations AND CoW sources) with the batch step inside the same
  program. All spill/fill traffic rides the pump boundary in host code.
- **Fill before, balance after.** Before a pump the tier resolves the
  batch's (volume, page) lanes against the table ONCE on the host, and
  faults every spilled extent the batch needs back in a single batched
  row-scatter per replica pool. After the pump, if the resident set
  exceeds the budget, a clock/second-chance sweep over the stamps picks
  victims: first pass spares extents whose stamp advanced since the hand
  last saw them, second pass evicts unconditionally. Victim rows are
  fetched once (write="all" keeps replicas identical, so ONE host copy
  serves them all) and the device rows are zeroed.
- **Zeroing spilled rows is safe.** DBS never zeroes freshly allocated
  extents — a fresh allocation inherits whatever bytes the pool row holds,
  and every byte a volume can read through a live mapping was either
  written (faulted in before the write's CoW copy runs) or is a hole
  (masked to zeros on read). A freed-then-spilled-then-reallocated extent
  therefore reads zeros, matching the zero-filled oracle.

Enabled with ``EngineConfig(tier=N)`` (or ``tier=dict(device_extents=N)``)
on the fused engine; ``export.SnapshotExport`` reads *through* the tier
(``read_through``) so exports see spilled bytes.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ExtentTier:
    """Host-side state of the spill tier: which extents are device-resident,
    the spilled rows, and the clock hand (module docstring)."""

    def __init__(self, n_extents: int, device_extents: int):
        if not 0 < device_extents:
            raise ValueError(f"device_extents must be positive, got "
                             f"{device_extents}")
        self.n_extents = int(n_extents)
        self.device_extents = int(min(device_extents, n_extents))
        # stamps[e] = step of the last batch that resolved extent e; row E
        # is the dump slot for the fused step's invalid-lane scatter.
        self.stamps = jnp.zeros((self.n_extents + 1,), jnp.int32)
        self.resident = np.ones(self.n_extents, bool)
        self.spilled: Dict[int, np.ndarray] = {}
        self._mapped = np.zeros(self.n_extents, bool)
        self._hand = 0
        self._seen = np.zeros(self.n_extents, np.int64)
        self.fills = 0             # fault-in batches
        self.spills = 0            # eviction sweeps
        self.extents_filled = 0
        self.extents_spilled = 0

    # ------------------------------------------------------------- pump hooks
    def fault_in(self, table_host: np.ndarray, reqs,
                 pools: Tuple) -> Tuple[Tuple, set]:
        """Pre-pump fill: resolve the batch's (volume, page) lanes against
        the host copy of replica-0's table and fault every spilled extent
        back in with ONE batched row-scatter per replica pool. Returns the
        (possibly new) pools and the set of extents the batch touches.

        Also reconciles the spill set against the table: only MAPPED extents
        are ever evicted (below), so the allocator can only hand out extents
        whose device rows are live — but an extent can be freed *after*
        spilling (unmap / delete / CoW superseding it). Its content is dead
        to the data plane the moment it leaves the table, and its device row
        was zeroed at eviction — exactly the content a fresh allocation is
        supposed to inherit — so the stale spilled copy is dropped and the
        row counts as resident again. Without this, a reallocation of a
        spilled-then-freed extent would later fault stale bytes in over
        freshly written data."""
        self._mapped = np.zeros(self.n_extents, bool)
        self._mapped[table_host[table_host >= 0]] = True
        for e in [e for e in self.spilled if not self._mapped[e]]:
            del self.spilled[e]
            self.resident[e] = True
        nv, npg = table_host.shape
        need = set()
        for r in reqs:
            if 0 <= r.volume < nv and 0 <= r.page < npg:
                e = int(table_host[r.volume, r.page])
                if e >= 0:
                    need.add(e)
        fill = sorted(e for e in need if not self.resident[e])
        if fill:
            rows = jnp.asarray(np.stack([self.spilled.pop(e) for e in fill]))
            idx = jnp.asarray(np.asarray(fill, np.int32))
            pools = tuple(p.at[idx].set(rows) for p in pools)
            for e in fill:
                self.resident[e] = True
            self.fills += 1
            self.extents_filled += len(fill)
        return pools, need

    def balance(self, pools: Tuple, protect: Iterable[int] = ()) -> Tuple:
        """Post-pump eviction: while the MAPPED resident set exceeds the
        budget, sweep the clock hand over the stamps — first full pass gives
        a second chance to any extent whose stamp advanced since the hand
        last passed it, second pass evicts unconditionally. Only extents the
        table maps are candidates (a free extent holds no live bytes and may
        be handed out by the allocator any pump — see ``fault_in``); extents
        in ``protect`` (this batch's working set) are never evicted."""
        mapped = self._mapped
        over = int((self.resident & mapped).sum()) - self.device_extents
        if over <= 0:
            return pools
        stamps = np.asarray(jax.device_get(self.stamps))[:self.n_extents]
        shield = set(protect)
        victims: list = []
        taken = set()
        for ppass in range(2):
            for _ in range(self.n_extents):
                if len(victims) >= over:
                    break
                e = self._hand
                self._hand = (self._hand + 1) % self.n_extents
                if (not self.resident[e] or not mapped[e] or e in shield
                        or e in taken):
                    continue
                if ppass == 0 and stamps[e] > self._seen[e]:
                    self._seen[e] = stamps[e]    # second chance
                    continue
                self._seen[e] = stamps[e]
                victims.append(e)
                taken.add(e)
            if len(victims) >= over:
                break
        if not victims:
            return pools
        idx_np = np.asarray(victims, np.int32)
        idx = jnp.asarray(idx_np)
        # write="all" keeps replica pools identical: one host copy serves all
        rows = np.asarray(jax.device_get(pools[0][idx]))
        for j, e in enumerate(victims):
            self.spilled[e] = rows[j]
            self.resident[e] = False
        zero = jnp.zeros((len(victims),) + tuple(pools[0].shape[1:]),
                         pools[0].dtype)
        pools = tuple(p.at[idx].set(zero) for p in pools)
        self.spills += 1
        self.extents_spilled += len(victims)
        return pools

    # ------------------------------------------------------------- side doors
    def read_through(self, pool_host: np.ndarray) -> np.ndarray:
        """Overlay the spilled rows onto a host copy of a replica pool —
        the full-content view exports and oracles read."""
        if not self.spilled:
            return pool_host
        out = np.array(pool_host)
        for e, row in self.spilled.items():
            out[e] = row
        return out

    def reset_resident(self) -> None:
        """Forget all tier state (export install replaced the pools whole);
        the next balance() re-evicts if the budget is exceeded."""
        self.resident[:] = True
        self.spilled.clear()
        self._mapped[:] = False
        self._seen[:] = 0
        self._hand = 0
        self.stamps = jnp.zeros((self.n_extents + 1,), jnp.int32)

    def to_dict(self) -> dict:
        return {
            "device_extents": self.device_extents,
            "resident": int((self.resident & self._mapped).sum()),
            "spilled": len(self.spilled),
            "fills": self.fills, "spills": self.spills,
            "extents_filled": self.extents_filled,
            "extents_spilled": self.extents_spilled,
        }

    def __repr__(self):
        return (f"ExtentTier(budget={self.device_extents}, "
                f"resident={int(self.resident.sum())}, "
                f"spilled={len(self.spilled)})")


def as_tier(tier, n_extents: int):
    """Coerce an ``EngineConfig(tier=...)`` value: None | int budget |
    dict(device_extents=...) | ExtentTier."""
    if tier is None or isinstance(tier, ExtentTier):
        return tier
    if isinstance(tier, dict):
        return ExtentTier(n_extents, int(tier["device_extents"]))
    return ExtentTier(n_extents, int(tier))
