"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676; hf]

Hymba fuses a sliding-window attention branch and a Mamba (SSM) branch in the
same layer ("hybrid heads"); a few layers use global attention. We follow the
paper's 3-global-layer recipe (first/middle/last).
"""
from repro.configs.base import ArchConfig, SSMConfig, ATTN_HYBRID

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    layer_pattern=(ATTN_HYBRID,),
    sliding_window=1024,
    global_layer_indices=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2),
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

# layer indices using global (full) attention instead of SWA, per Hymba.
GLOBAL_LAYERS = (0, 15, 31)
