"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ArchConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    activation="gelu_tanh",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
