"""Config registry: ``get_config("<arch>")`` and reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    ArchConfig, ExecutionPlan, MLAConfig, MoEConfig, SSMConfig, ShapeSpec,
    SHAPES, ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, ATTN_HYBRID, ATTN_RWKV,
    MLP_DENSE, MLP_MOE, default_plan, model_flops, shape_applicable,
)

from repro.configs import (
    gemma2_2b, gemma3_27b, granite_3_8b, starcoder2_15b, chameleon_34b,
    hymba_1_5b, granite_moe_3b, deepseek_v3_671b, musicgen_large, rwkv6_3b,
)

_REGISTRY: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma2_2b, gemma3_27b, granite_3_8b, starcoder2_15b, chameleon_34b,
        hymba_1_5b, granite_moe_3b, deepseek_v3_671b, musicgen_large, rwkv6_3b,
    )
}

ALL_ARCHS: List[str] = sorted(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {ALL_ARCHS}") from None


def register(cfg: ArchConfig) -> None:
    _REGISTRY[cfg.name] = cfg


def smoke_config(name: str, *, n_layers: int = None, d_model: int = None,
                 vocab: int = 512) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests.

    Keeps the structural features (layer pattern, GQA ratio, MoE/MLA/SSM,
    softcaps, codebooks) while shrinking width/depth/vocab/experts.
    """
    cfg = get_config(name)
    hd = 16
    heads = max(2, cfg.n_heads // 8)
    kv = max(1, round(heads * cfg.n_kv_heads / cfg.n_heads))
    while heads % kv:
        kv -= 1
    d = d_model or hd * heads
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers or max(2, 2 * len(cfg.layer_pattern) if len(cfg.layer_pattern) <= 3 else len(cfg.layer_pattern)),
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=4 * d,
        vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        page_blocks=8,
    )
    nl = changes["n_layers"]
    if cfg.global_layer_indices:
        changes["global_layer_indices"] = tuple(
            i for i in cfg.global_layer_indices if i < nl) or (0,)
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4, top_k=2, d_ff_expert=2 * d,
            n_shared=min(cfg.moe.n_shared, 1),
            d_ff_shared=2 * d if cfg.moe.n_shared else 0,
            router_aux_free=cfg.moe.router_aux_free)
        changes["n_dense_layers"] = 1 if cfg.n_dense_layers else 0
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   rope_head_dim=8, nope_head_dim=16,
                                   v_head_dim=16)
        changes["head_dim"] = 16
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=4, expand=2,
                                             rwkv_head_dim=hd)
        if cfg.name.startswith("rwkv"):
            changes["n_heads"] = changes["n_kv_heads"] = d // hd
    if cfg.mtp_depth:
        changes["mtp_depth"] = 1
    return dataclasses.replace(cfg, **changes)
