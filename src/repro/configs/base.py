"""Architecture/config system.

Every assigned architecture is described by an :class:`ArchConfig` — a frozen
dataclass consumed by the model zoo (``repro.models``), the sharding planner
(``repro.distributed.planner``) and the launchers.  Configs are *data*: no jax
imports here, so importing a config never touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds (the per-layer pattern lets us express alternating stacks such
# as gemma2 local/global, hymba's hybrid heads or deepseek's dense->MoE split).
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"        # full causal attention
ATTN_LOCAL = "local"          # sliding-window causal attention
ATTN_MLA = "mla"              # DeepSeek multi-head latent attention
ATTN_HYBRID = "hybrid"        # parallel attention + mamba heads (hymba)
ATTN_RWKV = "rwkv6"           # attention-free RWKV-6 token mixer
MLP_DENSE = "dense"
MLP_MOE = "moe"


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    router_aux_free: bool = False   # deepseek-v3 aux-loss-free bias routing
    n_experts_padded: int = 0       # pad expert dim for even EP (§Perf B2)

    @property
    def e_total(self) -> int:
        return max(self.n_experts_padded, self.n_experts)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style SSM branch (hymba) or RWKV-6 channel config."""
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2
    rwkv_head_dim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention details -----------------------------------------------------
    layer_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)   # cycled over layers
    sliding_window: int = 0          # window for ATTN_LOCAL layers
    attn_logit_softcap: float = 0.0  # gemma2-style tanh soft capping
    final_logit_softcap: float = 0.0
    qk_norm: bool = False            # chameleon / gemma3
    post_norms: bool = False         # gemma2/3: extra post-attn / post-ffn norms
    rope_theta: float = 10_000.0
    # MLP / MoE --------------------------------------------------------------
    mlp_pattern: Tuple[str, ...] = (MLP_DENSE,)
    global_layer_indices: Tuple[int, ...] = ()  # hybrid archs: full-attn layers
    n_dense_layers: int = 0          # leading dense layers before MoE (deepseek: 3)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    activation: str = "silu"         # silu | gelu_tanh
    gated_mlp: bool = True
    # embeddings / output ----------------------------------------------------
    tie_embeddings: bool = True
    n_codebooks: int = 1             # musicgen: parallel EnCodec codebooks
    modality_stub: str = ""          # "audio_frames" | "vq_image" | ""
    mtp_depth: int = 0               # deepseek multi-token-prediction heads
    norm_eps: float = 1e-6
    # serving ----------------------------------------------------------------
    page_blocks: int = 32            # tokens per DBS extent (paper: 32 blocks/extent)

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def mlp_kind(self, i: int) -> str:
        if self.moe is not None and i >= self.n_dense_layers:
            return MLP_MOE
        return MLP_DENSE

    @property
    def attention_free(self) -> bool:
        return all(k == ATTN_RWKV for k in self.layer_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no layer keeps an unbounded full-attention KV cache."""
        return all(k in (ATTN_RWKV, ATTN_LOCAL) for k in self.layer_pattern)

    @property
    def long_context_capable(self) -> bool:
        """Eligible for the 524k decode shape: only a bounded-state or a small
        fraction of global layers (see DESIGN.md §Arch-applicability)."""
        if self.subquadratic:
            return True
        kinds = [self.layer_kind(i) for i in range(self.n_layers)]
        frac_global = sum(k in (ATTN_GLOBAL, ATTN_MLA) for k in kinds) / len(kinds)
        return frac_global <= 0.5 and self.sliding_window > 0

    # -------------------------------------------------------- parameter count
    def param_count(self) -> int:
        """Exact-ish parameter count (embeddings + per-layer weights)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_embed = self.vocab_size * d * self.n_codebooks
        if not self.tie_embeddings:
            n_embed += self.vocab_size * d * self.n_codebooks
        total = n_embed + d  # final norm
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == ATTN_RWKV:
                # rwkv6: r,k,v,g,o (d*d) + decay/low-rank mixers (small)
                attn = 5 * d * d + 6 * d * 32 * 2 + d * hd
            elif kind == ATTN_MLA:
                m = self.mla or MLAConfig()
                qh = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                attn = (d * m.q_lora_rank + m.q_lora_rank * qh
                        + d * (m.kv_lora_rank + m.rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                        + self.n_heads * hd * d)
                if kind == ATTN_HYBRID and self.ssm is not None:
                    e = self.ssm.expand * d
                    attn += d * 2 * e + e * self.ssm.conv_kernel + e * 2 * self.ssm.state_dim + e + e * d
            if self.mlp_kind(i) == MLP_MOE:
                mo = self.moe
                per = (3 if self.gated_mlp else 2) * d * mo.d_ff_expert
                mlp = mo.n_experts * per + d * mo.n_experts
                if mo.n_shared:
                    mlp += mo.n_shared * (3 if self.gated_mlp else 2) * d * mo.d_ff_shared
            else:
                mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
            total += attn + mlp + 2 * d  # two norms
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        n_moe_layers = self.n_layers - self.n_dense_layers
        per = (3 if self.gated_mlp else 2) * self.d_model * mo.d_ff_expert
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * per
        return int(full - inactive)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is paired with all four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.long_context_capable:
        return False, ("pure full-attention arch: 524k decode KV would be "
                       "unbounded-quadratic; skipped per assignment brief "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Execution plan: how a given (arch, shape) runs on a mesh. The planner uses
# it to pick microbatching, remat, optimizer and sharding strategy.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExecutionPlan:
    microbatches: int = 1            # gradient-accumulation steps (scan)
    remat: str = "none"              # none | block | full
    optimizer: str = "adamw"         # adamw | adafactor
    fsdp: bool = False               # shard params/opt state over "data" too
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    logits_chunk: int = 0            # chunked cross-entropy chunk (0 = auto)
    scan_layers: bool = True
    attn_impl: str = "chunked"       # chunked | dense | pallas
    kv_cache_kind: str = "paged"     # paged | dense (serve path)
    attn_chunk: int = 1024           # flash KV/Q chunk size
    ssm_chunk: int = 256             # mamba/rwkv chunk length
    unroll_scans: bool = False       # accounting variant: no while loops
    paged_stripe_slice: bool = True  # gather only owned page stripes (§Perf A2)
    constrain_activations: bool = False  # pin residual-stream sharding (§Perf C2)
    moe_pad_to: int = 0              # pad experts to a multiple (§Perf B2)
    unstack_params: bool = False     # per-layer weights for decode (§Perf A4)


def default_plan(cfg: ArchConfig, shape: ShapeSpec, n_chips: int = 256,
                 data_shards: int = 0) -> ExecutionPlan:
    params = cfg.param_count()
    big = params > 6e9            # needs FSDP + bf16 compute at scale
    huge = params > 60e9          # needs adafactor + bf16 params
    if shape.kind == "train":
        # microbatch down to per-data-shard batch 1 (activation fit for the
        # big configs); per-microbatch global batch stays shardable.
        ds = data_shards or max(1, n_chips // 16)
        micro = max(1, shape.global_batch // ds)
        return ExecutionPlan(
            microbatches=micro,
            remat="block",
            optimizer="adafactor" if huge else "adamw",
            fsdp=big,
            param_dtype="bfloat16" if huge else "float32",
            logits_chunk=1024 if cfg.vocab_size > 64_000 else 0,
        )
    # serve plans: bf16 weights; >25B params additionally shard over "data"
    # (pure TP leaves e.g. deepseek's experts at 84 GB/device — the memory
    # table in EXPERIMENTS.md §Dry-run is what catches this class of bug)
    return ExecutionPlan(
        microbatches=1, remat="none", optimizer="adamw", fsdp=params > 25e9,
        param_dtype="bfloat16",
        logits_chunk=0,
    )


def model_flops(cfg: ArchConfig, tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per the brief."""
    return 6.0 * cfg.active_param_count() * tokens
