"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP.

61L d_model=7168 128H (MLA) d_ff_expert=2048 vocab=129280, 3 leading dense
layers with d_ff=18432. [arXiv:2412.19437; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, MLAConfig, ATTN_MLA

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,   # MLA: all heads share the latent cache
    head_dim=128,
    d_ff=18432,       # dense layers / shared expert width basis
    vocab_size=129_280,
    layer_pattern=(ATTN_MLA,),
    n_dense_layers=3,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared=1, d_ff_shared=2048, router_aux_free=True),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    activation="silu",
    gated_mlp=True,
    tie_embeddings=False,
    mtp_depth=1,
    rope_theta=10_000.0,
)
