"""granite-3-8b [dense] — GQA decoder.

40L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base scaled per assignment; hf]
"""
from repro.configs.base import ArchConfig, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49_155,
    layer_pattern=(ATTN_GLOBAL,),
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
