"""musicgen-large [audio] — decoder-only over EnCodec tokens (4 codebooks).

48L d_model=2048 32H (kv=32, head_dim=64) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: inputs are the 4 parallel
codebook token streams (delay pattern applied upstream); embeddings of the K
codebooks are summed, and the model has K parallel LM heads.
"""
from repro.configs.base import ArchConfig, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    layer_pattern=(ATTN_GLOBAL,),
    activation="gelu_tanh",
    gated_mlp=False,
    tie_embeddings=False,
    n_codebooks=4,
    modality_stub="audio_frames",
    rope_theta=10_000.0,
)
