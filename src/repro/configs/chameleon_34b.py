"""chameleon-34b [vlm] — early-fusion, VQ image tokens, QK-norm.

48L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
token ids over a unified text+VQ-image vocabulary (early fusion); the backbone
is a standard decoder with QK-norm (chameleon's training-stability fix).
"""
from repro.configs.base import ArchConfig, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    layer_pattern=(ATTN_GLOBAL,),
    qk_norm=True,
    activation="silu",
    gated_mlp=True,
    tie_embeddings=False,
    modality_stub="vq_image",
    rope_theta=10_000.0,
)
