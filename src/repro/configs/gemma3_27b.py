"""gemma3-27b [dense] — 5:1 local:global attention, 128k context, QK-norm.

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt scaled per assignment; unverified]
"""
from repro.configs.base import ArchConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    # gemma3: five local layers followed by one global layer
    layer_pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
    sliding_window=1024,
    qk_norm=True,
    post_norms=True,
    activation="gelu_tanh",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
