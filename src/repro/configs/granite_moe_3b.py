"""granite-moe-3b-a800m [moe] — 40 experts, top-8 routing.

32L d_model=1536 24H (GQA kv=8, head_dim=64) d_ff_expert=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base scaled per assignment; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    layer_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
