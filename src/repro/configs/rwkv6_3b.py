"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=2560 (40 heads x 64) d_ff=8960 vocab=65536 [arXiv:2404.05892; hf]

The paper's block-store technique targets KV caches; RWKV-6 is attention-free
(O(1) recurrent state), so the paged-KV path is inapplicable to its compute —
recorded in DESIGN.md §Arch-applicability. The arch still runs everywhere
(train/prefill/decode/long_500k) with its recurrent state, and its states are
checkpointed through DBS volumes.
"""
from repro.configs.base import ArchConfig, SSMConfig, ATTN_RWKV

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    layer_pattern=(ATTN_RWKV,),
    ssm=SSMConfig(rwkv_head_dim=64),
    activation="silu",     # rwkv channel-mix uses relu^2; set in layer code
    gated_mlp=False,
    tie_embeddings=False,
    rope_theta=0.0,
)
