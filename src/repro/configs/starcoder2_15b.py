"""starcoder2-15b [dense] — GQA, RoPE.

40L d_model=6144 48H (GQA kv=4, head_dim=128) d_ff=24576 vocab=49152
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ArchConfig, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49_152,
    layer_pattern=(ATTN_GLOBAL,),
    activation="gelu_tanh",
    gated_mlp=False,  # starcoder2 uses a plain (non-gated) MLP
    tie_embeddings=True,
    rope_theta=100_000.0,
)
