"""Unified SQ/CQ ring protocol: ONE opcode-tagged submission path for data
AND control ops through the fused/sharded step (paper §IV-B/C).

The paper's second pillar restructures the communication protocol between
the ublk frontend and the replicas into one queue pair carrying everything,
instead of per-request synchronous hops. PR 1/2 built a fast device-resident
*data* plane (fused step, vmapped shard pool), but every *control* op —
snapshot, clone, unmap, delete, replica fail/rebuild — was still a separate
host-side dispatch that fenced the pump, and each engine spoke its own drain
protocol. This module is the io_uring-style fix:

- **SQE** — an opcode-tagged submission record (READ / WRITE / SNAPSHOT /
  CLONE / UNMAP / DELETE / FAIL_REPLICA / REBUILD_REPLICA / NOOP barrier),
  admitted through the SlotTable like any other request. The Messages Array
  records each slot's opcode (``slots.SlotTable.opcode``).
- **CQ** — a device-resident buffer of completion records indexed by slot id
  (the "payload slot"): status, op result value, op latency in pump ticks,
  and the read payload. The step scatters a CQE per admitted lane; the host
  performs its usual single per-pump fetch of the per-lane view.
- **ring_step_core** — the opcode-dispatched engine iteration: the batched
  data phase (mirrored CoW writes, rr reads — identical to fused.step_core),
  then a lane-ordered ``lax.scan`` applying the volume-control tail
  (``lax.switch`` over op class), then the masked replica-control op against
  the *traced* health mask. Everything is vmap-safe, so the sharded pool
  gets in-band control ops for free — per-shard fail/rebuild happens inside
  the same single jitted program as foreground I/O, no host branch between
  pumps.
- **RingFrontend** — THE drain protocol. S shards × Q admission queues, one
  opcode-aware drain (``drain_ring``). The legacy ``MultiQueueFrontend`` /
  ``ShardedFrontend`` are thin adapters over it (core/frontend.py).
- **RingEngine** — ``EngineConfig(comm="ring")``: S engine shards (S=1 runs
  the program unmapped), pipelined double-buffered pump, one compiled
  program per (batch geometry, opcode-class signature).

Batch-ordering contract (what makes in-band control bit-exact against the
host-side sequential reference): within one SQE batch, data lanes precede
control lanes (the frontend cuts the drain so that once a control op is
drained only further control ops may join, and a replica op closes the
batch). The step applies the data phase first, then the control tail in
lane order — exactly the submission order. Ordering *between* batches is
program order as always.
"""
from __future__ import annotations

import collections
import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compute import registry as compute_registry
from repro.compute.phase import apply_compute_ops
from repro.core import dbs, slots
from repro.core.control import ControlDispatch
from repro.core.fused import _cow_apply, _rr_gather
from repro.core.replication import ShardedReplicaGroup
from repro.core.transport import clone_page_rev, stamp_page_rev

# ---------------------------------------------------------------------------
# the opcode table (SQE.op) and completion statuses (CQE.status)
# ---------------------------------------------------------------------------
OP_NOOP = 0        # barrier: admit + complete, touches nothing
OP_READ = 1
OP_WRITE = 2
OP_SNAPSHOT = 3    # volume-control ops (applied in lane order)
OP_CLONE = 4
OP_UNMAP = 5
OP_DELETE = 6
OP_FAIL = 7        # replica-control ops (close their batch)
OP_REBUILD = 8
OP_COMPUTE = 9     # in-band storage function (repro/compute registry)

OP_NAMES = ("NOOP", "READ", "WRITE", "SNAPSHOT", "CLONE", "UNMAP", "DELETE",
            "FAIL_REPLICA", "REBUILD_REPLICA", "COMPUTE")

KIND_TO_OP = {"noop": OP_NOOP, "read": OP_READ, "write": OP_WRITE,
              "snapshot": OP_SNAPSHOT, "clone": OP_CLONE, "unmap": OP_UNMAP,
              "delete": OP_DELETE, "fail": OP_FAIL, "rebuild": OP_REBUILD,
              "compute": OP_COMPUTE}

# opcode classes: which phases of the step a batch needs (static per program)
KIND_CLASS = {"noop": "noop", "read": "read", "write": "write",
              "snapshot": "vol", "clone": "vol", "unmap": "vol",
              "delete": "vol", "fail": "repl", "rebuild": "repl",
              "compute": "compute"}

ST_OK = 0          # completed
ST_ERR = -1        # op rejected (bad volume / snapshot table full / bad arg)
ST_LAST = -2       # FAIL would lose the shard's last healthy replica
ST_HEALTHY = -3    # REBUILD target is healthy — nothing to rebuild
# positive status: the op ran, its predicate did not hold (CAS expectation
# miss, verify_on_read checksum mismatch) — NOT an I/O error, IOFuture only
# raises on status < 0. Canonical value lives in repro/compute/registry.py
# (this module imports the compute package; never the reverse).
ST_MISMATCH = compute_registry.ST_MISMATCH

# max control ops per batch: the in-program control scan covers a fixed
# K-lane window (control lanes are contiguous — the drain policy admits only
# further control ops once one is drained — so a dynamic-slice window at the
# first control lane sees them all). Small K keeps the scan cheap under
# vmap, where every lane executes every switch branch.
CTRL_TAIL = 8

# max COMPUTE ops per batch — the compute phase's scan window, same idiom
# (EngineConfig.compute_tail overrides per engine). Compute is its own batch
# rank between data and control: data < compute < control, the drain cuts on
# every rank change, and a *writing* storage function (compare_and_write)
# additionally closes the compute window so the phase commits at most one
# CoW write per batch.
COMPUTE_TAIL = 8


# ---------------------------------------------------------------------------
# SQE / CQ records
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclass
class SQE:
    """One fixed-shape submission batch: the opcode-tagged generalisation of
    ``fused.FusedBatch``. All lane arrays are (B,) ((S, B) stacked), inert
    padding lanes marked want=False. ``block`` doubles as the replica index
    for FAIL/REBUILD lanes; ``tick`` is the submission pump tick (latency =
    completion step - tick + 1)."""
    want: jnp.ndarray       # (B,) bool
    op: jnp.ndarray         # (B,) int32 opcode (OP_*)
    volume: jnp.ndarray     # (B,) int32 shard-local volume (-1 = none)
    page: jnp.ndarray       # (B,) int32
    block: jnp.ndarray      # (B,) int32 block offset / replica index
    payload: jnp.ndarray    # (B, *payload) write payloads
    queue: jnp.ndarray      # (B,) int32 admission queue
    tick: jnp.ndarray       # (B,) int32 submission pump tick
    fn: jnp.ndarray         # (B,) int32 storage-fn id (COMPUTE lanes)
    arg: jnp.ndarray        # (B,) int32 storage-fn immediate argument
    step: jnp.ndarray       # ()   int32 admission step (this pump's tick)


@jax.tree_util.register_dataclass
@dataclass
class CQ:
    """Device-resident completion records, indexed by slot id (the "payload
    slot" of the CQE). A slot's record lives until the slot is reacquired —
    the Messages-Array idiom applied to completions."""
    status: jnp.ndarray     # (N,) int32 ST_*
    value: jnp.ndarray      # (N,) int32 op result (snapshot id / clone vol)
    latency: jnp.ndarray    # (N,) int32 completion latency in pump ticks
    payload: jnp.ndarray    # (N, *payload) read payload slots


@jax.tree_util.register_dataclass
@dataclass
class CQEView:
    """The per-lane view of this pump's completion records — what the host's
    single per-pump ``device_get`` fetches."""
    ok: jnp.ndarray         # (B,) bool  lane admitted (and thus completed)
    status: jnp.ndarray     # (B,) int32
    value: jnp.ndarray      # (B,) int32
    latency: jnp.ndarray    # (B,) int32
    reads: jnp.ndarray      # (B, *payload)


def make_cq(n_slots: int, payload_shape: Tuple[int, ...] = ()) -> CQ:
    z = lambda: jnp.zeros((n_slots,), jnp.int32)
    return CQ(status=z(), value=z(), latency=z(),
              payload=jnp.zeros((n_slots,) + tuple(payload_shape),
                                jnp.float32))


def make_sharded_cq(n_shards: int, n_slots: int,
                    payload_shape: Tuple[int, ...] = ()) -> CQ:
    cq = make_cq(n_slots, payload_shape)
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (n_shards,) + (1,) * x.ndim), cq)


# ---------------------------------------------------------------------------
# the opcode-dispatched step
# ---------------------------------------------------------------------------
def _apply_vol_ops(states, page_revs, batch: SQE, ok, value, status):
    """Apply the SNAPSHOT/CLONE/UNMAP/DELETE tail in lane order.

    A ``lax.scan`` over a ``CTRL_TAIL``-lane window keeps submission-order
    semantics with a fixed trace structure; each lane is a masked
    ``lax.switch`` over op class (non-control and padding lanes take the
    NOOP branch). The window is a dynamic slice anchored at the first
    control lane — control lanes are contiguous (drain policy) and capped
    at CTRL_TAIL per batch, so the window covers every one of them without
    scanning the whole batch. Control ops apply to EVERY replica slice,
    healthy or not — the lock-step convention of the sharded group's
    mirrored control path, which lets rebuild copy metadata wholesale
    instead of replaying control ops. The per-replica watermark arrays ride
    the scan carry because CLONE must copy the source's row
    (``transport.clone_page_rev`` — delta rebuild would otherwise miss
    extents reachable only through the clone's table)."""
    b_n = batch.op.shape[0]
    k = min(CTRL_TAIL, b_n)
    is_vol = ok & (batch.op >= OP_SNAPSHOT) & (batch.op <= OP_DELETE)
    start = jnp.clip(jnp.argmax(is_vol), 0, b_n - k)
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, k)
    op_w, vol_w, page_w = sl(batch.op), sl(batch.volume), sl(batch.page)
    is_vol_w = sl(is_vol)       # data lanes caught by edge-clamping: masked

    def lane(carry, xs):
        op, vol, page, live = xs
        branch = jnp.where(live, op - OP_SNAPSHOT + 1, 0)

        def b_noop(c):
            return c, jnp.int32(-1)

        def each(fn):
            def b(c):
                sts, prs = c
                outs = [fn(st) for st in sts]
                return (tuple(st for st, _ in outs), prs), outs[0][1]
            return b

        def b_clone(c):
            sts, prs = c
            outs = [dbs.clone(st, vol) for st in sts]
            # each replica clones its OWN state (lock-step ids) and its
            # watermark row inherits the source's
            prs = tuple(clone_page_rev(pr, vol, vid)
                        for pr, (_, vid) in zip(prs, outs))
            return (tuple(st for st, _ in outs), prs), outs[0][1]

        b_snap = each(lambda st: dbs.snapshot(st, vol))
        b_unmap = each(
            lambda st: (dbs.unmap(st, vol, page[None]), jnp.int32(-1)))
        b_delete = each(
            lambda st: (dbs.delete_volume(st, vol), jnp.int32(-1)))
        c, val = jax.lax.switch(
            branch, [b_noop, b_snap, b_clone, b_unmap, b_delete], carry)
        return c, val

    (states, page_revs), vals = jax.lax.scan(
        lane, (states, page_revs), (op_w, vol_w, page_w, is_vol_w))
    value = jax.lax.dynamic_update_slice_in_dim(
        value, jnp.where(is_vol_w, vals, sl(value)), start, axis=0)
    # snapshot/clone report failure (table full / dead volume) through a
    # negative result id; unmap/delete are unconditional no-op-on-miss
    signals = is_vol_w & ((op_w == OP_SNAPSHOT) | (op_w == OP_CLONE))
    status = jax.lax.dynamic_update_slice_in_dim(
        status, jnp.where(signals & (vals < 0), ST_ERR, sl(status)),
        start, axis=0)
    return states, page_revs, value, status


def _apply_repl_ops(states, pools, page_revs, healthy, batch: SQE, ok,
                    status):
    """Apply the (at most one — the frontend closes the batch on it)
    FAIL/REBUILD lane against the traced health mask.

    FAIL flips the mask bit unless the target is the shard's last healthy
    replica (→ ST_LAST, mask untouched: an all-failed shard would silently
    ack writes and fabricate zero reads). REBUILD copies the most-up-to-date
    healthy replica's state+pool+watermarks into the target and re-marks it
    healthy (in-band rebuild is a whole-copy — it happens inside one
    program; the host-side *streamed delta* rebuild lives in
    core/replication.py); rebuilding a healthy replica is a protocol error
    (→ ST_HEALTHY). All of it is traced — in-band failover never leaves the
    compiled program."""
    n_rep = len(states)
    is_repl = ok & ((batch.op == OP_FAIL) | (batch.op == OP_REBUILD))
    has = jnp.any(is_repl)
    lane = jnp.argmax(is_repl)                   # first repl lane
    op = batch.op[lane]
    arg = batch.block[lane]                      # replica index rides block
    valid = has & (arg >= 0) & (arg < n_rep)
    tgt = jnp.clip(arg, 0, n_rep - 1)
    h = healthy
    n_h = jnp.sum(h.astype(jnp.int32))
    tgt_h = h[tgt]
    do_fail = valid & (op == OP_FAIL) & (~tgt_h | (n_h > 1))
    rej_last = valid & (op == OP_FAIL) & tgt_h & (n_h <= 1)
    do_rebuild = valid & (op == OP_REBUILD) & ~tgt_h & (n_h >= 1)
    rej_healthy = valid & (op == OP_REBUILD) & tgt_h

    # donor = healthy replica with the highest metadata revision
    revs = jnp.stack([st.revision for st in states])
    donor = jnp.argmax(jnp.where(h, revs, jnp.int32(-(2 ** 31) + 1)))

    def pick(leaves):                            # donor leaf, traced index
        out = leaves[0]
        for r in range(1, n_rep):
            out = jnp.where(donor == r, leaves[r], out)
        return out

    donor_state = jax.tree.map(lambda *ls: pick(ls), *states)
    states = tuple(
        jax.tree.map(lambda cur, d: jnp.where(do_rebuild & (tgt == r), d, cur),
                     st, donor_state)
        for r, st in enumerate(states))
    if pools:
        donor_pool = pick(pools)
        pools = tuple(
            jnp.where(do_rebuild & (tgt == r), donor_pool, p)
            for r, p in enumerate(pools))
    if page_revs:
        donor_pr = pick(page_revs)
        page_revs = tuple(
            jnp.where(do_rebuild & (tgt == r), donor_pr, p)
            for r, p in enumerate(page_revs))

    new_tgt = jnp.where(do_fail, False, jnp.where(do_rebuild, True, tgt_h))
    healthy = h.at[tgt].set(jnp.where(has, new_tgt, tgt_h))
    lane_status = jnp.where(
        rej_last, ST_LAST,
        jnp.where(rej_healthy, ST_HEALTHY,
                  jnp.where(do_fail | do_rebuild, ST_OK, ST_ERR)))
    b_n = batch.op.shape[0]
    status = jnp.where((jnp.arange(b_n) == lane) & has, lane_status, status)
    return states, pools, page_revs, healthy, status


def ring_step_core(table: slots.SlotTable, cq: CQ,
                   states: Tuple[dbs.DBSState, ...],
                   pools: Tuple[jnp.ndarray, ...],
                   page_revs: Tuple[jnp.ndarray, ...], batch: SQE,
                   rr: jnp.ndarray, healthy: jnp.ndarray, *,
                   classes: Tuple[str, ...], null_backend: bool = False,
                   null_storage: bool = False, kernel: str = "pallas",
                   compute_tail: int = COMPUTE_TAIL):
    """One ring iteration, un-jitted (vmap-safe over a leading shard axis).

    ``classes`` (static) names the opcode classes present in this batch
    ("read" / "write" / "compute" / "vol" / "repl" / "noop") — the host
    knows them at drain time, so each signature compiles its own program
    and a pure-data batch pays exactly the fused step's cost plus the CQE
    scatter. ``page_revs`` are the per-replica last-write watermarks
    (``transport.stamp_page_rev``), stamped with the write phase and copied
    whole on in-band REBUILD. Returns
    ``(table', cq', states', pools', page_revs', healthy', CQEView)``.
    """
    table, ids, ok = slots.transact(table, batch.want, batch.volume,
                                    batch.queue, batch.step,
                                    opcodes=batch.op, fnids=batch.fn)
    b_n = batch.op.shape[0]
    status = jnp.zeros((b_n,), jnp.int32)
    value = jnp.full((b_n,), -1, jnp.int32)
    reads = jnp.zeros_like(batch.payload)

    if not null_backend and states:
        if "write" in classes:                   # mirrored CoW data phase
            wmask = ok & (batch.op == OP_WRITE)
            bits = jnp.uint32(1) << batch.block.astype(jnp.uint32)
            out_states, out_pools, out_prs = [], [], []
            for i, st in enumerate(states):
                st, wops = dbs.write_pages(st, batch.volume, batch.page,
                                           bits, wmask & healthy[i])
                if not null_storage:
                    out_pools.append(_cow_apply(pools[i], wops,
                                                batch.payload, batch.block,
                                                kernel))
                    out_prs.append(stamp_page_rev(
                        page_revs[i], batch.volume, batch.page, wops.ok,
                        st.revision))
                out_states.append(st)
            states = tuple(out_states)
            if not null_storage:
                pools = tuple(out_pools)
                page_revs = tuple(out_prs)
        if "read" in classes and not null_storage:
            reads = _rr_gather(states, pools, batch, rr,
                               ok & (batch.op == OP_READ), reads, healthy,
                               kernel)
        if "compute" in classes and not null_storage:
            # in-band storage functions: between data and control (the drain
            # never mixes compute with control lanes, so this phase and the
            # control tail are mutually exclusive per batch)
            states, pools, page_revs, value, status, reads = (
                apply_compute_ops(states, pools, page_revs, healthy, batch,
                                  ok & (batch.op == OP_COMPUTE), value,
                                  status, reads, kernel=kernel,
                                  tail=compute_tail))
        if "vol" in classes:                     # lane-ordered control tail
            states, page_revs, value, status = _apply_vol_ops(
                states, page_revs, batch, ok, value, status)
        if "repl" in classes:                    # in-band fail/rebuild
            states, pools, page_revs, healthy, status = _apply_repl_ops(
                states, pools, page_revs, healthy, batch, ok, status)

    latency = (batch.step - batch.tick + 1).astype(jnp.int32)
    # CQE emission: one record per admitted lane, at its slot id
    idx = jnp.where(ok, ids, cq.status.shape[0])
    cq = CQ(status=cq.status.at[idx].set(status, mode="drop"),
            value=cq.value.at[idx].set(value, mode="drop"),
            latency=cq.latency.at[idx].set(latency, mode="drop"),
            payload=cq.payload.at[idx].set(reads, mode="drop"))
    # mirror the status into the Messages Array's status lane
    table = dataclasses.replace(
        table, status=table.status.at[idx].set(status, mode="drop"))
    view = CQEView(ok=ok, status=status, value=value, latency=latency,
                   reads=reads)
    return table, cq, states, pools, page_revs, healthy, view


def vmap_shards(fn, n_shards: int):
    """Map ``fn`` over a leading (S,) shard axis. At S=1 the program runs
    unmapped (squeeze/unsqueeze fuse away): vmap's batched-scatter lowering
    only costs there — the same trick EnginePool uses (core/sharded.py)."""
    if n_shards == 1:
        def unmapped(*args):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            out = fn(*(sq(a) for a in args))
            return jax.tree.map(lambda x: x[None], out)
        return unmapped
    return lambda *args: jax.vmap(fn)(*args)


# ---------------------------------------------------------------------------
# RingFrontend — THE drain protocol (legacy frontends adapt over it)
# ---------------------------------------------------------------------------
class RingFrontend:
    """S shards × Q admission queues feeding one opcode-tagged SQE drain.

    Requests hash to shards by volume (``volume % S``; replica-control ops
    carry an explicit ``Request.shard``), then to a queue by request id.
    ``drain_ring`` pulls up to ``batch`` requests per shard under the
    batch-ordering contract (module docstring): once a control op is
    drained only further control ops may join that shard's batch, and a
    replica op closes it — so "data phase, then control tail in lane order"
    reproduces submission order exactly.

    The submission tick is stamped on ``Request.tick`` at submit (requeues
    keep the original tick), giving the CQE its latency in pump ticks.
    """

    def __init__(self, n_shards: int, n_queues: int, n_slots: int,
                 batch: int = 64, with_table: bool = True,
                 compute_tail: int = COMPUTE_TAIL):
        self.n_shards = n_shards
        self.n_queues = n_queues
        self.n_slots = n_slots
        self.batch = batch
        self.compute_tail = compute_tail
        self.queues: List[List[collections.deque]] = [
            [collections.deque() for _ in range(n_queues)]
            for _ in range(n_shards)]
        self.table = (slots.make_sharded_table(n_shards, n_slots)
                      if with_table else None)
        self.step: List[int] = [0] * n_shards

    def shard_of(self, req) -> int:
        if getattr(req, "shard", None) is not None:
            return req.shard % self.n_shards
        return req.volume % self.n_shards if req.volume >= 0 else 0

    def submit(self, req) -> None:
        if req.kind not in KIND_TO_OP:
            raise ValueError(f"unknown request kind {req.kind!r} "
                             f"(expected one of {sorted(KIND_TO_OP)})")
        if req.kind == "compute":
            # resolve name -> registry id at the submission boundary (the
            # uniform unknown-name ValueError fires here, not at drain time)
            req.fnid = compute_registry.storage_fn_id(req.fn)
        s = self.shard_of(req)
        req.tick = self.step[s]
        self.queues[s][req.req_id % self.n_queues].append(req)

    def requeue(self, req) -> None:
        """Put a not-admitted request back at the front of its queue (its
        original submission tick is kept, so latency keeps counting)."""
        self.queues[self.shard_of(req)][req.req_id % self.n_queues].appendleft(
            req)

    def requeue_all(self, reqs: Sequence[Any]) -> None:
        """Requeue a completion's not-admitted lanes, back-to-front:
        admission starves the batch SUFFIX (prefix-sum compaction), and an
        appendleft in forward order would reverse the starved lanes'
        relative order in their queues — the ordering contract must survive
        starvation. Every completer funnels through here."""
        for req in reversed(list(reqs)):
            self.requeue(req)

    def depth(self) -> int:
        return sum(len(q) for qs in self.queues for q in qs)

    def _drain_shard(self, s: int, limit: int) -> List[Any]:
        """Round-robin drain of one shard under the batch-ordering contract:
        batch rank is data < compute < control, and the drain cuts on EVERY
        rank change (so compute lanes are contiguous, follow all data lanes,
        and never share a batch with control lanes — the step applies
        data, then the compute window, then the control tail, in lane
        order = submission order). A replica-control op closes the batch;
        at most CTRL_TAIL control / ``compute_tail`` compute ops per batch
        (the step's in-program scan windows), and a *writing* storage
        function closes the compute window so the compute phase commits at
        most one CoW write.

        The drain never exceeds ``n_slots``: with the transact lifecycle a
        pump starts with every slot free, so a batch that fits the slot
        count cannot starve — which is what lets the *pipelined* drain
        launch iteration N+1 before N's completion without a starved
        suffix of N re-entering the queues behind N+1 (out of submission
        order)."""
        reqs: List[Any] = []
        ctrl_seen = False
        comp_seen = False
        comp_closed = False
        n_ctrl = 0
        n_comp = 0
        limit = min(limit, self.n_slots)
        tail = min(CTRL_TAIL, limit)
        ctail = min(self.compute_tail, limit)
        qs = [q for q in self.queues[s] if q]
        while qs and len(reqs) < limit:
            for q in list(qs):
                if not q:
                    qs.remove(q)
                    continue
                k = KIND_CLASS[q[0].kind]
                if ctrl_seen and k not in ("vol", "repl"):
                    return reqs                  # rank downgrade: cut
                if comp_seen and k not in ("compute", "vol", "repl"):
                    return reqs                  # data after compute: cut
                if comp_seen and k in ("vol", "repl"):
                    return reqs                  # compute never joins control
                if k in ("vol", "repl") and n_ctrl >= tail:
                    return reqs                  # control window full
                if k == "compute" and (comp_closed or n_comp >= ctail):
                    return reqs                  # compute window closed/full
                r = q.popleft()
                # provisional latency in pump ticks, stamped at drain (the
                # unified semantics across every comm mode — requeued lanes
                # are re-stamped on their next drain, and the ring path's CQE
                # overwrites with the identical in-program value)
                r.latency = self.step[s] - getattr(r, "tick", 0) + 1
                reqs.append(r)
                if k in ("vol", "repl"):
                    ctrl_seen = True
                    n_ctrl += 1
                if k == "compute":
                    comp_seen = True
                    n_comp += 1
                    if compute_registry.fn_writes(getattr(r, "fnid", 0)):
                        comp_closed = True
                if k == "repl" or len(reqs) >= limit:
                    return reqs
        return reqs

    def _stage(self, payload_shape: Tuple[int, ...] = ()):
        """Drain every shard and fill host-side numpy lane buffers (ONE
        device transfer per leaf happens in the caller). Returns
        (per-shard request lists, staged dict | None, opcode classes)."""
        drained = [self._drain_shard(s, self.batch)
                   for s in range(self.n_shards)]
        if not any(drained):
            return [], None, set()
        s_n, b_n = self.n_shards, self.batch
        stage = {"want": np.zeros((s_n, b_n), bool),
                 "payload": np.zeros((s_n, b_n) + tuple(payload_shape),
                                     np.float32),
                 "step": np.zeros((s_n,), np.int32)}
        for k in ("op", "volume", "page", "block", "queue", "tick", "fn",
                  "arg"):
            stage[k] = np.zeros((s_n, b_n), np.int32)
        classes: Set[str] = set()
        for s, reqs in enumerate(drained):
            stage["step"][s] = self.step[s]
            if reqs:
                self.step[s] += 1
            for i, r in enumerate(reqs):
                classes.add(KIND_CLASS[r.kind])
                stage["want"][s, i] = True
                stage["op"][s, i] = KIND_TO_OP[r.kind]
                stage["volume"][s, i] = (r.volume // s_n if r.volume >= 0
                                         else -1)
                stage["page"][s, i] = r.page
                stage["block"][s, i] = r.block
                stage["queue"][s, i] = r.req_id % self.n_queues
                stage["tick"][s, i] = getattr(r, "tick", 0)
                stage["fn"][s, i] = getattr(r, "fnid", 0)
                stage["arg"][s, i] = getattr(r, "arg", 0)
                if r.payload is not None:
                    stage["payload"][s, i] = np.asarray(r.payload)
        return drained, stage, classes

    def drain_ring(self, payload_shape: Tuple[int, ...] = ()):
        """The unified drain: one stacked (S, B, ...) SQE batch per pump.
        Returns (per-shard request lists, SQE | None, opcode classes)."""
        drained, st, classes = self._stage(payload_shape)
        if st is None:
            return [], None, set()
        sqe = SQE(want=jnp.asarray(st["want"]), op=jnp.asarray(st["op"]),
                  volume=jnp.asarray(st["volume"]),
                  page=jnp.asarray(st["page"]),
                  block=jnp.asarray(st["block"]),
                  payload=jnp.asarray(st["payload"]),
                  queue=jnp.asarray(st["queue"]),
                  tick=jnp.asarray(st["tick"]),
                  fn=jnp.asarray(st["fn"]), arg=jnp.asarray(st["arg"]),
                  step=jnp.asarray(st["step"]))
        return drained, sqe, classes


# ---------------------------------------------------------------------------
# RingEngine — comm="ring": S shards, one opcode-dispatched program per pump
# ---------------------------------------------------------------------------
@dataclass
class PendingRing:
    """Completion handle from ``pump_async``: the per-lane CQE view (device
    futures) plus the host-side request lists that rode the batch."""
    reqs: List[List[Any]]
    view: CQEView


class RingEngine(ControlDispatch):
    """S engine shards behind the opcode-dispatched ring step.

    API-compatible with ``EnginePool`` (create_volume/snapshot/submit/pump/
    pump_async/drain/completed/read_volume), plus in-band control: snapshot,
    clone, unmap, delete_volume, fail, rebuild are *ring submissions* that
    execute inside the same jitted step as foreground I/O. One compiled
    program exists per (batch geometry, opcode-class signature);
    ``trace_counts``/``dispatches`` pin that contract in tests.

    Registered as ``backend="ring"`` in core/backends.py — the only backend
    whose submission path (``data_kinds``) accepts control opcodes.
    """

    is_pool = True
    data_kinds = frozenset(KIND_TO_OP)

    def __init__(self, cfg):
        if cfg.storage != "dbs":
            raise ValueError("RingEngine requires storage='dbs'")
        s = getattr(cfg, "n_shards", 1)
        if s < 1:
            raise ValueError(f"n_shards must be >= 1, got {s}")
        self.cfg = cfg
        self.n_shards = s
        self._compute_tail = getattr(cfg, "compute_tail", COMPUTE_TAIL)
        self.frontend = RingFrontend(s, cfg.n_queues, cfg.n_slots, cfg.batch,
                                     compute_tail=self._compute_tail)
        if cfg.null_backend:
            self.backend = None
        else:
            self.backend = ShardedReplicaGroup(
                s, cfg.n_replicas, cfg.n_extents, cfg.max_volumes,
                cfg.max_pages, cfg.page_blocks, cfg.payload_shape,
                null_storage=cfg.null_storage, transport=cfg.transport,
                write_policy=cfg.write_policy, read_policy=cfg.read_policy,
                transport_opts=cfg.transport_opts)
        self.cq = make_sharded_cq(s, cfg.n_slots, cfg.payload_shape)
        self._cow = (cfg.cow if cfg.cow != "auto" else
                     ("pallas" if jax.default_backend() == "tpu" else "ref"))
        from repro.kernels.dbs.registry import resolve_kernel_name
        self._kernel = resolve_kernel_name(cfg)
        self._vol_rr = 0
        self._ctl_seq = 1 << 30      # control-op request ids (own queue slot)
        self.completed = 0
        self.dispatches = 0
        self.trace_counts: Dict[Tuple[str, ...], int] = {}
        self._steps: Dict[Tuple[str, ...], Any] = {}

    # ------------------------------------------------------------ programs
    @staticmethod
    def _canon(classes: Set[str]) -> Tuple[str, ...]:
        """Canonical program signature for a drained batch. Each tier
        includes the cheaper ones (masked lanes are inert), so at most
        SEVEN programs exist per batch geometry — a mixed workload can't
        trace a program per opcode combination, and heavyweight machinery
        (the control-tail scan, the rebuild pool copy, the storage-function
        switch) is only in the programs that need it. Compute gets its OWN
        tier (the drain never mixes compute with control lanes *within a
        shard*), so the control programs never pay for the full-volume
        content gather — but ``classes`` merges across shards, and one
        pump can drain control on shard 0 while shard 1 drains computes,
        so the control tiers gain compute-including variants for exactly
        that cross-shard mix."""
        if "repl" in classes:
            base = ("read", "repl", "vol", "write")
        elif "vol" in classes:
            base = ("read", "vol", "write")
        elif "compute" in classes:
            return ("compute", "read", "write")
        elif "write" in classes:
            return ("read", "write")
        else:
            return ("read",)
        if "compute" in classes:
            return ("compute",) + base
        return base

    def _get_step(self, classes: Set[str]):
        key = self._canon(classes)
        cache_key = key
        if "compute" in key:
            # compute programs bake the registry's branch table in: a
            # storage fn registered after first compile must retrace
            cache_key = key + (f"sfns:{compute_registry.registry_version()}",)
        if cache_key in self._steps:
            return self._steps[cache_key], key
        self.trace_counts.setdefault(cache_key, 0)
        read_only = key == ("read",)
        core = partial(ring_step_core, classes=key,
                       null_backend=self.cfg.null_backend,
                       null_storage=self.cfg.null_storage,
                       kernel=self._kernel,
                       compute_tail=self._compute_tail)
        mapped = vmap_shards(core, self.n_shards)

        if read_only:
            # replica state, pools, watermarks and health are inputs only —
            # returning them would materialize pass-through copies
            # (fused_step_read's rationale); only the table and the CQ
            # round-trip.
            def stepped(table, cq, states, pools, page_revs, batch, rr,
                        healthy):
                self.trace_counts[cache_key] += 1
                table, cq, _, _, _, _, view = mapped(
                    table, cq, states, pools, page_revs, batch, rr, healthy)
                return table, cq, view
            fn = jax.jit(stepped, donate_argnums=(0, 1))
        else:
            def stepped(table, cq, states, pools, page_revs, batch, rr,
                        healthy):
                self.trace_counts[cache_key] += 1
                return mapped(table, cq, states, pools, page_revs, batch,
                              rr, healthy)
            fn = jax.jit(stepped, donate_argnums=(0, 1, 2, 3, 4))
        self._steps[cache_key] = fn
        return fn, key

    # ------------------------------------------------------------ volumes
    def create_volume(self) -> int:
        """Create a volume on the next shard (round-robin placement);
        global id = local * S + shard, as in EnginePool."""
        shard = self._vol_rr % self.n_shards
        self._vol_rr += 1
        local = 0 if self.backend is None else self.backend.create_volume(shard)
        return local * self.n_shards + shard

    def read_volume(self, vol: int, pages, block_offsets):
        """Host read path for verification (the pump serves reads in-band)."""
        if self.backend is None:
            raise RuntimeError("null backend holds no volumes")
        return self.backend.read(vol % self.n_shards, vol // self.n_shards,
                                 pages, block_offsets)

    # ----------------------------------------------------- in-band control
    def _control(self, kind: str, *, volume: int = -1, page: int = 0,
                 block: int = 0, shard: Optional[int] = None):
        """Submit one control SQE and drain to completion — the synchronous
        convenience wrapper over the in-band path (callers that want control
        ops interleaved with foreground traffic submit Requests directly).

        Matches the host-side controllers' error surface: replica-protocol
        violations raise (like ``ShardedReplicaGroup.fail/rebuild``), while
        failed snapshot/clone report through a negative result id (like
        ``dbs.snapshot``/``ReplicaGroup.clone`` and ``EnginePool.clone``)."""
        from repro.core.frontend import Request
        r = Request(req_id=self._ctl_seq, kind=kind, volume=volume,
                    page=page, block=block, shard=shard)
        self._ctl_seq += 1
        self.submit(r)
        self.drain()
        if r.status == ST_LAST:
            raise RuntimeError(
                f"replica {block} is shard {shard}'s last healthy replica; "
                "failing it would lose the shard's volumes")
        if r.status == ST_HEALTHY:
            raise ValueError(f"shard {shard} replica {block} is healthy; "
                             "only a failed replica can be rebuilt")
        return r.result

    def snapshot(self, vol: int):
        """Freeze the volume head — as a ring submission. Returns the
        (shard-local) snapshot id, -1 on failure (dead volume / table
        full), like the host-side backends."""
        return self._control("snapshot", volume=vol)

    def clone(self, vol: int) -> int:
        """Fork a volume in-band. Returns the new *global* volume id, -1 on
        failure — the same surface as ``EnginePool.clone``."""
        out = self._control("clone", volume=vol)
        return -1 if out is None or out < 0 else out

    def unmap(self, vol: int, pages: Sequence[int]) -> None:
        """TRIM pages in-band (one SQE per page; they share batches)."""
        from repro.core.frontend import Request
        for p in pages:
            r = Request(req_id=self._ctl_seq, kind="unmap", volume=vol,
                        page=int(p))
            self._ctl_seq += 1
            self.submit(r)
        self.drain()

    def delete_volume(self, vol: int) -> None:
        self._control("delete", volume=vol)

    def fail(self, shard: int, replica: int) -> None:
        """In-band replica failover (raises like the host-side controller
        on protocol violations, from the CQE status)."""
        if self.backend is not None:
            self.backend._check(shard, replica)
        self._control("fail", shard=shard, block=replica)

    def rebuild(self, shard: int, replica: int) -> None:
        if self.backend is not None:
            self.backend._check(shard, replica)
        self._control("rebuild", shard=shard, block=replica)

    # -------------------------------------------------- backend protocol
    @property
    def storage(self):
        """The replica storage behind this backend (core/backends.py)."""
        return self.backend

    def _control_repl(self, kind, shard, replica):
        # in-band FAIL/REBUILD SQEs (ControlDispatch.control routes here)
        fn = self.fail if kind == "fail" else self.rebuild
        return fn(shard, replica)

    def depth(self) -> int:
        return self.frontend.depth()

    # ------------------------------------------------------------- pumping
    def submit(self, req) -> None:
        if req.kind not in self.data_kinds:
            raise ValueError(f"unknown request kind {req.kind!r} "
                             f"(expected one of {sorted(self.data_kinds)})")
        self.frontend.submit(req)

    def pump_async(self) -> Optional[PendingRing]:
        """Admit one opcode-tagged batch per shard and launch the ring step;
        do NOT block. Control lanes execute inside the same program as the
        data lanes — no host dispatch per control op."""
        reqs, batch, classes = self.frontend.drain_ring(
            self.cfg.payload_shape)
        if batch is None:
            return None
        if self.backend is None:
            states, pools, page_revs = (), (), ()
            healthy = jnp.ones((self.n_shards, 1), bool)
            rr = jnp.zeros((self.n_shards,), jnp.int32)
        else:
            states, pools, healthy = self.backend.device_state()
            page_revs = self.backend.device_page_revs()
            rr = self.backend.bump_rr()
        step, key = self._get_step(classes)
        self.dispatches += 1
        read_only = key == ("read",)
        if read_only:
            table, cq, view = step(self.frontend.table, self.cq, states,
                                   pools, page_revs, batch, rr, healthy)
        else:
            table, cq, states, pools, page_revs, healthy, view = step(
                self.frontend.table, self.cq, states, pools, page_revs,
                batch, rr, healthy)
            if self.backend is not None:
                self.backend.set_device_state(states, pools)
                self.backend.set_device_page_revs(page_revs)
                if "repl" in key:
                    # only the repl program can change health; adopting on
                    # every pump would mark the host mirror stale and make
                    # each .healthy access pay a device sync for nothing
                    self.backend.adopt_health(healthy)
        self.frontend.table = table
        self.cq = cq
        return PendingRing(reqs=reqs, view=view)

    def _complete(self, p: PendingRing) -> int:
        """The pump's single host hop: fetch the per-lane CQE view, deliver
        result/status/latency, requeue not-admitted requests."""
        v = p.view
        ok, status, value, latency, reads = jax.device_get(
            (v.ok, v.status, v.value, v.latency, v.reads))
        done = 0
        requeues = []
        for s, shard_reqs in enumerate(p.reqs):
            for i, r in enumerate(shard_reqs):
                if not ok[s][i]:
                    requeues.append(r)
                    continue
                r.status = int(status[s][i])
                r.latency = int(latency[s][i])
                if r.kind == "read":
                    r.result = reads[s, i]
                elif r.kind == "snapshot":
                    r.result = int(value[s][i])
                elif r.kind == "clone":
                    local = int(value[s][i])
                    r.result = (local * self.n_shards + s if local >= 0
                                else -1)
                elif r.kind == "compute":
                    # (scalar result, CQ payload lanes) — blockdev wraps it
                    r.result = (int(value[s][i]), reads[s, i])
                done += 1
        self.frontend.requeue_all(requeues)
        self.completed += done
        return done

    def pump(self) -> int:
        p = self.pump_async()
        return self._complete(p) if p is not None else 0

    def drain(self, max_iters: int = 100_000) -> int:
        """Pipelined drain: launch iteration N+1 before blocking on N
        (EnginePool's double-buffered completion)."""
        total = 0
        pending: Optional[PendingRing] = None
        for _ in range(max_iters):
            nxt = self.pump_async()
            if pending is not None:
                total += self._complete(pending)
            pending = nxt
            if nxt is None and self.frontend.depth() == 0:
                break
        if pending is not None:
            total += self._complete(pending)
        return total
