"""The control-plane verb set shared by every registered backend.

``ControlDispatch`` maps the uniform ``control(kind, ...)`` surface of the
backend protocol (core/backends.py) onto the concrete class's named
methods — ``snapshot``/``clone``/``unmap``/``delete_volume`` for the
volume ops, ``_control_repl`` for the replica ops (``fail``/``rebuild``),
which backends without replicas leave at the raising default. One dispatch
ladder, subclassed five ways, instead of five drifting copies.

Deliberately dependency-free: ring.py, sharded.py, engine.py and
backends.py all mix it in, and any pair of those importing each other at
module level would cycle.
"""
from __future__ import annotations

from typing import Optional

CONTROL_KINDS = ("snapshot", "clone", "unmap", "delete", "fail", "rebuild")


class ControlDispatch:
    """Mixin: the backend protocol's ``control()`` verb dispatch."""

    def control(self, kind: str, *, volume: int = -1, pages=None,
                shard: Optional[int] = None, replica: int = -1):
        """Uniform control-plane dispatch (``backends.Backend.control``):
        in-band ring submissions on the ring backend, host-side calls
        elsewhere — whatever the concrete class's named methods do."""
        if kind == "snapshot":
            return self.snapshot(volume)
        if kind == "clone":
            return self.clone(volume)
        if kind == "unmap":
            return self.unmap(volume, pages if pages is not None else [])
        if kind == "delete":
            return self.delete_volume(volume)
        if kind in ("fail", "rebuild"):
            return self._control_repl(kind, shard, replica)
        raise ValueError(f"unknown control op {kind!r} "
                         f"(expected one of {CONTROL_KINDS})")

    def _control_repl(self, kind: str, shard: Optional[int], replica: int):
        raise ValueError(
            f"{type(self).__name__} has no {kind!r} control op")
