"""The engine: frontend -> controller -> replicas(DBS), per paper Fig. 2/3.

``Engine`` composes the three optimized layers; ``UpstreamEngine`` is the
faithful baseline (single-loop frontend, per-request dispatch, chained
snapshot lookup on reads) so the benchmark ladder can reproduce Tables I/II.

Null-layer switches implement the paper's §IV-A methodology:
  null_backend  — requests complete at the controller (frontend-only run)
  null_storage  — replicas ack without touching DBS (no-storage run)

``comm="fused"`` routes pump() through the single-program fused step
(core/fused.py). Pipeline and ladder columns: docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dbs
from repro.core.frontend import MultiQueueFrontend, Request, UpstreamFrontend
from repro.core.fused import fused_step, fused_step_read
from repro.core.replication import ReplicaGroup


@dataclass
class EngineConfig:
    n_replicas: int = 2
    n_queues: int = 4            # ublk frontend hardware queues
    n_slots: int = 256           # Messages Array size (max in-flight)
    batch: int = 64              # admission batch
    n_extents: int = 1024
    max_volumes: int = 16
    max_pages: int = 256
    page_blocks: int = 32        # paper: 32 blocks per extent
    payload_shape: Tuple[int, ...] = (64,)
    null_backend: bool = False
    null_storage: bool = False
    storage: str = "dbs"         # dbs | chained (sparse-file-style baseline)
    comm: str = "slots"          # slots (Messages Array) | loop (per-request)
                                 # | fused (single-program step, core/fused.py)
                                 # | sharded (vmapped EnginePool, core/sharded.py)
    cow: str = "auto"            # CoW data plane for comm="fused"/"sharded":
                                 # auto (pallas on TPU, ref elsewhere)
                                 # | pallas (force the dbs_copy kernel)
                                 # | ref (apply_write_ops gather/scatter)
    n_shards: int = 1            # engine shards for comm="sharded"


class Engine:
    """Modified engine: multi-queue frontend + slot comm + DBS replicas.

    ``storage="chained"`` swaps the replica backing store for the sparse-
    file-style snapshot-chain store, and ``comm="loop"`` serializes request
    handling through a per-request registry — the two knobs that let the
    benchmark ladder reproduce the paper's cumulative columns.
    """

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        if cfg.comm in ("fused", "sharded") and cfg.storage != "dbs":
            raise ValueError(f"comm={cfg.comm!r} requires storage='dbs'")
        if cfg.cow not in ("auto", "pallas", "ref"):
            raise ValueError(f"unknown cow impl {cfg.cow!r} "
                             "(expected auto | pallas | ref)")
        if cfg.comm == "sharded":
            # the whole engine is the pool: S shards, one vmapped step
            from repro.core.sharded import EnginePool
            self.pool = EnginePool(cfg)
            self.frontend = self.pool.frontend
            self.backend = self.pool.backend
            self._cow = self.pool._cow
            return
        self.pool = None
        self.frontend = MultiQueueFrontend(cfg.n_queues, cfg.n_slots, cfg.batch)
        if cfg.null_backend:
            self.backend = None
        elif cfg.storage == "chained":
            self.backend = ChainedReplicas(cfg)
        else:
            self.backend = ReplicaGroup(
                cfg.n_replicas, cfg.n_extents, cfg.max_volumes, cfg.max_pages,
                cfg.page_blocks, cfg.payload_shape,
                null_storage=cfg.null_storage)
        self._cow = (cfg.cow if cfg.cow != "auto" else
                     ("pallas" if jax.default_backend() == "tpu" else "ref"))
        self.completed = 0

    @property
    def completed(self) -> int:
        return self.pool.completed if self.pool is not None else self._completed

    @completed.setter
    def completed(self, v: int) -> None:
        if self.pool is not None:
            self.pool.completed = v
        else:
            self._completed = v

    def create_volume(self) -> int:
        if self.pool is not None:
            return self.pool.create_volume()
        if self.backend is None:
            return 0
        return self.backend.create_volume()

    def snapshot(self, vol: int) -> None:
        if self.pool is not None:
            self.pool.snapshot(vol)
        elif self.backend is not None:
            self.backend.snapshot(vol)

    def submit(self, req: Request) -> None:
        self.frontend.submit(req)

    def _exec_write_batch(self, rs: List[Request]) -> None:
        if self.cfg.storage == "chained":
            for r in rs:
                self.backend.write(r.volume, [r.page], [r.block],
                                   [r.payload])
            return
        # fixed-shape vectorized write (padded to the admission batch)
        n, cap = len(rs), self.cfg.batch
        pad = cap - (n % cap) if n % cap else 0
        vols = jnp.asarray([r.volume for r in rs] + [0] * pad, jnp.int32)
        pages = jnp.asarray([r.page for r in rs] + [0] * pad, jnp.int32)
        offs = jnp.asarray([r.block for r in rs] + [0] * pad, jnp.int32)
        payload = jnp.stack(
            [r.payload if r.payload is not None
             else jnp.zeros(self.cfg.payload_shape) for r in rs]
            + [jnp.zeros(self.cfg.payload_shape)] * pad)
        mask = jnp.arange(n + pad) < n
        for i in range(0, n + pad, cap):
            s = slice(i, i + cap)
            self.backend.write(vols[s], pages[s], offs[s], payload[s],
                               mask=mask[s])

    def _pump_fused(self) -> int:
        """One controller iteration as ONE compiled program (core/fused.py).

        The host drains raw request arrays in, launches ``fused_step``, and
        performs exactly one ``device_get`` — at completion, to learn which
        lanes were admitted and to carry read payloads out. Between admission
        and completion nothing crosses the host: the slot table, replica
        DBS states and payload pools round-trip device-side.
        """
        reqs, batch = self.frontend.drain_batch(self.cfg.payload_shape)
        if not reqs:
            return 0
        if self.backend is None:
            states, pools = (), ()
            rr = 0
        else:
            states, pools = self.backend.device_state()
            rr = self.backend.bump_rr()
        if any(r.kind == "write" for r in reqs):
            table, states, pools, ok, reads = fused_step(
                self.frontend.table, states, pools, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage, cow=self._cow)
            if self.backend is not None:
                self.backend.set_device_state(states, pools)
        else:
            # read-only batch: replica state is untouched, so dispatch the
            # input-only variant (no pool pass-through copies)
            table, ok, reads = fused_step_read(
                self.frontend.table, states, pools, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage)
        self.frontend.table = table
        # the single host hop: completion flags + completed read payloads
        ok_host, reads_host = jax.device_get((ok, reads))
        done = 0
        for i, r in enumerate(reqs):
            if ok_host[i]:
                if r.kind == "read":
                    r.result = reads_host[i]
                done += 1
            else:
                self.frontend.requeue(r)
        self.completed += done
        return done

    def pump(self) -> int:
        """One controller iteration: admit a batch, execute it against the
        replicas (writes mirrored / reads round-robin), complete the slots.
        Returns the number of completed requests."""
        if self.pool is not None:
            return self.pool.pump()
        if self.cfg.comm == "fused":
            return self._pump_fused()
        slot_ids, reqs = self.frontend.poll_batch()
        if not reqs:
            return 0
        if self.backend is not None:
            if self.cfg.comm == "loop":
                # the single loop function: one request at a time
                for r in reqs:
                    if r.kind == "write":
                        self._exec_write_batch([r])
                    else:
                        self.backend.read(
                            r.volume, jnp.asarray([r.page], jnp.int32),
                            jnp.asarray([r.block], jnp.int32))
            else:
                writes = [r for r in reqs if r.kind == "write"]
                reads = [r for r in reqs if r.kind == "read"]
                if writes:
                    self._exec_write_batch(writes)
                if reads:
                    if self.cfg.storage == "chained":
                        self.backend.read(
                            [r.volume for r in reads],
                            [r.page for r in reads],
                            [r.block for r in reads])
                    else:
                        n, cap = len(reads), self.cfg.batch
                        pad = cap - (n % cap) if n % cap else 0
                        vols = jnp.asarray(
                            [r.volume for r in reads] + [0] * pad, jnp.int32)
                        pages = jnp.asarray(
                            [r.page for r in reads] + [0] * pad, jnp.int32)
                        offs = jnp.asarray(
                            [r.block for r in reads] + [0] * pad, jnp.int32)
                        for i in range(0, n + pad, cap):
                            s = slice(i, i + cap)
                            self.backend.read(vols[s], pages[s], offs[s])
        done = self.frontend.complete(slot_ids)
        self.completed += len(done)
        return len(done)

    def drain(self, max_iters: int = 100_000) -> int:
        if self.pool is not None:
            return self.pool.drain(max_iters)     # pipelined double-buffer
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and self.frontend.depth() == 0:
                break
            n += got
        return n


class ChainedReplicas:
    """ReplicaGroup-shaped adapter over the sparse-file-style ChainedStore
    (the upstream storage scheme behind the modern frontend/comm layers —
    benchmark ladder column '+comm, chained storage')."""

    def __init__(self, cfg: "EngineConfig"):
        self.cfg = cfg
        self.stores = [ChainedStore(cfg.payload_shape)
                       for _ in range(cfg.n_replicas)]
        self._rr = 0

    def create_volume(self) -> int:
        return [s.create_volume() for s in self.stores][0]

    def snapshot(self, vol: int) -> None:
        for s in self.stores:
            s.snapshot(vol)

    def write(self, vol, pages, offs, payload, mask=None) -> None:
        import numpy as _np
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        for s in self.stores:
            for i in range(len(pages)):
                if mask is not None and not bool(mask[i]):
                    continue
                s.write(int(vols[i]), int(pages[i]), int(offs[i]), payload[i])

    def read(self, vol, pages, offs):
        import numpy as _np
        s = self.stores[self._rr % len(self.stores)]
        self._rr += 1
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        if self.cfg.null_storage:
            return None
        return [s.read(int(vols[i]), int(pages[i]), int(offs[i]))
                for i in range(len(pages))]


# ---------------------------------------------------------------------------
# upstream baseline
# ---------------------------------------------------------------------------
class ChainedStore:
    """Sparse-file-style backing store: per-snapshot page maps; reads walk
    the snapshot chain newest->oldest (paper: 'Reads in volumes with many
    snapshots may have to go through the whole chain')."""

    def __init__(self, payload_shape=(64,)):
        self.chains: Dict[int, List[Dict[int, jnp.ndarray]]] = {}
        self.payload_shape = tuple(payload_shape)
        self._next = 0
        self.layers_walked = 0      # instrumentation: chain-walk depth
        self.reads = 0

    def create_volume(self) -> int:
        vid = self._next
        self._next += 1
        self.chains[vid] = [{}]
        return vid

    def snapshot(self, vol: int) -> None:
        self.chains[vol].append({})     # new live layer

    def write(self, vol: int, page: int, block: int, payload) -> None:
        live = self.chains[vol][-1]
        key = (page, block)
        live[key] = payload             # delegated allocation (dict = fs)

    def read(self, vol: int, page: int, block: int):
        self.reads += 1
        for layer in reversed(self.chains[vol]):   # walk the chain
            self.layers_walked += 1
            if (page, block) in layer:
                return layer[(page, block)]
        return None


class UpstreamEngine:
    """TGT-style frontend + loop-function dispatch + chained sparse store."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.frontend = UpstreamFrontend(max_inflight=cfg.n_slots)
        self.stores = (None if cfg.null_backend else
                       [ChainedStore(cfg.payload_shape)
                        for _ in range(cfg.n_replicas)])
        self._rr = 0
        self.completed = 0

    def create_volume(self) -> int:
        if self.stores is None:
            return 0
        return [s.create_volume() for s in self.stores][0]

    def snapshot(self, vol: int) -> None:
        if self.stores is not None:
            for s in self.stores:
                s.snapshot(vol)

    def submit(self, req: Request) -> None:
        self.frontend.submit(req)

    def pump(self) -> int:
        got = self.frontend.poll_one()      # ONE request per loop iteration
        if got is None:
            return 0
        mid, req = got
        if self.stores is not None and not self.cfg.null_storage:
            if req.kind == "write":
                for s in self.stores:       # mirrored, sequential
                    s.write(req.volume, req.page, req.block, req.payload)
            else:
                s = self.stores[self._rr % len(self.stores)]
                self._rr += 1
                s.read(req.volume, req.page, req.block)
        self.frontend.complete(mid)
        self.completed += 1
        return 1

    def drain(self, max_iters: int = 1_000_000) -> int:
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and len(self.frontend) == 0:
                break
            n += got
        return n
