"""The engine: frontend -> controller -> replicas(DBS), per paper Fig. 2/3.

``Engine`` composes the three optimized layers; ``UpstreamEngine`` is the
faithful baseline (single-loop frontend, per-request dispatch, chained
snapshot lookup on reads) so the benchmark ladder can reproduce Tables I/II.

Null-layer switches implement the paper's §IV-A methodology:
  null_backend  — requests complete at the controller (frontend-only run)
  null_storage  — replicas ack without touching DBS (no-storage run)

``comm="fused"`` routes pump() through the single-program fused step
(core/fused.py); ``comm="ring"`` through the opcode-tagged SQ/CQ ring
protocol (core/ring.py), where ``snapshot``/``clone``/``unmap``/
``delete_volume``/``fail``/``rebuild`` become ring submissions executed
in-band with foreground I/O. Pipeline and ladder columns:
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbs
from repro.core.frontend import MultiQueueFrontend, Request, UpstreamFrontend
from repro.core.fused import fused_step, fused_step_read
from repro.core.replication import ReplicaGroup


@dataclass
class EngineConfig:
    n_replicas: int = 2
    n_queues: int = 4            # ublk frontend hardware queues
    n_slots: int = 256           # Messages Array size (max in-flight)
    batch: int = 64              # admission batch
    n_extents: int = 1024
    max_volumes: int = 16
    max_pages: int = 256
    page_blocks: int = 32        # paper: 32 blocks per extent
    payload_shape: Tuple[int, ...] = (64,)
    null_backend: bool = False
    null_storage: bool = False
    storage: str = "dbs"         # dbs | chained (sparse-file-style baseline)
    comm: str = "slots"          # slots (Messages Array) | loop (per-request)
                                 # | fused (single-program step, core/fused.py)
                                 # | sharded (vmapped EnginePool, core/sharded.py)
                                 # | ring (opcode-tagged SQ/CQ, core/ring.py)
    cow: str = "auto"            # CoW data plane for comm="fused"/"sharded":
                                 # auto (pallas on TPU, ref elsewhere)
                                 # | pallas (force the dbs_copy kernel)
                                 # | ref (apply_write_ops gather/scatter)
    n_shards: int = 1            # engine shards for comm="sharded"


class Engine:
    """Modified engine: multi-queue frontend + slot comm + DBS replicas.

    ``storage="chained"`` swaps the replica backing store for the sparse-
    file-style snapshot-chain store, and ``comm="loop"`` serializes request
    handling through a per-request registry — the two knobs that let the
    benchmark ladder reproduce the paper's cumulative columns.
    """

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        if cfg.comm in ("fused", "sharded", "ring") and cfg.storage != "dbs":
            raise ValueError(f"comm={cfg.comm!r} requires storage='dbs'")
        if cfg.cow not in ("auto", "pallas", "ref"):
            raise ValueError(f"unknown cow impl {cfg.cow!r} "
                             "(expected auto | pallas | ref)")
        if cfg.comm in ("sharded", "ring"):
            # the whole engine is the pool: S shards, one vmapped step
            # (comm="ring" adds the opcode-dispatched SQ/CQ protocol, so
            # control ops ride the same program as data I/O)
            if cfg.comm == "ring":
                from repro.core.ring import RingEngine
                self.pool = RingEngine(cfg)
            else:
                from repro.core.sharded import EnginePool
                self.pool = EnginePool(cfg)
            self.frontend = self.pool.frontend
            self.backend = self.pool.backend
            self._cow = self.pool._cow
            return
        self.pool = None
        self.frontend = MultiQueueFrontend(cfg.n_queues, cfg.n_slots, cfg.batch)
        if cfg.null_backend:
            self.backend = None
        elif cfg.storage == "chained":
            self.backend = ChainedReplicas(cfg)
        else:
            self.backend = ReplicaGroup(
                cfg.n_replicas, cfg.n_extents, cfg.max_volumes, cfg.max_pages,
                cfg.page_blocks, cfg.payload_shape,
                null_storage=cfg.null_storage)
        self._cow = (cfg.cow if cfg.cow != "auto" else
                     ("pallas" if jax.default_backend() == "tpu" else "ref"))
        self.completed = 0

    @property
    def completed(self) -> int:
        return self.pool.completed if self.pool is not None else self._completed

    @completed.setter
    def completed(self, v: int) -> None:
        if self.pool is not None:
            self.pool.completed = v
        else:
            self._completed = v

    def create_volume(self) -> int:
        if self.pool is not None:
            return self.pool.create_volume()
        if self.backend is None:
            return 0
        return self.backend.create_volume()

    # -- control plane (comm="ring": in-band ring submissions; other comms:
    # host-side dispatch to the backend) ------------------------------------
    def snapshot(self, vol: int):
        if self.pool is not None:
            return self.pool.snapshot(vol)
        if self.backend is not None:
            return self.backend.snapshot(vol)
        return None

    def clone(self, vol: int) -> int:
        if self.pool is not None:
            return self.pool.clone(vol)
        if self.backend is None:
            return -1
        return self.backend.clone(vol)

    def unmap(self, vol: int, pages) -> None:
        if self.pool is not None:
            self.pool.unmap(vol, pages)
        elif self.backend is not None:
            self.backend.unmap(vol, pages)

    def delete_volume(self, vol: int) -> None:
        if self.pool is not None:
            self.pool.delete_volume(vol)
        elif self.backend is not None:
            self.backend.delete_volume(vol)

    def submit(self, req: Request) -> None:
        if self.cfg.comm != "ring" and req.kind not in ("read", "write"):
            raise ValueError(
                f"kind={req.kind!r} requests need comm='ring' (the opcode-"
                "tagged SQ/CQ path); other comm modes carry data ops only")
        self.frontend.submit(req)

    def _exec_write_batch(self, rs: List[Request]) -> None:
        if self.cfg.storage == "chained":
            for r in rs:
                self.backend.write(r.volume, [r.page], [r.block],
                                   [r.payload])
            return
        # fixed-shape vectorized write (padded to the admission batch)
        n, cap = len(rs), self.cfg.batch
        pad = cap - (n % cap) if n % cap else 0
        vols = jnp.asarray([r.volume for r in rs] + [0] * pad, jnp.int32)
        pages = jnp.asarray([r.page for r in rs] + [0] * pad, jnp.int32)
        offs = jnp.asarray([r.block for r in rs] + [0] * pad, jnp.int32)
        payload = jnp.stack(
            [r.payload if r.payload is not None
             else jnp.zeros(self.cfg.payload_shape) for r in rs]
            + [jnp.zeros(self.cfg.payload_shape)] * pad)
        mask = jnp.arange(n + pad) < n
        for i in range(0, n + pad, cap):
            s = slice(i, i + cap)
            self.backend.write(vols[s], pages[s], offs[s], payload[s],
                               mask=mask[s])

    def _pump_fused(self) -> int:
        """One controller iteration as ONE compiled program (core/fused.py).

        The host drains raw request arrays in, launches ``fused_step``, and
        performs exactly one ``device_get`` — at completion, to learn which
        lanes were admitted and to carry read payloads out. Between admission
        and completion nothing crosses the host: the slot table, replica
        DBS states and payload pools round-trip device-side.
        """
        reqs, batch = self.frontend.drain_batch(self.cfg.payload_shape)
        if not reqs:
            return 0
        if self.backend is None:
            states, pools = (), ()
            rr = 0
        else:
            states, pools = self.backend.device_state()
            rr = self.backend.bump_rr()
        if any(r.kind == "write" for r in reqs):
            table, states, pools, ok, reads = fused_step(
                self.frontend.table, states, pools, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage, cow=self._cow)
            if self.backend is not None:
                self.backend.set_device_state(states, pools)
        else:
            # read-only batch: replica state is untouched, so dispatch the
            # input-only variant (no pool pass-through copies)
            table, ok, reads = fused_step_read(
                self.frontend.table, states, pools, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage)
        self.frontend.table = table
        # the single host hop: completion flags + completed read payloads
        ok_host, reads_host = jax.device_get((ok, reads))
        done = 0
        requeues = []
        for i, r in enumerate(reqs):
            if ok_host[i]:
                r.status = 0
                if r.kind == "read":
                    r.result = reads_host[i]
                done += 1
            else:
                requeues.append(r)
        self.frontend.ring.requeue_all(requeues)
        self.completed += done
        return done

    def pump(self) -> int:
        """One controller iteration: admit a batch, execute it against the
        replicas (writes mirrored / reads round-robin), complete the slots.
        Returns the number of completed requests."""
        if self.pool is not None:
            return self.pool.pump()
        if self.cfg.comm == "fused":
            return self._pump_fused()
        slot_ids, reqs = self.frontend.poll_batch()
        if not reqs:
            return 0
        if self.backend is not None:
            if self.cfg.comm == "loop":
                # the single loop function: one request at a time
                for r in reqs:
                    if r.kind == "write":
                        self._exec_write_batch([r])
                    else:
                        out = self.backend.read(
                            r.volume, jnp.asarray([r.page], jnp.int32),
                            jnp.asarray([r.block], jnp.int32))
                        if out is not None:
                            r.result = np.asarray(jax.device_get(out))[0]
            else:
                writes = [r for r in reqs if r.kind == "write"]
                reads = [r for r in reqs if r.kind == "read"]
                if writes:
                    self._exec_write_batch(writes)
                if reads:
                    if self.cfg.storage == "chained":
                        out = self.backend.read(
                            [r.volume for r in reads],
                            [r.page for r in reads],
                            [r.block for r in reads])
                        if out is not None:
                            for r, v in zip(reads, out):
                                r.result = v
                    else:
                        n, cap = len(reads), self.cfg.batch
                        pad = cap - (n % cap) if n % cap else 0
                        vols = jnp.asarray(
                            [r.volume for r in reads] + [0] * pad, jnp.int32)
                        pages = jnp.asarray(
                            [r.page for r in reads] + [0] * pad, jnp.int32)
                        offs = jnp.asarray(
                            [r.block for r in reads] + [0] * pad, jnp.int32)
                        for i in range(0, n + pad, cap):
                            s = slice(i, i + cap)
                            out = self.backend.read(vols[s], pages[s],
                                                    offs[s])
                            # one fetch per chunk, host indexing after:
                            # per-lane out[j] would put O(B) tiny device
                            # gathers on the pump (and deliver device
                            # arrays where every other comm mode delivers
                            # host numpy)
                            out = np.asarray(jax.device_get(out))
                            for j, r in enumerate(reads[i:i + cap]):
                                r.result = out[j]
        done = self.frontend.complete(slot_ids)
        for r in done:
            # unified completion semantics across comm modes: every
            # completed request carries a status (0 = OK), and reads carry
            # their payload in ``result`` (see ring.CQ / tests/test_ring.py)
            r.status = 0
        self.completed += len(done)
        return len(done)

    def drain(self, max_iters: int = 100_000) -> int:
        if self.pool is not None:
            return self.pool.drain(max_iters)     # pipelined double-buffer
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and self.frontend.depth() == 0:
                break
            n += got
        return n


class ChainedReplicas:
    """ReplicaGroup-shaped adapter over the sparse-file-style ChainedStore
    (the upstream storage scheme behind the modern frontend/comm layers —
    benchmark ladder column '+comm, chained storage')."""

    def __init__(self, cfg: "EngineConfig"):
        self.cfg = cfg
        self.stores = [ChainedStore(cfg.payload_shape)
                       for _ in range(cfg.n_replicas)]
        self._rr = 0

    def _agree(self, ids) -> int:
        """Mirrored control ops must agree on the id every store assigned —
        divergent per-store volume/clone ids would silently route every
        subsequent read/write of that volume to different data on each
        replica (the id returned here names the volume engine-wide)."""
        if len(set(ids)) != 1:
            raise RuntimeError(f"replica stores diverged on id: {ids}")
        return ids[0]

    def create_volume(self) -> int:
        return self._agree([s.create_volume() for s in self.stores])

    def snapshot(self, vol: int) -> None:
        for s in self.stores:
            s.snapshot(vol)

    def clone(self, vol: int) -> int:
        return self._agree([s.clone(vol) for s in self.stores])

    def unmap(self, vol: int, pages) -> None:
        for s in self.stores:
            for p in pages:
                s.unmap(vol, int(p))

    def delete_volume(self, vol: int) -> None:
        for s in self.stores:
            s.delete_volume(vol)

    def write(self, vol, pages, offs, payload, mask=None) -> None:
        import numpy as _np
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        for s in self.stores:
            for i in range(len(pages)):
                if mask is not None and not bool(mask[i]):
                    continue
                s.write(int(vols[i]), int(pages[i]), int(offs[i]), payload[i])

    def read(self, vol, pages, offs):
        import numpy as _np
        if self.cfg.null_storage:
            # no store serves anything: do NOT advance the rr cursor — the
            # layer-cut row must not skew the read distribution the real
            # stores would see (ReplicaGroup.read holds the same contract)
            return None
        s = self.stores[self._rr % len(self.stores)]
        self._rr += 1
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        return [s.read(int(vols[i]), int(pages[i]), int(offs[i]))
                for i in range(len(pages))]


# ---------------------------------------------------------------------------
# upstream baseline
# ---------------------------------------------------------------------------
class ChainedStore:
    """Sparse-file-style backing store: per-snapshot page maps; reads walk
    the snapshot chain newest->oldest (paper: 'Reads in volumes with many
    snapshots may have to go through the whole chain')."""

    def __init__(self, payload_shape=(64,)):
        self.chains: Dict[int, List[Dict[int, jnp.ndarray]]] = {}
        self.payload_shape = tuple(payload_shape)
        self._next = 0
        self.layers_walked = 0      # instrumentation: chain-walk depth
        self.reads = 0

    def create_volume(self) -> int:
        vid = self._next
        self._next += 1
        self.chains[vid] = [{}]
        return vid

    # control ops are no-op-on-miss (clone: -1), like the DBS path they are
    # compared against — a deleted/unknown volume must not diverge the
    # reference baseline into a KeyError where dbs completes harmlessly
    def snapshot(self, vol: int) -> None:
        if vol in self.chains:
            self.chains[vol].append({})     # new live layer

    def clone(self, vol: int) -> int:
        """Fork: freeze src (snapshot), share its frozen layers (the dicts
        themselves — CoW at layer granularity), own a fresh live layer."""
        if vol not in self.chains:
            return -1
        self.snapshot(vol)
        vid = self._next
        self._next += 1
        self.chains[vid] = list(self.chains[vol][:-1]) + [{}]
        return vid

    def unmap(self, vol: int, page: int) -> None:
        """TRIM a page: a tombstone in the live layer shadows older layers;
        same-layer writes to the page are dropped (trim-after-write wins,
        and a later write re-creates the key, so write-after-trim wins)."""
        if vol not in self.chains:
            return
        live = self.chains[vol][-1]
        for key in [k for k in live if k[0] == page]:
            del live[key]
        live[("TRIM", page)] = True

    def delete_volume(self, vol: int) -> None:
        self.chains.pop(vol, None)      # clones keep their shared layers

    def write(self, vol: int, page: int, block: int, payload) -> None:
        live = self.chains[vol][-1]
        key = (page, block)
        live[key] = payload             # delegated allocation (dict = fs)

    def read(self, vol: int, page: int, block: int):
        self.reads += 1
        for layer in reversed(self.chains.get(vol, ())):   # walk the chain
            self.layers_walked += 1
            if (page, block) in layer:
                return layer[(page, block)]
            if ("TRIM", page) in layer:
                return None             # unmapped above any older data
        return None


class UpstreamEngine:
    """TGT-style frontend + loop-function dispatch + chained sparse store."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.frontend = UpstreamFrontend(max_inflight=cfg.n_slots)
        self.stores = (None if cfg.null_backend else
                       [ChainedStore(cfg.payload_shape)
                        for _ in range(cfg.n_replicas)])
        self._rr = 0
        self.completed = 0

    def create_volume(self) -> int:
        if self.stores is None:
            return 0
        ids = [s.create_volume() for s in self.stores]
        if len(set(ids)) != 1:          # same hazard as ChainedReplicas
            raise RuntimeError(f"replica stores diverged on id: {ids}")
        return ids[0]

    def snapshot(self, vol: int) -> None:
        if self.stores is not None:
            for s in self.stores:
                s.snapshot(vol)

    def submit(self, req: Request) -> None:
        self.frontend.submit(req)

    def pump(self) -> int:
        got = self.frontend.poll_one()      # ONE request per loop iteration
        if got is None:
            return 0
        mid, req = got
        if self.stores is not None and not self.cfg.null_storage:
            if req.kind == "write":
                for s in self.stores:       # mirrored, sequential
                    s.write(req.volume, req.page, req.block, req.payload)
            else:
                s = self.stores[self._rr % len(self.stores)]
                self._rr += 1
                req.result = s.read(req.volume, req.page, req.block)
        self.frontend.complete(mid)
        req.status = 0
        self.completed += 1
        return 1

    def drain(self, max_iters: int = 1_000_000) -> int:
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and len(self.frontend) == 0:
                break
            n += got
        return n
