"""The engine façade + the upstream baseline, per paper Fig. 2/3.

``Engine`` is a THIN FAÇADE over the backend registry (core/backends.py):
``EngineConfig.comm`` names a registered backend (loop | slots | fused |
sharded | ring | upstream | host), ``make_backend`` builds it, and every
engine method delegates — there is no comm string branching here anymore.
The public block-device API (core/blockdev.py ``VolumeManager``) drives the
same registry with byte-addressed async I/O; ``Engine`` keeps the
request-level surface alive for the ladder and the legacy tests.

``UpstreamEngine`` is the faithful baseline (single-loop frontend,
per-request dispatch, chained snapshot lookup on reads) so the benchmark
ladder can reproduce Tables I/II; it also satisfies the backend protocol
(registered as ``"upstream"``).

Null-layer switches implement the paper's §IV-A methodology:
  null_backend  — requests complete at the controller (frontend-only run)
  null_storage  — replicas ack without touching DBS (no-storage run)

Pipeline and ladder columns: docs/ARCHITECTURE.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.control import ControlDispatch
from repro.core.frontend import Request, UpstreamFrontend


@dataclass
class EngineConfig:
    n_replicas: int = 2
    n_queues: int = 4            # ublk frontend hardware queues
    n_slots: int = 256           # Messages Array size (max in-flight)
    batch: int = 64              # admission batch
    n_extents: int = 1024
    max_volumes: int = 16
    max_pages: int = 256
    page_blocks: int = 32        # paper: 32 blocks per extent
    payload_shape: Tuple[int, ...] = (64,)
    null_backend: bool = False
    null_storage: bool = False
    storage: str = "dbs"         # dbs | chained (sparse-file-style baseline)
    comm: str = "slots"          # a REGISTERED BACKEND name (core/backends):
                                 # slots (Messages Array) | loop (per-request)
                                 # | fused (single-program step, core/fused.py)
                                 # | sharded (vmapped EnginePool, core/sharded.py)
                                 # | ring (opcode-tagged SQ/CQ, core/ring.py)
                                 # | upstream (TGT-style baseline)
                                 # | host (sequential host-state oracle)
    cow: str = "auto"            # LEGACY data-plane axis (pre-registry):
                                 # auto | pallas | ref — only consulted
                                 # when kernel="auto" (see below)
    kernel: str = "auto"         # DBS data plane for comm="fused"/"sharded"/
                                 # "ring" (a REGISTERED KERNEL, kernels/dbs
                                 # registry): auto (follow cow: pallas on
                                 # TPU, xla elsewhere) | pallas (dbs_rw
                                 # scatter/gather kernels) | xla
                                 # (apply_write_ops reference) | ref
                                 # (pure-jnp row composition) | copy
                                 # (dbs_copy + XLA scatter hybrid)
    n_shards: int = 1            # engine shards for comm="sharded"/"ring"
    compute_tail: int = 8        # max COMPUTE SQEs per ring batch (the
                                 # in-program storage-function scan window,
                                 # core/ring.py / compute/phase.py)
    transport: str = "local"     # controller<->replica wire (a REGISTERED
                                 # TRANSPORT, core/transport.py): local
                                 # (in-process) | device (stacked device
                                 # endpoints) | simnet (simulated network).
                                 # On in-program backends (fused/sharded/
                                 # ring) it carries control+rebuild traffic
    write_policy: str = "all"    # mirrored-write completion: all | quorum
                                 # | async (host-dispatch backends only)
    read_policy: str = "rr"      # serving-replica pick: rr | latency
    transport_opts: Optional[Dict[str, Any]] = None
                                 # per-transport knobs (simnet: latency /
                                 # window / drop / reorder / seed; list
                                 # values are per-replica)
    journal: Any = None          # durability write-ahead journal: a path or
                                 # a repro.durability.journal.Journal. The
                                 # block-device manager group-commits every
                                 # mutating public-API op to it at each pump
                                 # boundary (repro/durability/journal.py)
    tier: Any = None             # cold-extent spill tier (comm="fused" only):
                                 # an int device-extent budget, a
                                 # dict(device_extents=N), or an ExtentTier
                                 # (repro/durability/tier.py)


class Engine:
    """Thin façade over a registered backend (core/backends.py).

    Construction resolves ``cfg.comm`` through the registry; submission,
    pumping and control ops delegate to the backend. Legacy attribute
    surface is preserved: ``.pool`` is the backend itself when it is a
    shard pool (sharded/ring), ``.frontend`` the backend's frontend, and
    ``.backend`` the replica storage (``ReplicaGroup``/
    ``ShardedReplicaGroup``/``ChainedReplicas``/None) — so pre-registry
    call sites (``eng.pool.backend.fail(...)``, ``eng.backend.read(...)``)
    keep working unchanged.
    """

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        if cfg.cow not in ("auto", "pallas", "ref"):
            raise ValueError(f"unknown cow impl {cfg.cow!r} "
                             "(expected auto | pallas | ref)")
        from repro.kernels.dbs.registry import available_kernels
        if cfg.kernel != "auto" and cfg.kernel not in available_kernels():
            raise ValueError(
                f"unknown kernel {cfg.kernel!r} (expected auto | "
                f"{' | '.join(available_kernels())})")
        if cfg.tier is not None and cfg.comm != "fused":
            raise ValueError(
                f"tier= (the cold-extent spill tier) needs comm='fused' — "
                f"the tier's access stamps live in the fused step; got "
                f"comm={cfg.comm!r}")
        from repro.core.backends import make_backend
        self._impl = make_backend(cfg.comm, cfg)
        # the durability journal (repro/durability): resolved here so
        # EngineConfig(journal=path) is enough to enable it; the manager
        # (core/blockdev.py) owns the record buffer and the group commit
        self.journal = None
        self._journal_owned = False
        if cfg.journal is not None:
            from repro.durability.journal import as_journal
            self.journal = as_journal(cfg.journal)
            self._journal_owned = self.journal is not cfg.journal
        self.pool = (self._impl if getattr(self._impl, "is_pool", False)
                     else None)
        self.frontend = self._impl.frontend
        self.backend = self._impl.storage
        self._cow = getattr(self._impl, "_cow", None)
        self._kernel = getattr(self._impl, "_kernel", None)

    @property
    def impl(self):
        """The registered backend instance behind this façade."""
        return self._impl

    @property
    def data_kinds(self):
        """Request kinds the backend's submission boundary accepts."""
        return self._impl.data_kinds

    @property
    def completed(self) -> int:
        return self._impl.completed

    @completed.setter
    def completed(self, v: int) -> None:
        self._impl.completed = v

    def create_volume(self) -> int:
        return self._impl.create_volume()

    # -- control plane: uniform dispatch through the backend's control()
    # (in-band ring submissions on backend="ring"; host-side elsewhere) ------
    def snapshot(self, vol: int):
        return self._impl.control("snapshot", volume=vol)

    def clone(self, vol: int) -> int:
        return self._impl.control("clone", volume=vol)

    def unmap(self, vol: int, pages) -> None:
        self._impl.control("unmap", volume=vol, pages=pages)

    def delete_volume(self, vol: int) -> None:
        self._impl.control("delete", volume=vol)

    def control(self, kind: str, **kw) -> Any:
        """Raw control-plane passthrough (snapshot/clone/unmap/delete/fail/
        rebuild — see ``backends.Backend.control``)."""
        return self._impl.control(kind, **kw)

    def submit(self, req: Request) -> None:
        # validation happens at the backend's submission boundary — BEFORE
        # any enqueue, so mixed-kind batches never lose innocent data
        # requests to a drain-time rejection
        self._impl.submit(req)

    def depth(self) -> int:
        return self._impl.depth()

    def pump(self) -> int:
        """One backend iteration. Returns the number of completions."""
        return self._impl.pump()

    def drain(self, max_iters: int = 100_000) -> int:
        return self._impl.drain(max_iters)


class ChainedReplicas:
    """ReplicaGroup-shaped adapter over the sparse-file-style ChainedStore
    (the upstream storage scheme behind the modern frontend/comm layers —
    benchmark ladder column '+comm, chained storage')."""

    def __init__(self, cfg: "EngineConfig"):
        self.cfg = cfg
        self.stores = [ChainedStore(cfg.payload_shape)
                       for _ in range(cfg.n_replicas)]
        self._rr = 0

    def _agree(self, ids) -> int:
        """Mirrored control ops must agree on the id every store assigned —
        divergent per-store volume/clone ids would silently route every
        subsequent read/write of that volume to different data on each
        replica (the id returned here names the volume engine-wide)."""
        if len(set(ids)) != 1:
            raise RuntimeError(f"replica stores diverged on id: {ids}")
        return ids[0]

    def create_volume(self) -> int:
        return self._agree([s.create_volume() for s in self.stores])

    def snapshot(self, vol: int) -> None:
        for s in self.stores:
            s.snapshot(vol)

    def clone(self, vol: int) -> int:
        return self._agree([s.clone(vol) for s in self.stores])

    def unmap(self, vol: int, pages) -> None:
        for s in self.stores:
            for p in pages:
                s.unmap(vol, int(p))

    def delete_volume(self, vol: int) -> None:
        for s in self.stores:
            s.delete_volume(vol)

    def write(self, vol, pages, offs, payload, mask=None) -> None:
        import numpy as _np
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        for s in self.stores:
            for i in range(len(pages)):
                if mask is not None and not bool(mask[i]):
                    continue
                s.write(int(vols[i]), int(pages[i]), int(offs[i]), payload[i])

    def read(self, vol, pages, offs):
        import numpy as _np
        if self.cfg.null_storage:
            # no store serves anything: do NOT advance the rr cursor — the
            # layer-cut row must not skew the read distribution the real
            # stores would see (ReplicaGroup.read holds the same contract)
            return None
        s = self.stores[self._rr % len(self.stores)]
        self._rr += 1
        vols = _np.broadcast_to(_np.asarray(vol), (len(pages),))
        return [s.read(int(vols[i]), int(pages[i]), int(offs[i]))
                for i in range(len(pages))]


# ---------------------------------------------------------------------------
# upstream baseline
# ---------------------------------------------------------------------------
class ChainedStore:
    """Sparse-file-style backing store: per-snapshot page maps; reads walk
    the snapshot chain newest->oldest (paper: 'Reads in volumes with many
    snapshots may have to go through the whole chain')."""

    def __init__(self, payload_shape=(64,)):
        self.chains: Dict[int, List[Dict[int, jnp.ndarray]]] = {}
        self.payload_shape = tuple(payload_shape)
        self._next = 0
        self.layers_walked = 0      # instrumentation: chain-walk depth
        self.reads = 0

    def create_volume(self) -> int:
        vid = self._next
        self._next += 1
        self.chains[vid] = [{}]
        return vid

    # control ops are no-op-on-miss (clone: -1), like the DBS path they are
    # compared against — a deleted/unknown volume must not diverge the
    # reference baseline into a KeyError where dbs completes harmlessly
    def snapshot(self, vol: int) -> None:
        if vol in self.chains:
            self.chains[vol].append({})     # new live layer

    def clone(self, vol: int) -> int:
        """Fork: freeze src (snapshot), share its frozen layers (the dicts
        themselves — CoW at layer granularity), own a fresh live layer."""
        if vol not in self.chains:
            return -1
        self.snapshot(vol)
        vid = self._next
        self._next += 1
        self.chains[vid] = list(self.chains[vol][:-1]) + [{}]
        return vid

    def unmap(self, vol: int, page: int) -> None:
        """TRIM a page: a tombstone in the live layer shadows older layers;
        same-layer writes to the page are dropped (trim-after-write wins,
        and a later write re-creates the key, so write-after-trim wins)."""
        if vol not in self.chains:
            return
        live = self.chains[vol][-1]
        for key in [k for k in live if k[0] == page]:
            del live[key]
        live[("TRIM", page)] = True

    def delete_volume(self, vol: int) -> None:
        self.chains.pop(vol, None)      # clones keep their shared layers

    def write(self, vol: int, page: int, block: int, payload) -> None:
        live = self.chains[vol][-1]
        key = (page, block)
        live[key] = payload             # delegated allocation (dict = fs)

    def read(self, vol: int, page: int, block: int):
        self.reads += 1
        for layer in reversed(self.chains.get(vol, ())):   # walk the chain
            self.layers_walked += 1
            if (page, block) in layer:
                return layer[(page, block)]
            if ("TRIM", page) in layer:
                return None             # unmapped above any older data
        return None


class UpstreamEngine(ControlDispatch):
    """TGT-style frontend + loop-function dispatch + chained sparse store.

    Registered as ``backend="upstream"`` (core/backends.py): the measured
    baseline satisfies the same protocol as every optimized backend, so the
    public block-device API can run byte-for-byte equivalence against it.
    """

    is_pool = False
    data_kinds = frozenset({"read", "write"})
    storage = None                  # no replica-group-shaped storage object

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.frontend = UpstreamFrontend(max_inflight=cfg.n_slots)
        self.stores = (None if cfg.null_backend else
                       [ChainedStore(cfg.payload_shape)
                        for _ in range(cfg.n_replicas)])
        self._rr = 0
        self.completed = 0

    def _agree(self, ids) -> int:
        if len(set(ids)) != 1:          # same hazard as ChainedReplicas
            raise RuntimeError(f"replica stores diverged on id: {ids}")
        return ids[0]

    def create_volume(self) -> int:
        if self.stores is None:
            return 0
        return self._agree([s.create_volume() for s in self.stores])

    def snapshot(self, vol: int) -> None:
        if self.stores is not None:
            for s in self.stores:
                s.snapshot(vol)

    def clone(self, vol: int) -> int:
        if self.stores is None:
            return -1
        return self._agree([s.clone(vol) for s in self.stores])

    def unmap(self, vol: int, pages) -> None:
        if self.stores is not None:
            for s in self.stores:
                for p in pages:
                    s.unmap(vol, int(p))

    def delete_volume(self, vol: int) -> None:
        if self.stores is not None:
            for s in self.stores:
                s.delete_volume(vol)

    def depth(self) -> int:
        return len(self.frontend)

    def submit(self, req: Request) -> None:
        # submission-boundary validation: historically the upstream path
        # enqueued ANY kind and silently executed it as a read — validate
        # before enqueue like every registered backend
        if req.kind not in self.data_kinds:
            raise ValueError(
                f"kind={req.kind!r} requests need backend='ring'; the "
                "upstream baseline carries data ops only")
        self.frontend.submit(req)

    def pump(self) -> int:
        got = self.frontend.poll_one()      # ONE request per loop iteration
        if got is None:
            return 0
        mid, req = got
        if self.stores is not None and not self.cfg.null_storage:
            if req.kind == "write":
                for s in self.stores:       # mirrored, sequential
                    s.write(req.volume, req.page, req.block, req.payload)
            else:
                s = self.stores[self._rr % len(self.stores)]
                self._rr += 1
                req.result = s.read(req.volume, req.page, req.block)
        self.frontend.complete(mid)
        req.status = 0
        self.completed += 1
        return 1

    def drain(self, max_iters: int = 1_000_000) -> int:
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and len(self.frontend) == 0:
                break
            n += got
        return n
