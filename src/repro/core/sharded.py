"""EnginePool: S engine shards served by ONE vmapped fused step, pipelined.

The fused step (core/fused.py) removed the host from a single engine's
datapath; this module removes the *per-engine dispatch* from a fleet of
them. Real Longhorn nodes serve many volumes concurrently — one engine
process per volume — and the survey literature on user-space storage
(PAPERS.md) identifies per-tenant scale-out plus submission/completion
overlap as the step after single-path optimization. Here:

- **Shard axis.** S independent engine shards — each its own Messages
  Array (SlotTable), its own R mirrored replica DBS states, payload pools
  and round-robin cursor — are stacked along a leading (S,) axis
  (slots.make_sharded_table, replication.ShardedReplicaGroup). Volumes
  hash to shards (``volume % S``); a volume lives entirely on one shard.
- **One program per pump.** ``jax.vmap`` over the shard axis turns the
  fused step into a single compiled program that performs admission ->
  CoW write -> mirrored store -> rr read -> retire for ALL S shards per
  dispatch. Per-shard divergence that used to be Python-level (the rr
  replica choice, replica health) is traced: health is a dense (S, R)
  mask and rr a (S,) device array (see fused.step_core).
- **Pipelined pump.** ``pump_async`` launches the sharded step and
  returns a completion handle without blocking: JAX's async dispatch
  keeps the device busy while the host returns immediately. ``drain``
  double-buffers completions — it admits and launches iteration N+1
  *before* performing the single blocking ``device_get`` for iteration N,
  so the host-side drain/stack of N+1 overlaps N's device execution.

``EngineConfig(comm="sharded", n_shards=S)`` routes ``Engine`` through a
pool; ``benchmarks/table3_shards.py`` measures throughput vs S and
``benchmarks/ladder.py`` carries the cumulative ``+sharded`` column.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.control import ControlDispatch
from repro.core.frontend import Request, ShardedFrontend
from repro.core.fused import FusedBatch, step_core, step_core_read
from repro.core.replication import ShardedReplicaGroup
from repro.core.ring import vmap_shards


@dataclass
class PendingPump:
    """Completion handle from ``pump_async``: device futures for one
    in-flight sharded step plus the host-side request lists that rode it.
    ``EnginePool._complete`` resolves it with the pump's single device_get."""
    reqs: List[List[Request]]      # per shard, aligned with batch lanes
    ok: jnp.ndarray                # (S, B) bool (device future)
    reads: jnp.ndarray             # (S, B, *payload) (device future)


class EnginePool(ControlDispatch):
    """S engine shards behind one vmapped fused step with a pipelined pump.

    API-compatible with ``Engine`` for the ladder/tests surface
    (create_volume/snapshot/submit/pump/drain/completed), plus
    ``pump_async`` and per-shard failover via ``backend.fail(shard, r)`` /
    ``backend.rebuild(shard, r)``.

    ``trace_counts`` records how many times each step variant was traced
    (i.e. how many distinct compiled programs exist) and ``dispatches`` how
    many pump launches they served — the "one compiled program serves all S
    shards per pump" contract, pinned by tests/test_sharded.py.

    Registered as ``backend="sharded"`` in core/backends.py: the submission
    path carries data ops only (``data_kinds``); control ops go host-side
    through ``control()`` between pumps.
    """

    is_pool = True
    data_kinds = frozenset({"read", "write"})

    def __init__(self, cfg, n_shards: Optional[int] = None):
        self.cfg = cfg
        s = n_shards if n_shards is not None else getattr(cfg, "n_shards", 1)
        if s < 1:
            raise ValueError(f"n_shards must be >= 1, got {s}")
        if cfg.storage != "dbs":
            raise ValueError("EnginePool requires storage='dbs'")
        self.n_shards = s
        self.frontend = ShardedFrontend(s, cfg.n_queues, cfg.n_slots,
                                        cfg.batch)
        if cfg.null_backend:
            self.backend = None
        else:
            self.backend = ShardedReplicaGroup(
                s, cfg.n_replicas, cfg.n_extents, cfg.max_volumes,
                cfg.max_pages, cfg.page_blocks, cfg.payload_shape,
                null_storage=cfg.null_storage, transport=cfg.transport,
                write_policy=cfg.write_policy, read_policy=cfg.read_policy,
                transport_opts=cfg.transport_opts)
        self._cow = (cfg.cow if cfg.cow != "auto" else
                     ("pallas" if jax.default_backend() == "tpu" else "ref"))
        from repro.kernels.dbs.registry import resolve_kernel_name
        self._kernel = resolve_kernel_name(cfg)
        self._vol_rr = 0
        self.completed = 0
        self.dispatches = 0
        self.trace_counts = {"step": 0, "step_read": 0}
        self._step = self._build_step(read_only=False)
        self._step_read = self._build_step(read_only=True)

    def _build_step(self, *, read_only: bool):
        """The pool's single compiled program (per batch geometry): the
        fused step vmapped over the leading shard axis. The trace counter
        bumps only while tracing, so it counts compiled programs, not
        dispatches.

        Donation mirrors fused_step/fused_step_read: the stacked slot
        table (and, on the write path, the stacked replica states/pools)
        are replaced by the outputs every pump, so XLA updates the big
        (S, E, ...) pools in place instead of round-tripping copies."""
        kw = dict(null_backend=self.cfg.null_backend,
                  null_storage=self.cfg.null_storage)
        # same program, unmapped at S=1: vmap only buys the worse batched-
        # scatter lowering there (ring.vmap_shards, shared with RingEngine)
        if read_only:
            mapped = vmap_shards(partial(step_core_read,
                                         kernel=self._kernel, **kw),
                                 self.n_shards)

            def stepped(table, states, pools, batch, rr, healthy):
                self.trace_counts["step_read"] += 1
                return mapped(table, states, pools, batch, rr, healthy)
            return jax.jit(stepped, donate_argnums=(0,))

        mapped = vmap_shards(partial(step_core, kernel=self._kernel, **kw),
                             self.n_shards)

        def stepped(table, states, pools, page_revs, batch, rr, healthy):
            self.trace_counts["step"] += 1
            return mapped(table, states, pools, page_revs, batch, rr,
                          healthy)
        return jax.jit(stepped, donate_argnums=(0, 1, 2, 3))

    # ------------------------------------------------------------ volumes
    def create_volume(self) -> int:
        """Create a volume on the next shard (round-robin placement).
        Returns a *global* volume id encoding its shard: ``local * S +
        shard`` — so ``gid % S`` recovers the shard and ``gid // S`` the
        shard-local id the device-side DBS states use."""
        shard = self._vol_rr % self.n_shards
        self._vol_rr += 1
        local = 0 if self.backend is None else self.backend.create_volume(shard)
        return local * self.n_shards + shard

    def snapshot(self, vol: int):
        """Freeze the volume head. Returns the (shard-local) snapshot id,
        -1 on failure — the same surface as RingEngine.snapshot."""
        if self.backend is None:
            return None
        return self.backend.snapshot(vol % self.n_shards,
                                     vol // self.n_shards)

    def clone(self, vol: int) -> int:
        """Fork a volume on its shard. Returns the new global volume id."""
        if self.backend is None:
            return -1
        shard = vol % self.n_shards
        local = self.backend.clone(shard, vol // self.n_shards)
        return local * self.n_shards + shard if local >= 0 else -1

    def unmap(self, vol: int, pages) -> None:
        if self.backend is not None:
            self.backend.unmap(vol % self.n_shards, vol // self.n_shards,
                               pages)

    def delete_volume(self, vol: int) -> None:
        if self.backend is not None:
            self.backend.delete_volume(vol % self.n_shards,
                                       vol // self.n_shards)

    def read_volume(self, vol: int, pages: jnp.ndarray,
                    block_offsets: jnp.ndarray) -> jnp.ndarray:
        """Host read path for verification (the pump serves reads in-program)."""
        if self.backend is None:
            raise RuntimeError("null backend holds no volumes")
        return self.backend.read(vol % self.n_shards, vol // self.n_shards,
                                 pages, block_offsets)

    # -------------------------------------------------- backend protocol
    @property
    def storage(self):
        """The replica storage behind this backend (core/backends.py).
        Every control op here is a host-side call between pumps — the
        fence the ring backend exists to remove (ControlDispatch)."""
        return self.backend

    def _control_repl(self, kind, shard, replica):
        if self.backend is None:
            raise RuntimeError("null backend holds no replicas")
        fn = self.backend.fail if kind == "fail" else self.backend.rebuild
        return fn(shard, replica)

    def depth(self) -> int:
        return self.frontend.depth()

    # ------------------------------------------------------------- pumping
    def submit(self, req: Request) -> None:
        if req.kind not in self.data_kinds:
            raise ValueError(
                f"kind={req.kind!r} requests need backend='ring' (the "
                "opcode-tagged SQ/CQ path); this backend carries data ops "
                "only — use control() for host-side control ops")
        self.frontend.submit(req)

    def pump_async(self) -> Optional[PendingPump]:
        """Admit one batch per shard and launch the sharded step; do NOT
        block on results. Returns a PendingPump (or None if no traffic).
        JAX async dispatch returns futures immediately, so the caller can
        keep draining/admitting while the device executes."""
        reqs, batch = self.frontend.drain_sharded(self.cfg.payload_shape)
        if batch is None:
            return None
        if self.backend is None:
            states, pools, page_revs = (), (), ()
            healthy = jnp.ones((self.n_shards, 1), bool)
            rr = jnp.zeros((self.n_shards,), jnp.int32)
        else:
            states, pools, healthy = self.backend.device_state()
            page_revs = self.backend.device_page_revs()
            rr = self.backend.bump_rr()
        self.dispatches += 1
        if any(r.kind == "write" for rs in reqs for r in rs):
            table, states, pools, page_revs, ok, reads = self._step(
                self.frontend.table, states, pools, page_revs, batch, rr,
                healthy)
            if self.backend is not None:
                self.backend.set_device_state(states, pools)
                self.backend.set_device_page_revs(page_revs)
        else:
            # read-only pump: replica state untouched — input-only variant
            # (no (S, E, ...) pool pass-through copies)
            table, ok, reads = self._step_read(
                self.frontend.table, states, pools, batch, rr, healthy)
        self.frontend.table = table
        return PendingPump(reqs=reqs, ok=ok, reads=reads)

    def _complete(self, p: PendingPump) -> int:
        """The pump's single host hop: fetch completion flags + read
        payloads, deliver results, requeue not-admitted requests."""
        ok, reads = jax.device_get((p.ok, p.reads))
        done = 0
        requeues = []
        for s, shard_reqs in enumerate(p.reqs):
            for i, r in enumerate(shard_reqs):
                if ok[s][i]:
                    r.status = 0
                    if r.kind == "read":
                        r.result = reads[s, i]
                    done += 1
                else:
                    requeues.append(r)
        self.frontend.ring.requeue_all(requeues)
        self.completed += done
        return done

    def pump(self) -> int:
        """One synchronous pool iteration (launch + complete)."""
        p = self.pump_async()
        return self._complete(p) if p is not None else 0

    def drain(self, max_iters: int = 100_000) -> int:
        """Pipelined drain: launch iteration N+1 before blocking on N.

        The admission/stacking host work and the device execution of the
        new step overlap the previous iteration's ``device_get`` — the
        double-buffered completion that keeps both sides busy. Requeued
        (not-admitted) requests surface at the completion of N and are
        re-drained by N+2's launch.
        """
        total = 0
        pending: Optional[PendingPump] = None
        for _ in range(max_iters):
            nxt = self.pump_async()
            if pending is not None:
                total += self._complete(pending)
            pending = nxt
            if nxt is None and self.frontend.depth() == 0:
                break
        if pending is not None:
            total += self._complete(pending)
        return total
