"""Frontends: multi-queue (ublk-style) vs single-loop (TGT-style upstream).

The paper's frontend finding (§IV-B): the TGT/iSCSI path serializes — every
I/O crosses a synchronous unix-socket hop, one at a time; ublk with *multiple
frontend queues* raises queue depth and throughput ~14x. On a TPU host the
analogue is request admission into the compiled engine:

- ``UpstreamFrontend``: one queue, one dispatcher, one request per device
  call (a dict tracks in-flight requests) — deliberately faithful to the
  upstream structure, used as the measured baseline.
- ``RingFrontend`` (core/ring.py): THE drain protocol since the SQ/CQ
  refactor — S shards × N admission queues drained into one opcode-tagged
  ``SQE`` batch per pump (data ops AND control ops through the same path).
- ``MultiQueueFrontend`` / ``ShardedFrontend``: thin adapters over a
  RingFrontend that keep the legacy drain surfaces alive: ``poll_batch``
  (the unfused ``comm="slots"`` engine), ``drain_batch`` (single-engine
  ``comm="fused"``), and ``drain_sharded`` (the vmapped EnginePool). Each
  converts the staged ring drain into its legacy batch shape; none owns
  drain logic of its own anymore.

See docs/ARCHITECTURE.md for where the frontend sits in the pipeline.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots
from repro.core.fused import FusedBatch
from repro.core.ring import KIND_CLASS, OP_WRITE, RingFrontend


@dataclass
class Request:
    req_id: int
    kind: str                 # read | write | snapshot | clone | unmap |
                              # delete | fail | rebuild | compute | noop
                              # (ring opcodes)
    volume: int = -1
    page: int = 0
    block: int = 0            # block offset; replica index for fail/rebuild;
                              # page count (range fns) / block (block fns)
                              # for compute
    payload: Any = None
    shard: Optional[int] = None  # explicit shard (fail/rebuild; else by vol)
    result: Any = None        # read payload / snapshot id / clone volume id
                              # / (value, CQ payload lanes) for compute
    status: Any = None        # CQE status (ring.ST_*); 0 = completed OK
    latency: Any = None       # completion latency in pump ticks (ring path)
    tick: int = 0             # submission pump tick (stamped by the frontend)
    fn: Optional[str] = None  # storage-function name (kind="compute")
    arg: int = 0              # storage-function immediate argument
    fnid: int = 0             # resolved registry id (stamped at submit)


class UpstreamFrontend:
    """Single queue + single loop function + dynamic map (paper Fig. 4 left)."""

    def __init__(self, max_inflight: int = 256):
        self.queue: Deque[Request] = collections.deque()
        self.messages: Dict[int, Request] = {}      # the Messages Map
        self._next_id = itertools.count()
        self.max_inflight = max_inflight
        self.step = 0               # pump tick (latency accounting)

    def submit(self, req: Request) -> None:
        req.tick = self.step        # unified latency semantics: every comm
        self.queue.append(req)      # mode stamps submission in pump ticks

    def poll_one(self) -> Optional[Tuple[int, Request]]:
        """The loop function: take ONE request, assign a unique id, store it
        in the map. Sequential by construction (the paper's bottleneck).
        Each poll is one pump tick; the popped request's ``latency`` is
        stamped in ticks, like the ring CQE's."""
        if not self.queue or len(self.messages) >= self.max_inflight:
            return None
        req = self.queue.popleft()
        req.latency = self.step - req.tick + 1
        self.step += 1
        mid = next(self._next_id)
        self.messages[mid] = req
        return mid, req

    def complete(self, mid: int) -> Request:
        return self.messages.pop(mid)

    def __len__(self):
        return len(self.queue)


def _reject_control(req) -> None:
    """Legacy (data-only) frontends refuse control kinds at SUBMIT time:
    rejecting at drain would have already popped the whole batch — dropping
    innocent data requests alongside the offending one."""
    if KIND_CLASS.get(req.kind) in ("vol", "repl", "compute"):
        raise ValueError("control/compute opcodes require comm='ring' "
                         f"(got kind={req.kind!r} on a data-only frontend)")


def _check_data_only(classes) -> None:
    # defensive: unreachable via submit(), which rejects control kinds
    ctrl = set(classes) - {"read", "write", "noop"}
    if ctrl:
        raise ValueError("control opcodes require comm='ring' "
                         f"(got {sorted(ctrl)} on a legacy drain path)")


class MultiQueueFrontend:
    """N admission queues + batched slot admission (paper Fig. 4 right).

    A thin adapter over a single-shard ``RingFrontend``: submission,
    requeueing and the round-robin drain live there; this class keeps the
    legacy surfaces — ``poll_batch`` (admission as its own device op, slot
    ids fetched back) and ``drain_batch`` (raw FusedBatch arrays for the
    fused step) — by converting the staged ring drain.

    ``with_table=False`` builds only the host-side admission rings (the
    composing caller owns the authoritative slot table).
    """

    def __init__(self, n_queues: int, n_slots: int, batch: int = 64,
                 with_table: bool = True):
        self.ring = RingFrontend(1, n_queues, n_slots, batch,
                                 with_table=False)
        self.table = slots.make_table(n_slots) if with_table else None
        self.batch = batch
        self._by_slot: Dict[int, Request] = {}

    @property
    def queues(self) -> List[Deque[Request]]:
        return self.ring.queues[0]

    @property
    def step(self) -> int:
        return self.ring.step[0]

    @step.setter
    def step(self, v: int) -> None:
        self.ring.step[0] = v

    def submit(self, req: Request) -> None:
        _reject_control(req)
        self.ring.submit(req)

    def depth(self) -> int:
        return self.ring.depth()

    def requeue(self, req: Request) -> None:
        """Put a not-admitted request back at the front of its queue."""
        self.ring.requeue(req)

    def _drain(self, limit: int) -> List[Request]:
        """Host-only round-robin drain of up to ``limit`` requests — the
        shared ring drain, shard 0."""
        return self.ring._drain_shard(0, limit)

    def drain_batch(self, payload_shape: Tuple[int, ...] = ()
                    ) -> Tuple[List[Request], Optional[FusedBatch]]:
        """Drain up to ``batch`` requests into the fixed-shape raw arrays the
        fused engine step consumes. Pure host->device traffic: admission
        itself happens *inside* ``fused_step`` (core/fused.py), so no slot id
        is ever read back — the admission state (``self.table``) stays on
        device across ``pump()`` iterations."""
        drained, st, classes = self.ring._stage(payload_shape)
        if st is None:
            return [], None
        _check_data_only(classes)
        # shard 0's numpy lanes cross as ONE transfer per leaf, as before
        batch = FusedBatch(
            want=jnp.asarray(st["want"][0]),
            is_write=jnp.asarray(st["op"][0] == OP_WRITE),
            volume=jnp.asarray(st["volume"][0]),
            page=jnp.asarray(st["page"][0]),
            block=jnp.asarray(st["block"][0]),
            payload=jnp.asarray(st["payload"][0]),
            queue=jnp.asarray(st["queue"][0]),
            step=jnp.int32(int(st["step"][0])),
        )
        return drained[0], batch

    def poll_batch(self) -> Tuple[jnp.ndarray, List[Request]]:
        """Drain up to ``batch`` requests round-robin across queues and admit
        them in ONE device op. Returns (slot_ids (k,), requests)."""
        reqs = self._drain(self.batch)
        if not reqs:
            return jnp.zeros((0,), jnp.int32), []
        # fixed-shape admission (pad to the batch size): one compiled program
        # regardless of how many requests arrived — the Messages-Array idiom
        n = len(reqs)
        want = jnp.arange(self.batch) < n
        vols = jnp.asarray([r.volume for r in reqs]
                           + [0] * (self.batch - n), jnp.int32)
        queues = jnp.asarray([r.req_id % len(self.queues) for r in reqs]
                             + [0] * (self.batch - n), jnp.int32)
        self.table, ids, ok = slots.admit(self.table, want, vols, queues,
                                          jnp.int32(self.step))
        ids = ids[:n]
        ok = ok[:n]
        self.step += 1
        ids_host = np.asarray(jax.device_get(ids))
        ok_host = np.asarray(jax.device_get(ok))
        admitted, requeues = [], []
        for i, r in enumerate(reqs):
            if ok_host[i]:
                self._by_slot[int(ids_host[i])] = r
                admitted.append(r)
            else:  # no slot: requeue at the front
                requeues.append(r)
        self.ring.requeue_all(requeues)
        return ids[:len(reqs)], admitted

    def complete(self, slot_ids: jnp.ndarray) -> List[Request]:
        self.table = slots.retire(self.table, slot_ids)
        out = []
        for sid in jax.device_get(slot_ids):
            if int(sid) >= 0 and int(sid) in self._by_slot:
                out.append(self._by_slot.pop(int(sid)))
        return out


class ShardedFrontend:
    """S volume-hashed shards feeding ONE vmapped admission program.

    A thin adapter over an S-shard ``RingFrontend`` (which owns the queues,
    the stacked shard-major ``SlotTable`` and the drain); ``drain_sharded``
    converts the staged ring drain into the legacy stacked (S, B, ...)
    ``FusedBatch`` the EnginePool step consumes. Volume ids are translated
    to shard-local ids (``volume // S``) by the ring stage.
    """

    def __init__(self, n_shards: int, n_queues: int, n_slots: int,
                 batch: int = 64):
        self.ring = RingFrontend(n_shards, n_queues, n_slots, batch,
                                 with_table=True)
        self.n_shards = n_shards
        self.batch = batch

    @property
    def table(self) -> slots.SlotTable:
        return self.ring.table

    @table.setter
    def table(self, t: slots.SlotTable) -> None:
        self.ring.table = t

    def shard_of(self, volume: int) -> int:
        return volume % self.n_shards

    def submit(self, req: Request) -> None:
        _reject_control(req)
        self.ring.submit(req)

    def requeue(self, req: Request) -> None:
        self.ring.requeue(req)

    def depth(self) -> int:
        return self.ring.depth()

    def drain_sharded(self, payload_shape: Tuple[int, ...] = ()
                      ) -> Tuple[List[List[Request]], Optional[FusedBatch]]:
        """Drain every shard into one stacked (S, B, ...) FusedBatch.

        Returns (per-shard request lists, stacked batch) — batch is None
        when no shard had traffic. Request lists line up with batch lanes:
        shard s's request i rode lane (s, i); shards with no traffic
        contribute all-inert (want=False) rows, so the program geometry
        never depends on which shards are busy. One device transfer per
        leaf, as always on the pump path.
        """
        drained, st, classes = self.ring._stage(payload_shape)
        if st is None:
            return [], None
        _check_data_only(classes)
        batch = FusedBatch(
            want=jnp.asarray(st["want"]),
            is_write=jnp.asarray(st["op"] == OP_WRITE),
            volume=jnp.asarray(st["volume"]), page=jnp.asarray(st["page"]),
            block=jnp.asarray(st["block"]),
            payload=jnp.asarray(st["payload"]),
            queue=jnp.asarray(st["queue"]), step=jnp.asarray(st["step"]))
        return drained, batch
