"""Frontends: multi-queue (ublk-style) vs single-loop (TGT-style upstream).

The paper's frontend finding (§IV-B): the TGT/iSCSI path serializes — every
I/O crosses a synchronous unix-socket hop, one at a time; ublk with *multiple
frontend queues* raises queue depth and throughput ~14x. On a TPU host the
analogue is request admission into the compiled engine:

- ``UpstreamFrontend``: one queue, one dispatcher, one request per device
  call (a dict tracks in-flight requests) — deliberately faithful to the
  upstream structure, used as the measured baseline.
- ``MultiQueueFrontend``: N admission rings drained into a single *batched*
  jitted admission op backed by the SlotTable (Messages Array); queue depth =
  slot count, no per-request host hop. Two drain paths: ``poll_batch`` (the
  unfused ``comm="slots"`` engine) and ``drain_batch`` (raw arrays for the
  fused step — admission state never leaves the device).
- ``ShardedFrontend``: S multi-queue frontends (volume-hashed) whose slot
  tables live as one shard-major stacked table; ``drain_sharded`` feeds the
  vmapped EnginePool step (core/sharded.py) one (S, B, ...) batch.

See docs/ARCHITECTURE.md for where the frontend sits in the pipeline.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots
from repro.core.fused import FusedBatch


@dataclass
class Request:
    req_id: int
    kind: str                 # "read" | "write"
    volume: int
    page: int
    block: int = 0
    payload: Any = None
    result: Any = None        # filled with the read payload on completion
                              # (fused path only; see docs/ARCHITECTURE.md)


class UpstreamFrontend:
    """Single queue + single loop function + dynamic map (paper Fig. 4 left)."""

    def __init__(self, max_inflight: int = 256):
        self.queue: Deque[Request] = collections.deque()
        self.messages: Dict[int, Request] = {}      # the Messages Map
        self._next_id = itertools.count()
        self.max_inflight = max_inflight

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def poll_one(self) -> Optional[Tuple[int, Request]]:
        """The loop function: take ONE request, assign a unique id, store it
        in the map. Sequential by construction (the paper's bottleneck)."""
        if not self.queue or len(self.messages) >= self.max_inflight:
            return None
        req = self.queue.popleft()
        mid = next(self._next_id)
        self.messages[mid] = req
        return mid, req

    def complete(self, mid: int) -> Request:
        return self.messages.pop(mid)

    def __len__(self):
        return len(self.queue)


class MultiQueueFrontend:
    """N admission queues + batched slot admission (paper Fig. 4 right).

    ``with_table=False`` builds only the host-side admission rings — the
    ShardedFrontend composes S of these but keeps the single authoritative
    stacked slot table itself (a per-shard table here would be dead state
    that ``poll_batch`` could silently diverge against).
    """

    def __init__(self, n_queues: int, n_slots: int, batch: int = 64,
                 with_table: bool = True):
        self.queues: List[Deque[Request]] = [collections.deque()
                                             for _ in range(n_queues)]
        self.table = slots.make_table(n_slots) if with_table else None
        self.batch = batch
        self.step = 0
        self._by_slot: Dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self.queues[req.req_id % len(self.queues)].append(req)

    def depth(self) -> int:
        return sum(len(q) for q in self.queues)

    def requeue(self, req: Request) -> None:
        """Put a not-admitted request back at the front of its queue."""
        self.queues[req.req_id % len(self.queues)].appendleft(req)

    def _drain(self, limit: int) -> List[Request]:
        """Host-only round-robin drain of up to ``limit`` requests — no
        device ops, shared by the unfused and fused admission paths."""
        reqs: List[Request] = []
        qs = [q for q in self.queues if q]
        while qs and len(reqs) < limit:
            for q in list(qs):
                if not q:
                    qs.remove(q)
                    continue
                reqs.append(q.popleft())
                if len(reqs) >= limit:
                    break
        return reqs

    def drain_batch(self, payload_shape: Tuple[int, ...] = ()
                    ) -> Tuple[List[Request], Optional[FusedBatch]]:
        """Drain up to ``batch`` requests into the fixed-shape raw arrays the
        fused engine step consumes. Pure host->device traffic: admission
        itself happens *inside* ``fused_step`` (core/fused.py), so no slot id
        is ever read back — the admission state (``self.table``) stays on
        device across ``pump()`` iterations."""
        reqs = self._drain(self.batch)
        if not reqs:
            return [], None
        n, b = len(reqs), self.batch
        pad = b - n
        ints = lambda xs: jnp.asarray(np.asarray(xs + [0] * pad, np.int32))
        # fill a host-side numpy buffer, ONE device transfer for the batch
        # (a per-request jnp.stack puts O(B) tiny dispatches on the pump)
        np_payload = np.zeros((b,) + tuple(payload_shape), np.float32)
        for i, r in enumerate(reqs):
            if r.payload is not None:
                np_payload[i] = np.asarray(r.payload)
        payload = jnp.asarray(np_payload)
        batch = FusedBatch(
            want=jnp.arange(b) < n,
            is_write=jnp.asarray(np.asarray(
                [r.kind == "write" for r in reqs] + [False] * pad)),
            volume=ints([r.volume for r in reqs]),
            page=ints([r.page for r in reqs]),
            block=ints([r.block for r in reqs]),
            payload=payload,
            queue=ints([r.req_id % len(self.queues) for r in reqs]),
            step=jnp.int32(self.step),
        )
        self.step += 1
        return reqs, batch

    def poll_batch(self) -> Tuple[jnp.ndarray, List[Request]]:
        """Drain up to ``batch`` requests round-robin across queues and admit
        them in ONE device op. Returns (slot_ids (k,), requests)."""
        reqs = self._drain(self.batch)
        if not reqs:
            return jnp.zeros((0,), jnp.int32), []
        # fixed-shape admission (pad to the batch size): one compiled program
        # regardless of how many requests arrived — the Messages-Array idiom
        n = len(reqs)
        want = jnp.arange(self.batch) < n
        vols = jnp.asarray([r.volume for r in reqs]
                           + [0] * (self.batch - n), jnp.int32)
        queues = jnp.asarray([r.req_id % len(self.queues) for r in reqs]
                             + [0] * (self.batch - n), jnp.int32)
        self.table, ids, ok = slots.admit(self.table, want, vols, queues,
                                          jnp.int32(self.step))
        ids = ids[:n]
        ok = ok[:n]
        self.step += 1
        ids_host = np.asarray(jax.device_get(ids))
        ok_host = np.asarray(jax.device_get(ok))
        admitted = []
        for i, r in enumerate(reqs):
            if ok_host[i]:
                self._by_slot[int(ids_host[i])] = r
                admitted.append(r)
            else:  # no slot: requeue at the front
                self.queues[r.req_id % len(self.queues)].appendleft(r)
        return ids[:len(reqs)], admitted

    def complete(self, slot_ids: jnp.ndarray) -> List[Request]:
        self.table = slots.retire(self.table, slot_ids)
        out = []
        for sid in jax.device_get(slot_ids):
            if int(sid) >= 0 and int(sid) in self._by_slot:
                out.append(self._by_slot.pop(int(sid)))
        return out


class ShardedFrontend:
    """S multi-queue frontends feeding ONE vmapped admission program.

    Requests hash to a shard by volume id (``volume % S`` — a volume lives
    entirely on one shard, like a Longhorn volume on its engine instance).
    Each shard keeps its own host-side admission rings, but the S slot
    tables are held as a single shard-major stacked ``SlotTable``
    (slots.make_sharded_table) so the EnginePool's vmapped step admits and
    retires every shard's batch in one compiled program.

    ``drain_sharded`` is the fused-path drain: it pulls up to ``batch``
    requests per shard and stacks the raw per-shard arrays into one
    (S, B, ...) ``FusedBatch``. Shards with no traffic contribute an inert
    all-padding batch lane set — the program geometry never depends on which
    shards happen to be busy. Volume ids are translated to the shard-local
    ids the device-side DBS states use (``volume // S``).
    """

    def __init__(self, n_shards: int, n_queues: int, n_slots: int,
                 batch: int = 64):
        self.n_shards = n_shards
        self.batch = batch
        self.shards = [MultiQueueFrontend(n_queues, n_slots, batch,
                                          with_table=False)
                       for _ in range(n_shards)]
        self.table = slots.make_sharded_table(n_shards, n_slots)

    def shard_of(self, volume: int) -> int:
        return volume % self.n_shards

    def submit(self, req: Request) -> None:
        self.shards[self.shard_of(req.volume)].submit(req)

    def requeue(self, req: Request) -> None:
        self.shards[self.shard_of(req.volume)].requeue(req)

    def depth(self) -> int:
        return sum(f.depth() for f in self.shards)

    def drain_sharded(self, payload_shape: Tuple[int, ...] = ()
                      ) -> Tuple[List[List[Request]], Optional[FusedBatch]]:
        """Drain every shard into one stacked (S, B, ...) FusedBatch.

        Returns (per-shard request lists, stacked batch) — batch is None
        when no shard had traffic. Request lists line up with batch lanes:
        shard s's request i rode lane (s, i); shards with no traffic
        contribute all-inert (want=False) rows, so the program geometry
        never depends on which shards are busy.

        The lane arrays are filled into host-side numpy buffers and cross
        to the device as ONE transfer per leaf — not one per shard per
        field, which would put O(S) tiny dispatches on the exact pump path
        the shard axis exists to amortize. Volume ids are translated to the
        shard-local ids the device-side DBS states use (``volume // S``).
        """
        drained = [f._drain(self.batch) for f in self.shards]
        if not any(drained):
            return [], None
        s_n, b_n = self.n_shards, self.batch
        want = np.zeros((s_n, b_n), bool)
        is_write = np.zeros((s_n, b_n), bool)
        ints = {k: np.zeros((s_n, b_n), np.int32)
                for k in ("volume", "page", "block", "queue")}
        step = np.zeros((s_n,), np.int32)
        payload = np.zeros((s_n, b_n) + tuple(payload_shape), np.float32)
        for s, (f, reqs) in enumerate(zip(self.shards, drained)):
            step[s] = f.step
            if reqs:
                f.step += 1
            for i, r in enumerate(reqs):
                want[s, i] = True
                is_write[s, i] = r.kind == "write"
                ints["volume"][s, i] = r.volume // s_n
                ints["page"][s, i] = r.page
                ints["block"][s, i] = r.block
                ints["queue"][s, i] = r.req_id % len(f.queues)
                if r.payload is not None:
                    payload[s, i] = np.asarray(r.payload)
        batch = FusedBatch(
            want=jnp.asarray(want), is_write=jnp.asarray(is_write),
            volume=jnp.asarray(ints["volume"]), page=jnp.asarray(ints["page"]),
            block=jnp.asarray(ints["block"]), payload=jnp.asarray(payload),
            queue=jnp.asarray(ints["queue"]), step=jnp.asarray(step))
        return drained, batch
