"""Frontends: multi-queue (ublk-style) vs single-loop (TGT-style upstream).

The paper's frontend finding (§IV-B): the TGT/iSCSI path serializes — every
I/O crosses a synchronous unix-socket hop, one at a time; ublk with *multiple
frontend queues* raises queue depth and throughput ~14x. On a TPU host the
analogue is request admission into the compiled engine:

- ``UpstreamFrontend``: one queue, one dispatcher, one request per device
  call (a dict tracks in-flight requests) — deliberately faithful to the
  upstream structure, used as the measured baseline.
- ``MultiQueueFrontend``: N admission rings drained into a single *batched*
  jitted admission op backed by the SlotTable (Messages Array); queue depth =
  slot count, no per-request host hop. Two drain paths: ``poll_batch`` (the
  unfused ``comm="slots"`` engine) and ``drain_batch`` (raw arrays for the
  fused step — admission state never leaves the device).

See docs/ARCHITECTURE.md for where the frontend sits in the pipeline.
"""
from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots
from repro.core.fused import FusedBatch


@dataclass
class Request:
    req_id: int
    kind: str                 # "read" | "write"
    volume: int
    page: int
    block: int = 0
    payload: Any = None
    result: Any = None        # filled with the read payload on completion
                              # (fused path only; see docs/ARCHITECTURE.md)


class UpstreamFrontend:
    """Single queue + single loop function + dynamic map (paper Fig. 4 left)."""

    def __init__(self, max_inflight: int = 256):
        self.queue: Deque[Request] = collections.deque()
        self.messages: Dict[int, Request] = {}      # the Messages Map
        self._next_id = itertools.count()
        self.max_inflight = max_inflight

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def poll_one(self) -> Optional[Tuple[int, Request]]:
        """The loop function: take ONE request, assign a unique id, store it
        in the map. Sequential by construction (the paper's bottleneck)."""
        if not self.queue or len(self.messages) >= self.max_inflight:
            return None
        req = self.queue.popleft()
        mid = next(self._next_id)
        self.messages[mid] = req
        return mid, req

    def complete(self, mid: int) -> Request:
        return self.messages.pop(mid)

    def __len__(self):
        return len(self.queue)


class MultiQueueFrontend:
    """N admission queues + batched slot admission (paper Fig. 4 right)."""

    def __init__(self, n_queues: int, n_slots: int, batch: int = 64):
        self.queues: List[Deque[Request]] = [collections.deque()
                                             for _ in range(n_queues)]
        self.table = slots.make_table(n_slots)
        self.batch = batch
        self.step = 0
        self._by_slot: Dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self.queues[req.req_id % len(self.queues)].append(req)

    def depth(self) -> int:
        return sum(len(q) for q in self.queues)

    def requeue(self, req: Request) -> None:
        """Put a not-admitted request back at the front of its queue."""
        self.queues[req.req_id % len(self.queues)].appendleft(req)

    def _drain(self, limit: int) -> List[Request]:
        """Host-only round-robin drain of up to ``limit`` requests — no
        device ops, shared by the unfused and fused admission paths."""
        reqs: List[Request] = []
        qs = [q for q in self.queues if q]
        while qs and len(reqs) < limit:
            for q in list(qs):
                if not q:
                    qs.remove(q)
                    continue
                reqs.append(q.popleft())
                if len(reqs) >= limit:
                    break
        return reqs

    def drain_batch(self, payload_shape: Tuple[int, ...] = ()
                    ) -> Tuple[List[Request], Optional[FusedBatch]]:
        """Drain up to ``batch`` requests into the fixed-shape raw arrays the
        fused engine step consumes. Pure host->device traffic: admission
        itself happens *inside* ``fused_step`` (core/fused.py), so no slot id
        is ever read back — the admission state (``self.table``) stays on
        device across ``pump()`` iterations."""
        reqs = self._drain(self.batch)
        if not reqs:
            return [], None
        n, b = len(reqs), self.batch
        pad = b - n
        ints = lambda xs: jnp.asarray(np.asarray(xs + [0] * pad, np.int32))
        zero = jnp.zeros(payload_shape, jnp.float32)
        payload = jnp.stack(
            [r.payload if r.payload is not None else zero for r in reqs]
            + [zero] * pad)
        batch = FusedBatch(
            want=jnp.arange(b) < n,
            is_write=jnp.asarray(np.asarray(
                [r.kind == "write" for r in reqs] + [False] * pad)),
            volume=ints([r.volume for r in reqs]),
            page=ints([r.page for r in reqs]),
            block=ints([r.block for r in reqs]),
            payload=payload,
            queue=ints([r.req_id % len(self.queues) for r in reqs]),
            step=jnp.int32(self.step),
        )
        self.step += 1
        return reqs, batch

    def poll_batch(self) -> Tuple[jnp.ndarray, List[Request]]:
        """Drain up to ``batch`` requests round-robin across queues and admit
        them in ONE device op. Returns (slot_ids (k,), requests)."""
        reqs = self._drain(self.batch)
        if not reqs:
            return jnp.zeros((0,), jnp.int32), []
        # fixed-shape admission (pad to the batch size): one compiled program
        # regardless of how many requests arrived — the Messages-Array idiom
        n = len(reqs)
        want = jnp.arange(self.batch) < n
        vols = jnp.asarray([r.volume for r in reqs]
                           + [0] * (self.batch - n), jnp.int32)
        queues = jnp.asarray([r.req_id % len(self.queues) for r in reqs]
                             + [0] * (self.batch - n), jnp.int32)
        self.table, ids, ok = slots.admit(self.table, want, vols, queues,
                                          jnp.int32(self.step))
        ids = ids[:n]
        ok = ok[:n]
        self.step += 1
        ids_host = np.asarray(jax.device_get(ids))
        ok_host = np.asarray(jax.device_get(ok))
        admitted = []
        for i, r in enumerate(reqs):
            if ok_host[i]:
                self._by_slot[int(ids_host[i])] = r
                admitted.append(r)
            else:  # no slot: requeue at the front
                self.queues[r.req_id % len(self.queues)].appendleft(r)
        return ids[:len(reqs)], admitted

    def complete(self, slot_ids: jnp.ndarray) -> List[Request]:
        self.table = slots.retire(self.table, slot_ids)
        out = []
        for sid in jax.device_get(slot_ids):
            if int(sid) >= 0 and int(sid) in self._by_slot:
                out.append(self._by_slot.pop(int(sid)))
        return out
