"""The backend registry: named engine backends behind one protocol.

Before this module, picking an engine meant string-branching inside
``Engine.__init__`` on ``EngineConfig.comm`` — six hard-coded modes, each
with its own construction path, and no way to add a seventh without editing
the engine. This is the io_uring/ublk-style fix applied to *construction*:
every engine variant is a **backend** registered by name, and ``Engine``
(core/engine.py) plus the public block-device API
(``blockdev.VolumeManager``) are thin façades that look the name up here.

The **Backend protocol** (duck-typed; ``Backend`` below is the typing
reference) is the four-verb surface the paper's ublk frontend needs from an
engine plus lifecycle plumbing:

- ``submit(req)``  — enqueue one request; MUST validate ``req.kind`` against
  ``data_kinds`` and raise *before* touching any queue (a drain-time
  rejection would pop — and then lose — innocent requests batched alongside
  the offending one),
- ``pump()``       — one engine iteration; returns completions,
- ``drain()``      — pump to empty (pipelined where the backend supports it),
- ``control(kind, ...)`` — snapshot / clone / unmap / delete / fail /
  rebuild, executed however the backend likes (in-band SQEs on the ring,
  host-side dispatch elsewhere),

plus ``create_volume()``, ``depth()``, ``completed`` (get/set), a
``storage`` attribute naming the replica storage (or None), ``is_pool``
(True when the backend IS a shard pool — ``Engine.pool`` compatibility),
and ``data_kinds`` (the request kinds ``submit`` accepts).

Registered backends:

| name       | class                          | submission path          |
| ---------- | ------------------------------ | ------------------------ |
| ``loop``   | ``HostDispatchBackend``        | one host dispatch per op |
| ``slots``  | ``HostDispatchBackend``        | batched slot admission   |
| ``fused``  | ``FusedBackend``               | ONE program per pump     |
| ``sharded``| ``sharded.EnginePool``         | vmapped pool, pipelined  |
| ``ring``   | ``ring.RingEngine``            | opcode-tagged SQ/CQ      |
| ``upstream``| ``engine.UpstreamEngine``     | TGT-style baseline       |
| ``host``   | ``HostStateBackend``           | sequential host oracle   |

``host`` is the registry-extensibility demo and does double duty: it is the
sequential single-state oracle the byte-API tests compare engines against,
and the control plane the paged-KV serving engine embeds (``alloc_pages``
exposes DBS ``WriteOps`` so an external data plane can mirror the CoW
copies — serving/engine.py).
"""
from __future__ import annotations

import collections
from typing import (Any, Callable, Dict, FrozenSet, List, Optional, Protocol,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbs
from repro.core.control import ControlDispatch
from repro.core.frontend import MultiQueueFrontend, Request
from repro.core.fused import (fused_step, fused_step_read,
                              fused_step_read_tiered, fused_step_tiered)
from repro.core.replication import ReplicaGroup


class Backend(Protocol):
    """Typing reference for the duck-typed backend protocol (docstring
    above). Concrete backends do not need to inherit from this."""

    cfg: Any
    storage: Any
    is_pool: bool
    data_kinds: FrozenSet[str]
    completed: int

    def create_volume(self) -> int: ...
    def submit(self, req: Request) -> None: ...
    def pump(self) -> int: ...
    def drain(self, max_iters: int = 100_000) -> int: ...
    def depth(self) -> int: ...
    def control(self, kind: str, *, volume: int = -1, pages=None,
                shard: Optional[int] = None, replica: int = -1) -> Any: ...


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[Any], Any]] = {}


def register_backend(name: str, factory: Optional[Callable] = None, *,
                     override: bool = False):
    """Register ``factory(cfg) -> Backend`` under ``name``. Usable directly
    (``register_backend("slots", HostDispatchBackend)``) or as a decorator
    (``@register_backend("mybackend")``). Duplicate names raise (the uniform
    registry contract — backends/transports/kernels/storage fns all match);
    embedders that mean to shadow a built-in pass ``override=True``."""
    def _put(f):
        if name in _REGISTRY and not override:
            raise ValueError(
                f"duplicate backend {name!r} (registered: "
                f"{', '.join(available_backends())}); pass override=True "
                "to replace")
        _REGISTRY[name] = f
        return f
    if factory is None:
        return _put
    return _put(factory)


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_backend(name: str, cfg) -> Any:
    """Instantiate the backend registered under ``name`` for ``cfg``."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r} (registered: "
            f"{', '.join(available_backends())})") from None
    return factory(cfg)


# ---------------------------------------------------------------------------
# host-dispatch backends (the pre-fused engine paths)
# ---------------------------------------------------------------------------
class _FrontendBackendBase(ControlDispatch):
    """Shared construction for the MultiQueueFrontend-fed backends: the
    frontend, the replica storage (DBS ReplicaGroup, the chained sparse-file
    baseline, or None for the null-backend layer cut), and host-side
    control dispatch (ControlDispatch over the storage-delegating methods
    below; null-backend rows keep the engines' historical surface —
    snapshot None, clone -1)."""

    is_pool = False
    data_kinds = frozenset({"read", "write"})

    def __init__(self, cfg):
        self.cfg = cfg
        self.frontend = MultiQueueFrontend(cfg.n_queues, cfg.n_slots,
                                           cfg.batch)
        if cfg.null_backend:
            self.storage = None
        elif cfg.storage == "chained":
            from repro.core.engine import ChainedReplicas
            self.storage = ChainedReplicas(cfg)
        else:
            self.storage = ReplicaGroup(
                cfg.n_replicas, cfg.n_extents, cfg.max_volumes, cfg.max_pages,
                cfg.page_blocks, cfg.payload_shape,
                null_storage=cfg.null_storage, transport=cfg.transport,
                write_policy=cfg.write_policy, read_policy=cfg.read_policy,
                transport_opts=cfg.transport_opts)
        self._cow = (cfg.cow if cfg.cow != "auto" else
                     ("pallas" if jax.default_backend() == "tpu" else "ref"))
        from repro.kernels.dbs.registry import resolve_kernel_name
        self._kernel = resolve_kernel_name(cfg)
        self.completed = 0

    def create_volume(self) -> int:
        if self.storage is None:
            return 0
        return self.storage.create_volume()

    def submit(self, req: Request) -> None:
        # submission-boundary validation: reject BEFORE enqueue, so a mixed
        # batch never loses its innocent data requests to a drain-time error
        if req.kind not in self.data_kinds:
            raise ValueError(
                f"kind={req.kind!r} requests need backend='ring' (the "
                "opcode-tagged SQ/CQ path); this backend carries data ops "
                "only — use control() for host-side control ops")
        self.frontend.submit(req)

    def depth(self) -> int:
        return self.frontend.depth()

    def snapshot(self, volume: int):
        return None if self.storage is None else self.storage.snapshot(volume)

    def clone(self, volume: int) -> int:
        return -1 if self.storage is None else self.storage.clone(volume)

    def unmap(self, volume: int, pages) -> None:
        if self.storage is not None:
            self.storage.unmap(volume, pages)

    def delete_volume(self, volume: int) -> None:
        if self.storage is not None:
            self.storage.delete_volume(volume)

    def _control_repl(self, kind, shard, replica):
        if self.storage is None:
            return None
        fn = getattr(self.storage, kind, None)     # ReplicaGroup.fail/rebuild
        if fn is None:
            raise ValueError(f"storage {type(self.storage).__name__} has no "
                             f"{kind!r} control op")
        return fn(replica)

    def drain(self, max_iters: int = 100_000) -> int:
        n = 0
        for _ in range(max_iters):
            got = self.pump()
            if got == 0 and self.frontend.depth() == 0:
                break
            n += got
        return n

    def pump(self) -> int:                         # pragma: no cover
        raise NotImplementedError


@register_backend("loop")
@register_backend("slots")
class HostDispatchBackend(_FrontendBackendBase):
    """The unfused engine iteration: batched slot admission (``slots``) or
    the per-request loop (``loop``), with separate host dispatches for
    admission, writes, reads and completion — the benchmark ladder's
    ``+comm``/``+dbs`` columns and the ``+frontend`` loop baseline."""

    def _exec_write_batch(self, rs: List[Request]) -> None:
        if self.cfg.storage == "chained":
            for r in rs:
                self.storage.write(r.volume, [r.page], [r.block],
                                   [r.payload])
            return
        # fixed-shape vectorized write (padded to the admission batch)
        n, cap = len(rs), self.cfg.batch
        pad = cap - (n % cap) if n % cap else 0
        vols = jnp.asarray([r.volume for r in rs] + [0] * pad, jnp.int32)
        pages = jnp.asarray([r.page for r in rs] + [0] * pad, jnp.int32)
        offs = jnp.asarray([r.block for r in rs] + [0] * pad, jnp.int32)
        payload = jnp.stack(
            [r.payload if r.payload is not None
             else jnp.zeros(self.cfg.payload_shape) for r in rs]
            + [jnp.zeros(self.cfg.payload_shape)] * pad)
        mask = jnp.arange(n + pad) < n
        for i in range(0, n + pad, cap):
            s = slice(i, i + cap)
            self.storage.write(vols[s], pages[s], offs[s], payload[s],
                               mask=mask[s])

    def pump(self) -> int:
        """One controller iteration: admit a batch, execute it against the
        replicas (writes mirrored / reads round-robin), complete the slots.
        Returns the number of completed requests."""
        slot_ids, reqs = self.frontend.poll_batch()
        if not reqs:
            return 0
        if self.storage is not None:
            if self.cfg.comm == "loop":
                # the single loop function: one request at a time
                for r in reqs:
                    if r.kind == "write":
                        self._exec_write_batch([r])
                    else:
                        out = self.storage.read(
                            r.volume, jnp.asarray([r.page], jnp.int32),
                            jnp.asarray([r.block], jnp.int32))
                        if out is not None:
                            r.result = np.asarray(jax.device_get(out))[0]
            else:
                writes = [r for r in reqs if r.kind == "write"]
                reads = [r for r in reqs if r.kind == "read"]
                if writes:
                    self._exec_write_batch(writes)
                if reads:
                    if self.cfg.storage == "chained":
                        out = self.storage.read(
                            [r.volume for r in reads],
                            [r.page for r in reads],
                            [r.block for r in reads])
                        if out is not None:
                            for r, v in zip(reads, out):
                                r.result = v
                    else:
                        n, cap = len(reads), self.cfg.batch
                        pad = cap - (n % cap) if n % cap else 0
                        vols = jnp.asarray(
                            [r.volume for r in reads] + [0] * pad, jnp.int32)
                        pages = jnp.asarray(
                            [r.page for r in reads] + [0] * pad, jnp.int32)
                        offs = jnp.asarray(
                            [r.block for r in reads] + [0] * pad, jnp.int32)
                        for i in range(0, n + pad, cap):
                            s = slice(i, i + cap)
                            out = self.storage.read(vols[s], pages[s],
                                                    offs[s])
                            # one fetch per chunk, host indexing after:
                            # per-lane out[j] would put O(B) tiny device
                            # gathers on the pump (and deliver device
                            # arrays where every other comm mode delivers
                            # host numpy)
                            out = np.asarray(jax.device_get(out))
                            for j, r in enumerate(reads[i:i + cap]):
                                r.result = out[j]
        done = self.frontend.complete(slot_ids)
        for r in done:
            # unified completion semantics across backends: every completed
            # request carries a status (0 = OK) and a latency in pump ticks
            # (stamped at drain); reads carry their payload in ``result``
            r.status = 0
        self.completed += len(done)
        return len(done)


@register_backend("fused")
class FusedBackend(_FrontendBackendBase):
    """The single-program engine step (core/fused.py): admission -> CoW
    writes -> mirrored stores -> rr reads -> retirement in ONE compiled
    program per batch geometry, one ``device_get`` per pump."""

    def __init__(self, cfg):
        if cfg.storage != "dbs":
            raise ValueError("backend='fused' requires storage='dbs'")
        if cfg.write_policy != "all" or cfg.read_policy != "rr":
            raise ValueError(
                "backend='fused' serves the data plane IN-PROGRAM "
                "(mirror-to-all writes, in-program rr reads); write_policy="
                f"{cfg.write_policy!r}/read_policy={cfg.read_policy!r} "
                "need a host-dispatch backend (loop | slots)")
        super().__init__(cfg)
        # cold-extent spill tier (repro/durability/tier.py): bounded
        # device-resident hot set, host-memory capacity tier, spill/fill at
        # the pump boundary. Needs the real DBS storage plane.
        self.tier = None
        if getattr(cfg, "tier", None) is not None:
            if cfg.null_backend or cfg.null_storage:
                raise ValueError("tier= needs the real storage plane "
                                 "(null_backend/null_storage hold no pools)")
            from repro.durability.tier import as_tier
            self.tier = as_tier(cfg.tier, cfg.n_extents)

    def pump(self) -> int:
        """One controller iteration as ONE compiled program (core/fused.py).

        The host drains raw request arrays in, launches ``fused_step``, and
        performs exactly one ``device_get`` — at completion, to learn which
        lanes were admitted and to carry read payloads out. Between admission
        and completion nothing crosses the host: the slot table, replica
        DBS states and payload pools round-trip device-side.

        With a tier, spill/fill rides the pump boundary: spilled extents the
        batch touches fault in (one batched row-scatter per replica) before
        the step, the step itself is the *tiered* single program (it also
        stamps per-extent access ticks), and an over-budget resident set is
        rebalanced after — the in-program hot path is unchanged.
        """
        reqs, batch = self.frontend.drain_batch(self.cfg.payload_shape)
        if not reqs:
            return 0
        if self.storage is None:
            states, pools, page_revs = (), (), ()
            rr = 0
        else:
            states, pools = self.storage.device_state()
            page_revs = self.storage.device_page_revs()
            rr = self.storage.bump_rr()
        tier = self.tier
        if tier is not None:
            table_host = np.asarray(jax.device_get(states[0].table))
            pools, touched = tier.fault_in(table_host, reqs, pools)
            if any(r.kind == "write" for r in reqs):
                (table, states, pools, page_revs, stamps, ok,
                 reads) = fused_step_tiered(
                    self.frontend.table, states, pools, page_revs,
                    tier.stamps, batch, rr, kernel=self._kernel)
                self.storage.set_device_page_revs(page_revs)
            else:
                table, stamps, ok, reads = fused_step_read_tiered(
                    self.frontend.table, states, pools, tier.stamps, batch,
                    rr, kernel=self._kernel)
            tier.stamps = stamps
            pools = tier.balance(pools, protect=touched)
            self.storage.set_device_state(states, pools)
        elif any(r.kind == "write" for r in reqs):
            table, states, pools, page_revs, ok, reads = fused_step(
                self.frontend.table, states, pools, page_revs, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage, kernel=self._kernel)
            if self.storage is not None:
                self.storage.set_device_state(states, pools)
                self.storage.set_device_page_revs(page_revs)
        else:
            # read-only batch: replica state is untouched, so dispatch the
            # input-only variant (no pool pass-through copies)
            table, ok, reads = fused_step_read(
                self.frontend.table, states, pools, batch, rr,
                null_backend=self.cfg.null_backend,
                null_storage=self.cfg.null_storage, kernel=self._kernel)
        self.frontend.table = table
        # the single host hop: completion flags + completed read payloads
        ok_host, reads_host = jax.device_get((ok, reads))
        done = 0
        requeues = []
        for i, r in enumerate(reqs):
            if ok_host[i]:
                r.status = 0
                if r.kind == "read":
                    r.result = reads_host[i]
                done += 1
            else:
                requeues.append(r)
        self.frontend.ring.requeue_all(requeues)
        self.completed += done
        return done


# ---------------------------------------------------------------------------
# the host-state oracle backend (+ the serving engine's control plane)
# ---------------------------------------------------------------------------
@register_backend("host")
class HostStateBackend(ControlDispatch):
    """ONE host-driven DBSState + payload pool, strictly sequential.

    Three jobs: (1) the reference oracle the byte-API equivalence tests
    compare engine backends against, (2) the registry-extensibility demo —
    ~80 lines is all a new backend needs, (3) the control plane embedders
    with an external data plane drive: ``alloc_pages`` runs the DBS
    control-plane resolution on this backend's state and returns the
    ``WriteOps`` (dst extents, CoW sources) so the embedder can mirror the
    copies onto its own pools — the paged-KV serving engine allocates its
    cache pages through exactly this (serving/engine.py via
    ``blockdev.VolumeManager``)."""

    is_pool = False
    data_kinds = frozenset({"read", "write", "compute"})

    def __init__(self, cfg):
        self.cfg = cfg
        self.frontend = None                 # no admission machinery at all
        self.storage = None
        self.state = dbs.make_state(cfg.n_extents, cfg.max_volumes,
                                    cfg.max_pages)
        self.pool = (None if (cfg.null_storage or cfg.null_backend) else
                     jnp.zeros((cfg.n_extents + 1, cfg.page_blocks)
                               + tuple(cfg.payload_shape), jnp.float32))
        self.queue: collections.deque = collections.deque()
        self.step = 0                        # pump tick (latency accounting)
        self.completed = 0

    def create_volume(self) -> int:
        self.state, vid = dbs.create_volume(self.state)
        return int(vid)

    def submit(self, req: Request) -> None:
        if req.kind not in self.data_kinds:
            raise ValueError(
                f"kind={req.kind!r} requests need backend='ring'; the host "
                "oracle carries data and compute ops only — use control()")
        req.tick = self.step
        self.queue.append(req)

    def depth(self) -> int:
        return len(self.queue)

    def pump(self) -> int:
        """Execute ONE queued request (strictly sequential — the oracle's
        whole point is per-op submission-order semantics)."""
        if not self.queue:
            return 0
        r = self.queue.popleft()
        status = 0
        if r.kind == "write":
            self.state, ops = dbs.write_pages(
                self.state, jnp.int32(r.volume),
                jnp.asarray([r.page], jnp.int32),
                jnp.asarray([1 << r.block], jnp.uint32),
                jnp.asarray([True]))
            if self.pool is not None:
                self.pool = dbs.apply_write_ops(
                    self.pool, ops, jnp.asarray(r.payload)[None],
                    jnp.asarray([r.block], jnp.int32))
        elif r.kind == "compute":
            # the sequential host_ref — the reference every in-program
            # backend's storage-function results are gated against
            if self.pool is not None:
                from repro.compute.exec import host_compute
                val, status, out, self.state, self.pool = host_compute(
                    self.state, self.pool, r, self.cfg.payload_shape)
                r.result = (val, out)
        elif self.pool is not None:
            ext = int(self.state.table[r.volume, r.page])
            r.result = (np.zeros(tuple(self.cfg.payload_shape), np.float32)
                        if ext < 0 else
                        np.asarray(self.pool[ext, r.block]))
        r.status = status
        r.latency = self.step - getattr(r, "tick", 0) + 1
        self.step += 1
        self.completed += 1
        return 1

    def drain(self, max_iters: int = 1_000_000) -> int:
        n = 0
        for _ in range(max_iters):
            if not self.pump():
                break
            n += 1
        return n

    def snapshot(self, volume: int) -> int:
        self.state, sid = dbs.snapshot(self.state, jnp.int32(volume))
        return int(sid)

    def clone(self, volume: int) -> int:
        self.state, vid = dbs.clone(self.state, jnp.int32(volume))
        return int(vid)

    def unmap(self, volume: int, pages) -> None:
        ps = np.asarray(list(pages), np.int32)
        if ps.size:
            self.state = dbs.unmap(self.state, jnp.int32(volume),
                                   jnp.asarray(ps))

    def delete_volume(self, volume: int) -> None:
        self.state = dbs.delete_volume(self.state, jnp.int32(volume))

    # -- the external-data-plane hook (serving/engine.py) -------------------
    def alloc_pages(self, vols, pages, mask=None, bits=None) -> dbs.WriteOps:
        """Control-plane page allocation/CoW on this backend's state; the
        returned WriteOps drive the embedder's own data plane."""
        if bits is None:
            bits = jnp.ones(jnp.asarray(pages).shape, jnp.uint32)
        self.state, ops = dbs.write_pages(self.state, vols, pages, bits,
                                          mask)
        return ops


# ---------------------------------------------------------------------------
# pool / baseline backends (classes live in their own modules)
# ---------------------------------------------------------------------------
@register_backend("sharded")
def _make_sharded(cfg):
    from repro.core.sharded import EnginePool
    return EnginePool(cfg)


@register_backend("ring")
def _make_ring(cfg):
    from repro.core.ring import RingEngine
    return RingEngine(cfg)


@register_backend("upstream")
def _make_upstream(cfg):
    from repro.core.engine import UpstreamEngine
    return UpstreamEngine(cfg)
