# The paper's primary contribution: the optimized Longhorn engine layers,
# adapted to the TPU data plane (see docs/ARCHITECTURE.md):
#   slots.py        Messages Array + ID-token channel (paper §IV-C)
#   dbs.py          device-side Direct Block Store (paper §IV-D)
#   frontend.py     multi-queue ublk-style admission vs TGT-style baseline
#   replication.py  write-to-all / read-round-robin / rebuild (paper §III)
#   fused.py        single-program fused engine step (admit->CoW->complete)
#   sharded.py      EnginePool: S shards, one vmapped step, pipelined pump
#   ring.py         SQ/CQ ring protocol: opcode-tagged data+control ops
#   transport.py    controller<->replica wire: opcode-tagged messages over
#                   pluggable transports (local/device/simnet) + registry
#   backends.py     the backend registry (loop/slots/fused/sharded/ring/...)
#   engine.py       the Engine façade + upstream baseline + null layers
#   blockdev.py     ublk-style public API: VolumeManager/Volume, byte I/O
from repro.core import dbs, ring, slots, transport  # noqa: F401
from repro.core.backends import (Backend, available_backends,  # noqa: F401
                                 make_backend, register_backend)
from repro.core.blockdev import IOFuture, Volume, VolumeManager  # noqa: F401
from repro.core.engine import Engine, EngineConfig, UpstreamEngine  # noqa: F401
from repro.core.frontend import (MultiQueueFrontend, Request,  # noqa: F401
                                 ShardedFrontend, UpstreamFrontend)
from repro.core.fused import (FusedBatch, fused_step,  # noqa: F401
                              fused_step_read)
from repro.core.replication import (ReplicaGroup,  # noqa: F401
                                    ShardedReplicaGroup)
from repro.core.ring import (CQ, SQE, RingEngine,  # noqa: F401
                             RingFrontend)
from repro.core.sharded import EnginePool  # noqa: F401
from repro.core.transport import (LocalTransport,  # noqa: F401
                                  ReplicaTransport, SimNetTransport,
                                  WireMsg, available_transports,
                                  make_transport, register_transport)
