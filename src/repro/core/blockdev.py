"""The ublk-style public block-device API: byte-addressed async volumes.

This is the repo's analogue of the paper's third pillar — the **ublk
frontend** that exposes the optimized engine as a plain virtual block
device, so consumers never see slot tables, SQE batches or page/block
geometry. Callers open a ``VolumeManager`` (which owns one registered
engine backend — core/backends.py — and its pump loop), get ``Volume``
handles, and issue **byte-addressed asynchronous I/O**:

    mgr = VolumeManager(backend="ring", n_shards=4)
    vol = mgr.create()
    fut = vol.pwrite(4096, b"hello")       # async: an IOFuture
    assert vol.read(4096, 5) == b"hello"   # sync convenience wrapper

Byte -> page translation (one ``Volume`` spans ``max_pages`` DBS pages):

    block_bytes = payload_elems          # one engine payload lane = 1 block
    page_bytes  = page_blocks * block_bytes
    byte off    -> page  off // page_bytes,
                   block (off % page_bytes) // block_bytes

Each byte is carried in one float32 payload lane (values 0..255 are exact in
float32, so round-trips are bit-faithful on every backend). **Aligned spans
map straight onto batched block ops**: one ``pwrite``/``pread`` fans out to
one SQE per covered block, they ride the engine's normal admission batches,
and complete on the pump's single CQ fetch — the API adds no host hops.
**Unaligned edges** take an in-API read-modify-write path: the partial edge
blocks are read back synchronously (ordered behind every in-flight op),
merged on the host, and written as whole blocks.

Ordering semantics (standard for async block devices — NVMe/ublk give no
ordering between in-flight commands either, but this API is stricter where
it is free to be):

- per volume, **submission order is execution order** for write->read,
  write->write (disjoint blocks), and anything->control: a volume's
  requests ride one admission queue, batches apply writes before reads and
  data before control, and the manager routes control ops through the same
  stream (in-band SQEs on ``backend="ring"``, flush-then-host-dispatch
  elsewhere),
- **overlapping-block hazards** (a write racing an in-flight read or write
  of the same block) are detected by the manager and fenced with a flush,
  so even adversarial interleavings keep sequential semantics.

``discard`` TRIMs: fully-covered pages are unmapped (in-band ``UNMAP`` SQEs
on the ring), partial edge spans are zero-filled through the RMW write
path; reads of discarded or never-written bytes return zeros (the engines'
hole-masked read path).

Snapshot/clone are volume-granular: ``vol.snapshot()`` freezes the head,
``vol.clone()`` forks a CoW copy whose writes diverge extent-by-extent.

The manager's geometry parameters mirror ``EngineConfig``; ``backend=``
names any registered backend ("loop" | "slots" | "fused" | "sharded" |
"ring" | "upstream" | "host"), and ``transport=`` / ``write_policy=`` /
``read_policy=`` name the controller<->replica wire and its mirroring
policies (core/transport.py — host-dispatch backends take the full policy
matrix; the in-program engines mirror-to-all inside the step). The manager
is a context manager: ``with VolumeManager(...) as mgr:`` drains all
in-flight I/O (including write-behind replica traffic) on exit, and
``close()`` makes further submissions raise. See docs/ARCHITECTURE.md
("Public API").
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import Engine, EngineConfig
from repro.core.frontend import Request
from repro.core.transport import (MSG_CLONE, MSG_CREATE, MSG_DELETE,
                                  MSG_SNAPSHOT, MSG_UNMAP, MSG_WRITE,
                                  WireMsg)

# control kinds the durability journal records (core -> journal opcode)
_JOURNAL_CTRL = {"snapshot": MSG_SNAPSHOT, "clone": MSG_CLONE,
                 "delete": MSG_DELETE}


def _bytes_to_lanes(data: bytes) -> np.ndarray:
    """One byte per float32 payload lane (0..255 — exact in float32)."""
    return np.frombuffer(data, np.uint8).astype(np.float32)


def _lanes_to_bytes(arr) -> bytes:
    return np.asarray(arr).astype(np.uint8).tobytes()


class IOFuture:
    """Completion handle for one byte-addressed I/O call.

    Wraps the engine ``Request`` fan-out of a single ``pread``/``pwrite``/
    ``discard``: ``done()`` polls the requests' completion statuses,
    ``result()`` drives the manager's pump loop until complete and returns
    the call's value (``bytes`` for reads, the byte count for writes and
    discards). The value is assembled ONCE and cached: repeated ``result()``
    calls are idempotent — no re-assembly and no redundant flush after the
    first success. Raises ``OSError`` if any constituent op completed with
    a non-OK status."""

    _UNSET = object()

    __slots__ = ("_mgr", "_reqs", "_assemble", "_value", "_cached")

    def __init__(self, mgr: "VolumeManager", reqs: List[Request],
                 assemble: Optional[Callable[[], Any]] = None,
                 value: Any = None):
        self._mgr = mgr
        self._reqs = reqs
        self._assemble = assemble
        self._value = value
        self._cached = IOFuture._UNSET

    def done(self) -> bool:
        return (self._cached is not IOFuture._UNSET
                or all(r.status is not None for r in self._reqs))

    def latency(self) -> int:
        """Max completion latency (pump ticks) across the fan-out."""
        return max((r.latency or 0 for r in self._reqs), default=0)

    def completion_tick(self) -> int:
        """Absolute pump tick the last fan-out op completed on
        (``submission tick + latency - 1``; the frontend stamps both ends
        on the same clock). Deterministic across replays — the harness's
        replay-determinism gate compares per-op completion ticks."""
        return max((r.tick + (r.latency or 1) - 1 for r in self._reqs),
                   default=0)

    def result(self) -> Any:
        if self._cached is not IOFuture._UNSET:
            return self._cached
        if not self.done():
            self._mgr.flush()
        if not self.done():
            raise RuntimeError("I/O did not complete after a full drain")
        # negative statuses are I/O errors; positive ones (ST_MISMATCH from
        # compare_and_write / verify_on_read) are op-level outcomes the
        # caller inspects on the result — not exceptions
        bad = [r for r in self._reqs if r.status < 0]
        if bad:
            raise OSError(f"{bad[0].kind} failed with status {bad[0].status} "
                          f"(volume {bad[0].volume}, page {bad[0].page})")
        self._cached = (self._assemble() if self._assemble is not None
                        else self._value)
        return self._cached


@dataclass
class ComputeResult:
    """Outcome of one ``Volume.compute`` call.

    ``value`` is the function's scalar result (checksum, match count,
    actual blocksum for ``compare_and_write``...), ``status`` its op status
    (0 = OK, ``ST_MISMATCH`` = compare/verify failed — a *result*, not an
    I/O error), and ``payload`` the output lanes (matching pages for
    ``filter_pages``, the block contents for ``verify_on_read``)."""
    fn: str
    value: int
    status: int
    payload: np.ndarray = field(repr=False)

    @property
    def ok(self) -> bool:
        return self.status == 0

    def pages(self) -> List[int]:
        """Decode the payload as a page list (``filter_pages``): the
        non-negative lanes, in ascending order."""
        return [int(v) for v in np.asarray(self.payload).reshape(-1)
                if v >= 0]

    def data(self) -> bytes:
        """Decode the payload as block bytes (``verify_on_read``)."""
        return _lanes_to_bytes(self.payload)


class Volume:
    """A byte-addressed block-device handle (one DBS volume)."""

    def __init__(self, mgr: "VolumeManager", vid: int):
        self.mgr = mgr
        self.vid = vid

    # -- async byte I/O -----------------------------------------------------
    def pread(self, off: int, nbytes: int) -> IOFuture:
        return self.mgr.pread(self.vid, off, nbytes)

    def pwrite(self, off: int, data: bytes) -> IOFuture:
        return self.mgr.pwrite(self.vid, off, data)

    def discard(self, off: int, nbytes: int) -> IOFuture:
        return self.mgr.discard(self.vid, off, nbytes)

    def flush(self, durable: bool = False) -> None:
        """Drain in-flight I/O; ``durable=True`` additionally fsyncs the
        durability journal (repro/durability) — the write barrier."""
        self.mgr.flush(durable=durable)

    # -- computational storage ------------------------------------------------
    def compute(self, fn: str, off: int = 0, nbytes: Optional[int] = None,
                *, arg: int = 0, data: Optional[bytes] = None) -> IOFuture:
        """Run a registered storage function **in-band** against this
        volume's bytes (repro/compute). ``fn`` names a registry entry
        (``available_storage_fns()``); range-scoped functions take a
        page-aligned ``[off, off+nbytes)`` span (default: the whole
        device), block-scoped ones a single block at ``off``. ``arg`` is
        the function's scalar parameter, ``data`` the input block for
        writing functions (``compare_and_write``'s new contents). Returns
        an ``IOFuture`` resolving to a ``ComputeResult``."""
        return self.mgr.compute(self.vid, fn, off, nbytes, arg=arg,
                                data=data)

    # -- sync convenience wrappers -------------------------------------------
    def read(self, off: int, nbytes: int) -> bytes:
        return self.pread(off, nbytes).result()

    def write(self, off: int, data: bytes) -> int:
        return self.pwrite(off, data).result()

    # -- volume lifecycle -----------------------------------------------------
    def snapshot(self):
        """Freeze the volume head; returns the snapshot id (backends whose
        stores don't name snapshots return None)."""
        return self.mgr.snapshot(self.vid)

    def clone(self) -> Optional["Volume"]:
        return self.mgr.clone(self.vid)

    def delete(self) -> None:
        self.mgr.delete(self.vid)

    @property
    def capacity(self) -> int:
        return self.mgr.capacity

    @property
    def block_bytes(self) -> int:
        return self.mgr.block_bytes

    @property
    def page_bytes(self) -> int:
        return self.mgr.page_bytes

    def __repr__(self):
        return (f"Volume(vid={self.vid}, capacity={self.capacity}B, "
                f"backend={self.mgr.backend_name!r})")


class VolumeManager:
    """Owns one registered engine backend and hands out ``Volume`` handles.

    ``backend`` names a registry entry (core/backends.py); engine geometry
    kwargs mirror ``EngineConfig``. The manager owns the pump loop: every
    data op is submitted asynchronously and completed by ``flush()`` /
    ``IOFuture.result()`` driving the backend's (pipelined, single-fetch)
    drain.

    Per-volume ordering: all of a volume's requests are routed onto one
    admission queue (request ids are minted so ``req_id % n_queues`` is a
    function of the volume), which — together with the engines'
    writes-before-reads-before-control batch phases — makes submission
    order execution order. Overlapping-block write hazards are fenced with
    a flush (module docstring).
    """

    def __init__(self, backend: str = "ring", *, n_shards: int = 1,
                 n_replicas: int = 2, payload_elems: int = 64,
                 page_blocks: int = 32, n_extents: int = 1024,
                 max_volumes: int = 16, max_pages: int = 256,
                 n_queues: int = 4, n_slots: int = 256, batch: int = 64,
                 storage: str = "dbs", null_backend: bool = False,
                 null_storage: bool = False, cow: str = "auto",
                 kernel: str = "auto", transport: str = "local",
                 write_policy: str = "all", read_policy: str = "rr",
                 transport_opts: Optional[Dict[str, Any]] = None,
                 payload_shape: Optional[Tuple[int, ...]] = None,
                 journal: Any = None, tier: Any = None):
        # payload_shape overrides the byte-API's flat (payload_elems,) lane
        # layout with an arbitrary per-block tensor — the serving engine
        # stores one token's K/V for every layer in one block
        # ((n_planes, KV, hd), serving/engine.py). The byte-addressed
        # pread/pwrite surface assumes the flat layout; embedders with a
        # custom shape drive raw Requests + the device views below instead.
        self.payload_shape = (tuple(payload_shape)
                              if payload_shape is not None
                              else (payload_elems,))
        self.engine = Engine(EngineConfig(
            comm=backend, n_shards=n_shards, n_replicas=n_replicas,
            payload_shape=self.payload_shape, page_blocks=page_blocks,
            n_extents=n_extents, max_volumes=max_volumes,
            max_pages=max_pages, n_queues=n_queues, n_slots=n_slots,
            batch=batch, storage=storage, null_backend=null_backend,
            null_storage=null_storage, cow=cow, kernel=kernel,
            transport=transport,
            write_policy=write_policy, read_policy=read_policy,
            transport_opts=transport_opts, journal=journal, tier=tier))
        # durability journal (repro/durability/journal.py): the manager
        # buffers one WireMsg per mutating public-API op and group-commits
        # the buffer — ONE append + seal — at every pump boundary, BEFORE
        # the engine applies the batch (write-ahead)
        self._journal = self.engine.journal
        self._jbuf: List[WireMsg] = []
        self._closed = False
        self.backend_name = backend
        self.block_bytes = payload_elems
        self.page_blocks = page_blocks
        self.page_bytes = page_blocks * payload_elems
        self.capacity = max_pages * self.page_bytes
        self._nq = max(1, n_queues)
        self._ns = max(1, n_shards)
        self._seq = itertools.count()
        # control ops ride the data stream when the backend's submission
        # path accepts them (the ring); otherwise they fence host-side
        self._inband = "snapshot" in self.engine.data_kinds
        # the hot-path submit: the manager only mints valid data kinds, so
        # aligned spans go straight to the backend's frontend (the same
        # queues Engine.submit feeds, minus the per-request kind check)
        fe = self.engine.frontend
        self._fast_submit = (fe.submit if fe is not None
                             else self.engine.impl.submit)
        self.volumes: Dict[int, Volume] = {}
        # per-volume in-flight absolute-block sets, for the
        # overlapping-write hazard fence (O(span) per op; the counter
        # makes the no-traffic fence check O(1))
        self._pending_w: Dict[int, set] = {}
        self._pending_r: Dict[int, set] = {}
        self._n_pending = 0

    # ------------------------------------------------------------ plumbing
    def _rid(self, vid: int) -> int:
        """Mint a request id that pins this volume's stream to one admission
        queue of its shard (``req_id % n_queues`` is volume-determined), so
        per-volume FIFO survives the round-robin drain."""
        return next(self._seq) * self._nq + (vid // self._ns) % self._nq

    def _vid(self, vol) -> int:
        return vol.vid if isinstance(vol, Volume) else int(vol)

    def _check_span(self, off: int, nbytes: int) -> None:
        if off < 0 or nbytes < 0 or off + nbytes > self.capacity:
            raise ValueError(f"byte span [{off}, {off + nbytes}) outside "
                             f"device capacity {self.capacity}")

    def _fence_write(self, vid: int, lo: int, hi: int) -> None:
        """A write overlapping an in-flight read or write of the same block
        must not share its batch window — flush first (sequential
        semantics; disjoint-block and same-page traffic needs no fence)."""
        pw = self._pending_w.get(vid)
        pr = self._pending_r.get(vid)
        if pw is None and pr is None:
            return
        span = range(lo, hi)
        if ((pw and not pw.isdisjoint(span))
                or (pr and not pr.isdisjoint(span))):
            self.flush()

    def _track(self, table: Dict[int, set], vid: int, lo: int,
               hi: int) -> None:
        self._n_pending += 1
        s = table.get(vid)
        if s is None:
            table[vid] = set(range(lo, hi))
        else:
            s.update(range(lo, hi))

    def submit(self, req: Request) -> None:
        """Raw request-level escape hatch (validated at the backend's
        submission boundary)."""
        self._check_open()
        self.engine.submit(req)

    # ------------------------------------------------------------ journaling
    def _journal_seal(self) -> None:
        """Group commit: append the buffered records + ONE seal as a single
        file write (write-ahead: called before the engine pumps/drains)."""
        if self._journal is not None and self._jbuf:
            self._journal.append_batch(self._jbuf)
            self._jbuf.clear()

    def attach_journal(self, journal) -> None:
        """Adopt a (recovered, tail-truncated) journal: subsequent mutating
        ops append to it. ``durability.recovery.recover``'s reattach hook."""
        self._journal = journal
        self.engine.journal = journal
        self.engine._journal_owned = True

    def pump(self) -> int:
        if self._jbuf:
            self._journal_seal()
        done = self.engine.pump()
        if self._n_pending and self.engine.depth() == 0:
            # queues empty after a pump => every submitted op completed:
            # drop the hazard tracking so incremental pump() callers don't
            # accumulate stale blocks (and spurious fences) until a flush
            self._pending_w.clear()
            self._pending_r.clear()
            self._n_pending = 0
        return done

    def drain(self) -> int:
        return self.flush()

    def flush(self, durable: bool = False) -> int:
        """Complete everything in flight (the backends' pipelined drain —
        one device fetch per pump). Returns the number of completions.

        ``durable=True`` is the durability barrier: after the drain the
        journal is fsync'd, so every acked op survives a crash (without it,
        sealed records sit in OS buffers — crash-consistent but only as
        durable as the page cache)."""
        self._journal_seal()
        done = self.engine.drain()
        if self._n_pending:
            self._pending_w.clear()
            self._pending_r.clear()
            self._n_pending = 0
        if durable and self._journal is not None:
            self._journal.sync()
        return done

    def close(self) -> int:
        """Drain every in-flight I/O (including write-behind replica
        transport traffic) and close the manager: further submissions
        raise, ``flush``/``pump`` stay callable no-ops, handed-out
        ``IOFuture``s resolve (their requests completed in the drain).
        Idempotent. Returns the number of completions the final drain
        delivered."""
        if self._closed:
            return 0
        done = self.flush()
        storage = self.engine.backend
        if storage is not None and hasattr(storage, "drain_transports"):
            storage.drain_transports()    # quorum/async stragglers land
        if self._journal is not None:
            self._journal.sync()
            if self.engine._journal_owned:
                self._journal.close()
        self._closed = True
        return done

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "VolumeManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise ValueError("I/O on a closed VolumeManager")

    def stats(self) -> Dict[str, Any]:
        out = {"completed": self.engine.completed,
               "queued": self.engine.depth(),
               "backend": self.backend_name}
        table = getattr(self.engine.frontend, "table", None)
        if table is not None:
            from repro.core import slots
            out["slots_active"] = int(np.asarray(slots.n_active(table)))
        if self._journal is not None:
            out["journal"] = {"seq": self._journal.seq,
                              "appends": self._journal.appends,
                              "records": self._journal.records}
        tier = getattr(self.engine.impl, "tier", None)
        if tier is not None:
            out["tier"] = tier.to_dict()
        return out

    # ------------------------------------------------------------ lifecycle
    def create(self) -> Volume:
        self._check_open()
        vid = self.engine.create_volume()
        if vid is None or vid < 0:
            raise RuntimeError("volume table full")
        if self._journal is not None:
            self._jbuf.append(WireMsg(op=MSG_CREATE, volume=vid,
                                      meta=(vid, 0)))
        vol = Volume(self, vid)
        self.volumes[vid] = vol
        return vol

    def open(self, vid: int) -> Volume:
        return self.volumes.get(vid) or self.volumes.setdefault(
            vid, Volume(self, vid))

    def _control_sync(self, kind: str, vid: int, **kw):
        """One control op, ordered behind the volume's in-flight stream:
        in-band SQE through the volume's own queue on the ring, host-side
        dispatch behind a flush elsewhere. Drains to completion either way."""
        self._check_open()
        if self._inband and kind in ("snapshot", "clone", "delete"):
            r = Request(req_id=self._rid(vid), kind=kind, volume=vid)
            self.engine.submit(r)
            self.flush()
            res = r.result
        else:
            self.flush()
            res = self.engine.control(kind, volume=vid, **kw)
        op = _JOURNAL_CTRL.get(kind)
        if op is not None and self._journal is not None:
            # the engine's result id rides meta so recovery can ASSERT its
            # replay allocated the same volume/snapshot ids
            rid = -1 if res is None else int(res)
            self._jbuf.append(WireMsg(op=op, volume=vid, meta=(rid, 0)))
        return res

    def snapshot(self, vol) -> Any:
        return self._control_sync("snapshot", self._vid(vol))

    def clone(self, vol) -> Optional[Volume]:
        """Fork a CoW copy; returns the new Volume (None on failure)."""
        new_vid = self._control_sync("clone", self._vid(vol))
        if new_vid is None or new_vid < 0:
            return None
        child = Volume(self, new_vid)
        self.volumes[new_vid] = child
        return child

    def delete(self, vol) -> None:
        vid = self._vid(vol)
        self._control_sync("delete", vid)
        self.volumes.pop(vid, None)

    # ------------------------------------------------------------ byte I/O
    def pread(self, vol, off: int, nbytes: int) -> IOFuture:
        self._check_open()
        vid = self._vid(vol)
        self._check_span(off, nbytes)
        if nbytes == 0:
            return IOFuture(self, [], value=b"")
        bb, pb = self.block_bytes, self.page_blocks
        first, last = off // bb, (off + nbytes - 1) // bb
        reqs = []
        submit = self._fast_submit
        for ab in range(first, last + 1):
            r = Request(req_id=self._rid(vid), kind="read", volume=vid,
                        page=ab // pb, block=ab % pb)
            submit(r)
            reqs.append(r)
        self._track(self._pending_r, vid, first, last + 1)
        head = off - first * bb

        def assemble() -> bytes:
            if len(reqs) == 1:                   # fast path: one block
                r = reqs[0]
                lanes = (np.zeros(bb, np.float32) if r.result is None
                         else np.asarray(r.result))
                return _lanes_to_bytes(lanes)[head:head + nbytes]
            parts = [np.zeros(bb, np.float32) if r.result is None
                     else np.asarray(r.result, np.float32) for r in reqs]
            return _lanes_to_bytes(np.concatenate(parts))[head:head + nbytes]
        return IOFuture(self, reqs, assemble=assemble)

    def _read_span_sync(self, vid: int, off: int, nbytes: int) -> bytes:
        fut = self.pread(vid, off, nbytes)
        return fut.result()          # drains: ordered behind all in-flight

    def pwrite(self, vol, off: int, data) -> IOFuture:
        self._check_open()
        vid = self._vid(vol)
        data = bytes(data)
        n = len(data)
        self._check_span(off, n)
        if n == 0:
            return IOFuture(self, [], value=0)
        bb, pb = self.block_bytes, self.page_blocks
        first, last = off // bb, (off + n - 1) // bb
        head = off - first * bb
        tail = (last + 1) * bb - (off + n)
        if head or tail:
            # in-API read-modify-write: fetch the partial edge blocks
            # synchronously (the read drains behind every in-flight op, so
            # it observes the volume's full submission history), merge the
            # new bytes in, and write whole blocks. A span inside ONE block
            # has both edges in that block: one read covers both.
            span = bytearray((last - first + 1) * bb)
            if first == last:
                span[:] = self._read_span_sync(vid, first * bb, bb)
            else:
                if head:
                    span[:bb] = self._read_span_sync(vid, first * bb, bb)
                if tail:
                    span[-bb:] = self._read_span_sync(vid, last * bb, bb)
            span[head:head + n] = data
            data = span
        if self._n_pending:
            self._fence_write(vid, first, last + 1)
        submit = self._fast_submit
        if first == last:                        # fast path: one block
            r = Request(req_id=self._rid(vid), kind="write", volume=vid,
                        page=first // pb, block=first % pb,
                        payload=_bytes_to_lanes(data))
            submit(r)
            reqs = [r]
        else:
            view = memoryview(data)
            reqs = []
            for i, ab in enumerate(range(first, last + 1)):
                r = Request(req_id=self._rid(vid), kind="write", volume=vid,
                            page=ab // pb, block=ab % pb,
                            payload=_bytes_to_lanes(
                                view[i * bb:(i + 1) * bb]))
                submit(r)
                reqs.append(r)
        self._track(self._pending_w, vid, first, last + 1)
        if self._journal is not None:
            # ONE record per pwrite: the POST-RMW block-aligned lanes, so
            # replay applies them directly — no re-merge needed (replay has
            # already applied every earlier record, so the merged edge
            # bytes are exactly what this record carries)
            # bytes(data) is the post-RMW whole-block span already in hand:
            # the record costs two list comprehensions, no numpy, and the
            # journal stores one uint8 per lane
            self._jbuf.append(WireMsg(
                op=MSG_WRITE, volume=vid,
                pages=[r.page for r in reqs],
                blocks=[r.block for r in reqs],
                payload=bytes(data)))
        return IOFuture(self, reqs, value=n)

    def _replay_write(self, vid: int, pages, blocks, lanes) -> None:
        """Recovery replay of one journaled ``MSG_WRITE`` record: re-submit
        its block lanes through the normal path — hazard fence included, so
        replay re-serializes exactly the overlapping spans the original run
        fenced (durability/recovery.py)."""
        self._check_open()
        pb = self.page_blocks
        abs_blocks = np.asarray(pages, np.int64) * pb + np.asarray(blocks)
        lo, hi = int(abs_blocks.min()), int(abs_blocks.max()) + 1
        if self._n_pending:
            self._fence_write(vid, lo, hi)
        submit = self._fast_submit
        for p, b, lane in zip(pages, blocks, lanes):
            submit(Request(req_id=self._rid(vid), kind="write", volume=vid,
                           page=int(p), block=int(b),
                           payload=np.asarray(lane, np.float32)))
        self._track(self._pending_w, vid, lo, hi)

    def discard(self, vol, off: int, nbytes: int) -> IOFuture:
        """TRIM ``[off, off+nbytes)``: fully covered pages are unmapped
        (extents freed — in-band UNMAP SQEs on the ring), partial edges are
        zero-filled through the write path. Reads of the span return zeros
        afterwards."""
        self._check_open()
        vid = self._vid(vol)
        self._check_span(off, nbytes)
        if nbytes == 0:
            return IOFuture(self, [], value=0)
        pby = self.page_bytes
        end = off + nbytes
        first_full = -(-off // pby)              # ceil
        last_full = end // pby
        reqs: List[Request] = []
        if first_full < last_full:
            reqs.extend(self._unmap_pages(vid,
                                          list(range(first_full, last_full))))
            edges = [(off, first_full * pby), (last_full * pby, end)]
        else:
            edges = [(off, end)]
        for a, b in edges:
            if b > a:
                reqs.extend(self.pwrite(vid, a, b"\x00" * (b - a))._reqs)
        return IOFuture(self, reqs, value=nbytes)

    def _unmap_pages(self, vid: int, pages: List[int]) -> List[Request]:
        """Unmap fully covered pages (extents freed): in-band UNMAP SQEs on
        the ring, flush-then-host-dispatch elsewhere. Journaled as ONE
        ``MSG_UNMAP`` record; also recovery's replay entry for that record."""
        reqs: List[Request] = []
        if self._inband:
            for p in pages:
                r = Request(req_id=self._rid(vid), kind="unmap",
                            volume=vid, page=p)
                self.engine.submit(r)
                reqs.append(r)
        else:
            self.flush()                     # order: behind in-flight ops
            self.engine.unmap(vid, pages)
        if self._journal is not None and pages:
            self._jbuf.append(WireMsg(op=MSG_UNMAP, volume=vid,
                                      pages=np.asarray(pages, np.int32)))
        return reqs

    # ------------------------------------------------- computational storage
    def compute(self, vol, fn: str, off: int = 0,
                nbytes: Optional[int] = None, *, arg: int = 0,
                data: Optional[bytes] = None) -> IOFuture:
        """In-band storage function over a volume's bytes (see
        ``Volume.compute``). On backends whose submission path accepts
        ``kind="compute"`` (the ring executes it inside the fused step; the
        host oracle runs the sequential reference in its pump FIFO) this is
        one async SQE riding the volume's queue — ordered like any other
        request. Elsewhere (fused/sharded) it fences with a flush and runs
        the same device computation against the replica pools
        (repro.compute.exec.device_compute)."""
        self._check_open()
        from repro.compute import make_storage_fn, storage_fn_id
        vid = self._vid(vol)
        entry = make_storage_fn(fn)           # unknown names raise here
        bb, pby = self.block_bytes, self.page_bytes
        if entry.scope == "range":
            if nbytes is None:
                nbytes = self.capacity - off
            if off % pby or nbytes % pby or nbytes <= 0:
                raise ValueError(
                    f"range-scoped {fn!r} needs a page-aligned non-empty "
                    f"span (page_bytes={pby}), got [{off}, {off + nbytes})")
            self._check_span(off, nbytes)
            page, block = off // pby, nbytes // pby   # start page, page count
        else:                                  # scope == "block"
            if off % bb:
                raise ValueError(f"block-scoped {fn!r} needs a block-aligned "
                                 f"offset (block_bytes={bb}), got {off}")
            if nbytes is None:
                nbytes = bb
            if nbytes != bb:
                raise ValueError(f"block-scoped {fn!r} covers exactly one "
                                 f"block ({bb}B), got nbytes={nbytes}")
            self._check_span(off, nbytes)
            ab = off // bb
            page, block = ab // self.page_blocks, ab % self.page_blocks
        payload = None
        if entry.writes:
            if data is None:
                raise ValueError(f"{fn!r} writes: pass data= (the new "
                                 "block contents)")
            data = bytes(data)
            if len(data) != bb:
                raise ValueError(f"{fn!r} data must be one block "
                                 f"({bb}B), got {len(data)}")
            payload = _bytes_to_lanes(data)
        elif data is not None:
            raise ValueError(f"{fn!r} does not take data=")

        if entry.writes and self._journal is not None:
            # only MUTATING storage functions are journaled (read-only ones
            # don't change state); replay re-executes them in place — their
            # outcome is a pure function of the replayed device state
            from repro.durability.journal import OP_COMPUTE
            self._jbuf.append(WireMsg(
                op=OP_COMPUTE, volume=vid,
                pages=np.asarray([page], np.int32),
                blocks=np.asarray([block], np.int32),
                extents=fn.encode(),
                meta=(int(arg), 1 if entry.scope == "range" else 0),
                payload=data))

        def wrap(value, status, lanes) -> ComputeResult:
            return ComputeResult(fn=fn, value=int(value), status=int(status),
                                 payload=np.asarray(lanes, np.float32))

        if "compute" in self.engine.data_kinds:    # ring + host: in-queue
            r = Request(req_id=self._rid(vid), kind="compute", volume=vid,
                        page=page, block=block, payload=payload, fn=fn,
                        arg=int(arg), fnid=storage_fn_id(fn))
            self._fast_submit(r)

            def assemble() -> ComputeResult:
                value, lanes = (r.result if r.result is not None
                                else (0, np.zeros(self.payload_shape,
                                                  np.float32)))
                return wrap(value, r.status, lanes)
            return IOFuture(self, [r], assemble=assemble)
        # device backends without an in-band compute path: fence with a
        # flush (ordering behind in-flight I/O), then run the very same
        # device computation against the replica pools
        from repro.compute.exec import device_compute
        self.flush()
        value, status, lanes = device_compute(
            self.engine, vid, fn, page, block, int(arg), payload)
        return IOFuture(self, [], value=wrap(value, status, lanes))

    # ------------------------------------- embedder control-plane passthrough
    @property
    def state(self):
        """The backing DBSState (``backend="host"`` only) — the control
        plane embedders read block tables from (serving/engine.py)."""
        return self.engine.impl.state

    def alloc_pages(self, vols, pages, mask=None, bits=None):
        """Page-granular allocation/CoW on the host backend's state; returns
        the DBS ``WriteOps`` for an external data plane (serving KV pools).
        Host backend only — on the fused/sharded engines page allocation IS
        the write SQE path: submit zero-payload writes and ``flush()``, and
        every lane's allocation + CoW resolution rides ONE pumped program
        (the batching the serving engine's per-step admission relies on)."""
        return self.engine.impl.alloc_pages(vols, pages, mask=mask,
                                            bits=bits)

    # --------------------------------------------- device-resident KV views
    # The zero-copy serving path (serving/engine.py) reads these: the
    # extent map a paged-attention kernel indexes through, and the engine
    # payload pools it treats as the KV cache. All views are device arrays —
    # nothing here syncs to the host.
    def device_extent_map(self):
        """The device-resident flattened extent map as ONE (V, P) int32
        table over *global* volume ids (holes/unallocated pages -1).

        host backend: the oracle state's table. fused: replica 0's (the
        replicas execute identical control sequences — their tables agree).
        sharded: the per-shard (S, V_local, P) tables are fused into global
        coordinates — extent ids are offset by ``shard * (E+1)`` to index
        the flattened pool of ``device_pools`` and rows are reordered so
        row ``v`` is global volume ``v`` (= local * S + shard)."""
        impl = self.engine.impl
        if hasattr(impl, "state"):                      # host oracle
            return impl.state.table
        storage = self.engine.backend
        if storage is None:
            raise RuntimeError("null backend holds no extent map")
        if hasattr(storage, "states"):                  # sharded (stacked)
            import jax.numpy as jnp
            tbl = storage.states[0].table               # (S, Vl, P)
            s = storage.n_shards
            stride = self.engine.cfg.n_extents + 1      # pool rows per shard
            off = (jnp.arange(s, dtype=tbl.dtype) * stride)[:, None, None]
            flat = jnp.where(tbl >= 0, tbl + off, -1)
            return flat.transpose(1, 0, 2).reshape(-1, tbl.shape[2])
        states, _pools = storage.device_state()         # fused ReplicaGroup
        return states[0].table

    def device_pools(self):
        """The engine payload pools as a tuple of device arrays, one per
        (healthy) replica, each ``(rows, page_blocks, *payload_shape)`` —
        rows = E+1 on the fused engine, S*(E+1) on the sharded pool (the
        per-shard pools concatenated; ``device_extent_map`` hands out row
        ids in exactly this coordinate system)."""
        storage = self.engine.backend
        if storage is None:
            raise RuntimeError("null backend holds no pools")
        if hasattr(storage, "states"):                  # sharded (stacked)
            _st, pools, _h = storage.device_state()
            return tuple(p.reshape((-1,) + p.shape[2:]) for p in pools)
        _st, pools = storage.device_state()
        return tuple(pools)

    def set_device_pools(self, pools) -> None:
        """Write mutated pools (same shapes ``device_pools`` returned) back
        to the replicas — the commit half of an external compute step that
        scattered into the pools (the serving decode program)."""
        storage = self.engine.backend
        if storage is None:
            raise RuntimeError("null backend holds no pools")
        if hasattr(storage, "states"):                  # sharded (stacked)
            states, cur, _h = storage.device_state()
            reshaped = tuple(p.reshape(c.shape)
                             for p, c in zip(pools, cur))
            storage.set_device_state(states, reshaped)
            return
        states, _cur = storage.device_state()
        storage.set_device_state(states, tuple(pools))

    def __repr__(self):
        return (f"VolumeManager(backend={self.backend_name!r}, "
                f"block_bytes={self.block_bytes}, "
                f"page_bytes={self.page_bytes}, capacity={self.capacity})")
