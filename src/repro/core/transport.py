"""Controller<->replica transport: the pluggable wire between the two.

Paper §III describes the Longhorn controller talking to its replicas over
the network — every write fans out into messages that must be delivered
and acked, reads pick one replica, and a failed replica is rebuilt by
*streaming* data from a healthy copy. Until this module the repo's
controller (core/replication.py) reached into replica state with direct
method calls and rebuilt by copying the whole extent pool; there was no
boundary a real network (or a second process, or a remote engine) could
slot into. This is the transport fix, mirroring the ring's SQE/CQE design
one layer down:

- **WireMsg** — an opcode-tagged message (the controller->replica analogue
  of the ring ``SQE``): WRITE / READ / the volume-control verbs / the
  rebuild stream verbs (WATERMARKS / FETCH_DELTA / FETCH_PAGES /
  PUSH_PAGES / ADOPT_META). One message schema for data, control AND
  rebuild traffic — nothing moves between controller and replica except
  through messages.
- **Replica / StackedReplica** — the replica-side *endpoint*: owns one
  replica's device-resident ``DBSState`` + payload pool and executes wire
  messages against it (``StackedReplica`` holds a leading (S,) shard axis —
  one endpoint carries this replica's slice of every engine shard, the
  form the vmapped pool step threads).
- **ReplicaTransport** — the delivery contract: ``post(msg) -> MsgFuture``,
  ``tick()`` advances simulated time, per-opcode ``sent`` counters and a
  ``pages_moved`` counter (pool rows through the rebuild stream — what the
  delta-rebuild tests assert on).
- **LocalTransport** — in-process immediate delivery: a ``post`` IS the
  endpoint call, bit-identical to the pre-transport direct path.
- **DeviceTransport** — LocalTransport over a (possibly stacked)
  device-resident endpoint. On the fused/sharded/ring engines the *data
  plane* never rides messages at all: the controller threads the endpoint
  pytrees through the compiled step (``device_state``/``set_device_state``)
  and the transport carries control + rebuild traffic only.
- **SimNetTransport** — a simulated network: per-message latency in ticks,
  a bounded in-flight window (posting past it blocks — backpressure),
  injectable drop (TCP-style head-of-line retransmit, so delivery stays
  FIFO) and reorder (deliberately breaks FIFO — fault-injection only).
  This is what makes the write/read *policies* (core/replication.py)
  benchmarkable: quorum-vs-all only differs when acks take time.

``register_transport`` / ``make_transport`` mirror the backend registry
(core/backends.py): transports are named factories, and everything above
the boundary — ``EngineConfig.transport``, ``VolumeManager(transport=)`` —
is just a name lookup here. See docs/ARCHITECTURE.md ("Replica transport").
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbs

# ---------------------------------------------------------------------------
# the wire-message opcode table (WireMsg.op)
# ---------------------------------------------------------------------------
MSG_CREATE = 0        # volume control (mirrored by the controller)
MSG_SNAPSHOT = 1
MSG_CLONE = 2
MSG_UNMAP = 3
MSG_DELETE = 4
MSG_WRITE = 5         # data plane: one batched block write
MSG_READ = 6          # data plane: one batched block read
MSG_QUERY_REV = 7     # consistency: the replica's metadata revision
MSG_WATERMARKS = 8    # rebuild: the replica's per-page revision watermarks
MSG_FETCH_DELTA = 9   # rebuild: extents newer than the given watermarks
MSG_FETCH_PAGES = 10  # rebuild: stream a chunk of pool rows out (donor)
MSG_PUSH_PAGES = 11   # rebuild: stream a chunk of pool rows in (target)
MSG_ADOPT_META = 12   # rebuild: adopt the donor's metadata state (commit)

MSG_NAMES = ("CREATE", "SNAPSHOT", "CLONE", "UNMAP", "DELETE", "WRITE",
             "READ", "QUERY_REV", "WATERMARKS", "FETCH_DELTA", "FETCH_PAGES",
             "PUSH_PAGES", "ADOPT_META")


@dataclass
class WireMsg:
    """One opcode-tagged controller->replica message (the SQE of this
    boundary). Field use per opcode:

    | op          | fields                                              |
    | ----------- | --------------------------------------------------- |
    | CREATE      | —                                                   |
    | SNAPSHOT    | volume                                              |
    | CLONE       | volume                                              |
    | UNMAP       | volume, pages                                       |
    | DELETE      | volume                                              |
    | WRITE       | volume, pages, blocks, bits, payload, mask          |
    | READ        | volume, pages, blocks                               |
    | QUERY_REV   | —                                                   |
    | WATERMARKS  | —                                                   |
    | FETCH_DELTA | meta (the target's per-page watermarks)             |
    | FETCH_PAGES | extents                                             |
    | PUSH_PAGES  | extents, payload (the streamed pool rows)           |
    | ADOPT_META  | meta (the donor's metadata ``DBSState``)            |

    ``shard`` addresses one slice of a ``StackedReplica`` endpoint (None on
    flat endpoints). One message object may be posted to many transports
    (mirrored writes): endpoints treat it as read-only.
    """
    op: int
    volume: Any = None      # scalar or (B,) volume ids
    pages: Any = None       # (B,) int32 page ids
    blocks: Any = None      # (B,) int32 block offsets within the page
    bits: Any = None        # (B,) uint32 block bitmaps (precomputed once)
    payload: Any = None     # (B, *payload) write lanes / streamed pool rows
    mask: Any = None        # (B,) bool live write lanes
    extents: Any = None     # (k,) int32 rebuild-stream extent ids
    meta: Any = None        # watermarks / metadata state (rebuild stream)
    shard: Optional[int] = None


class MsgFuture:
    """Completion handle for one posted message. ``done`` flips when the
    transport delivers it (immediately for in-process transports); the
    controller waits by ticking the owning transport."""

    __slots__ = ("transport", "msg", "value", "done", "cancelled",
                 "posted_at")

    def __init__(self, transport: "ReplicaTransport", msg: WireMsg):
        self.transport = transport
        self.msg = msg
        self.value: Any = None
        self.done = False
        self.cancelled = False
        self.posted_at = 0

    def result(self) -> Any:
        self.transport.wait(self)
        return self.value


# jitted data-plane ops (fixed shapes -> compiled once per batch geometry;
# shared by every endpoint so the compile cache is, too)
_apply_jit = jax.jit(dbs.apply_write_ops)


def stamp_page_rev(page_rev: jnp.ndarray, vol, pages, ok,
                   rev) -> jnp.ndarray:
    """Record ``rev`` as the last-write watermark of the written pages.

    ``page_rev`` is a (V, P) int32 array held NEXT TO each replica's
    ``DBSState`` (not inside it: the state's bit-exact equivalence
    contracts compare metadata against a *sequential* reference, and any
    write-time stamp necessarily carries the engine's batching granularity
    — see ``dbs.DBSState.revision``). Watermarks only ever compare
    *between replicas of one group*, which execute identical batched op
    sequences, so batch-granular stamps are exactly as discriminating as
    per-op ones: two replicas' stamps for a page differ iff the page was
    written after their histories diverged. Not-ok (allocation-starved)
    lanes scatter out of bounds and drop."""
    drop = jnp.where(ok, pages, page_rev.shape[-1])
    return page_rev.at[vol, drop].set(rev, mode="drop")


@jax.jit
def _write_jit(state, page_rev, vol, pages, bits, mask):
    """Control-plane write + watermark stamp in one dispatch (the same
    dispatch count as the pre-watermark path)."""
    state, ops = dbs.write_pages(state, vol, pages, bits, mask)
    return state, ops, stamp_page_rev(page_rev, vol, pages, ops.ok,
                                      state.revision)


def clone_page_rev(page_rev: jnp.ndarray, src_vol, new_vol) -> jnp.ndarray:
    """A clone inherits the SOURCE's watermark row (vmap-safe; no-op when
    the clone failed, ``new_vol < 0``).

    Without this, extents reachable only through the clone's table escape
    delta selection: overwrite a post-fail page of the source (CoW to a
    fresh extent) and the old extent's sole table reference is the clone's
    row, whose zero watermarks would never beat the target's — the rebuilt
    replica would silently serve the clone stale pre-fail data. The shared
    extents' data is exactly as old as the source's stamps say."""
    safe = jnp.maximum(new_vol, 0)
    row = jnp.where(new_vol >= 0, page_rev[jnp.asarray(src_vol)],
                    page_rev[safe])
    return page_rev.at[safe].set(row)


@jax.jit
def _read_jit(state, pool, vol, pages, block_offsets):
    ext = dbs.read_resolve(state, vol, pages)
    got = pool[jnp.maximum(ext, 0), block_offsets]
    # holes (never-written / unmapped pages) read as zeros — the clamped
    # gather would otherwise leak extent 0's payload (fused._rr_gather holds
    # the same contract; core/blockdev.py byte equivalence relies on it)
    return jnp.where((ext >= 0).reshape(ext.shape + (1,) * (got.ndim - 1)),
                     got, 0)


def _delta_extents(table: jnp.ndarray, page_rev: jnp.ndarray,
                   target_watermarks) -> np.ndarray:
    """Extents the target is missing: every extent backing a page whose
    per-page revision watermark is newer than the target's. Healthy
    replicas execute identical op sequences (deterministic allocation), so
    a page not written since the target's watermark maps to an extent whose
    content the target already holds bit-for-bit — only the newer ones need
    to cross the wire. One host fetch per rebuild (rebuild is rare)."""
    newer = (page_rev > target_watermarks) & (table >= 0)
    exts = np.asarray(jax.device_get(jnp.where(newer, table, -1)))
    return np.unique(exts[exts >= 0]).astype(np.int32)


# ---------------------------------------------------------------------------
# replica endpoints (the server side of the boundary)
# ---------------------------------------------------------------------------
@dataclass
class Replica:
    """One replica endpoint: device-resident metadata state + payload pool
    + per-page revision watermarks, executing wire messages. ``healthy`` is
    the *controller's* mark (it rides here for the legacy
    ``group.replicas[i].healthy`` surface — the endpoint itself never
    consults it: a replica doesn't know it failed)."""

    state: dbs.DBSState
    pool: jnp.ndarray            # (E, page_blocks, *payload)
    page_rev: jnp.ndarray        # (V, P) int32 last-write watermarks
    healthy: bool = True
    null_storage: bool = False

    def execute(self, msg: WireMsg) -> Any:
        op = msg.op
        if op == MSG_WRITE:
            self.state, ops, self.page_rev = _write_jit(
                self.state, self.page_rev, msg.volume, msg.pages, msg.bits,
                msg.mask)
            if not self.null_storage:
                self.pool = _apply_jit(self.pool, ops, msg.payload,
                                       msg.blocks)
            return None
        if op == MSG_READ:
            return _read_jit(self.state, self.pool, msg.volume, msg.pages,
                             msg.blocks)
        if op == MSG_CREATE:
            self.state, vid = dbs.create_volume(self.state)
            return vid
        if op == MSG_SNAPSHOT:
            self.state, sid = dbs.snapshot(self.state, jnp.int32(msg.volume))
            return sid
        if op == MSG_CLONE:
            self.state, vid = dbs.clone(self.state, jnp.int32(msg.volume))
            self.page_rev = clone_page_rev(self.page_rev,
                                           jnp.int32(msg.volume), vid)
            return vid
        if op == MSG_UNMAP:
            self.state = dbs.unmap(self.state, jnp.int32(msg.volume),
                                   msg.pages)
            return None
        if op == MSG_DELETE:
            self.state = dbs.delete_volume(self.state, jnp.int32(msg.volume))
            return None
        if op == MSG_QUERY_REV:
            return self.state.revision       # device scalar; caller batches
        if op == MSG_WATERMARKS:
            return self.page_rev
        if op == MSG_FETCH_DELTA:
            return (_delta_extents(self.state.table, self.page_rev,
                                   msg.meta),
                    (self.state, self.page_rev))
        if op == MSG_FETCH_PAGES:
            return self.pool[msg.extents]
        if op == MSG_PUSH_PAGES:
            self.pool = self.pool.at[msg.extents].set(msg.payload)
            return None
        if op == MSG_ADOPT_META:
            # decouple from the donor's live arrays: both replicas' states
            # are later DONATED to the fused step, and one buffer donated
            # twice is undefined
            meta_state, meta_pr = msg.meta
            self.state = jax.tree.map(jnp.copy, meta_state)
            self.page_rev = jnp.copy(meta_pr)
            return None
        raise ValueError(f"unknown wire opcode {op}")


@dataclass
class StackedReplica:
    """One replica's endpoint across S engine shards: every leaf carries a
    leading (S,) axis and messages address one shard's slice (``msg.shard``).
    This is the device-resident form the vmapped pool step threads
    (core/sharded.py) — the transport carries control and rebuild traffic;
    foreground I/O rides the compiled program."""

    state: dbs.DBSState          # leaves (S, ...)
    pool: jnp.ndarray            # (S, E, page_blocks, *payload)
    page_rev: jnp.ndarray        # (S, V, P) int32 last-write watermarks
    null_storage: bool = False

    def _slice(self, s: int) -> dbs.DBSState:
        return jax.tree.map(lambda x: x[s], self.state)

    def _write_back(self, s: int, st: dbs.DBSState) -> None:
        self.state = jax.tree.map(lambda full, new: full.at[s].set(new),
                                  self.state, st)

    def execute(self, msg: WireMsg) -> Any:
        op, s = msg.op, msg.shard
        if op == MSG_QUERY_REV:
            return self.state.revision       # (S,) stacked; caller slices
        if s is None:
            raise ValueError("stacked endpoints need msg.shard")
        if op == MSG_WRITE:
            st, ops, pr = _write_jit(self._slice(s), self.page_rev[s],
                                     msg.volume, msg.pages, msg.bits,
                                     msg.mask)
            self._write_back(s, st)
            self.page_rev = self.page_rev.at[s].set(pr)
            if not self.null_storage:
                self.pool = self.pool.at[s].set(_apply_jit(
                    self.pool[s], ops, msg.payload, msg.blocks))
            return None
        if op == MSG_READ:
            return _read_jit(self._slice(s), self.pool[s], msg.volume,
                             msg.pages, msg.blocks)
        if op in (MSG_CREATE, MSG_SNAPSHOT, MSG_CLONE, MSG_UNMAP,
                  MSG_DELETE):
            st = self._slice(s)
            if op == MSG_CREATE:
                st, out = dbs.create_volume(st)
            elif op == MSG_SNAPSHOT:
                st, out = dbs.snapshot(st, jnp.int32(msg.volume))
            elif op == MSG_CLONE:
                st, out = dbs.clone(st, jnp.int32(msg.volume))
                self.page_rev = self.page_rev.at[s].set(clone_page_rev(
                    self.page_rev[s], jnp.int32(msg.volume), out))
            elif op == MSG_UNMAP:
                st, out = dbs.unmap(st, jnp.int32(msg.volume), msg.pages), None
            else:
                st, out = dbs.delete_volume(st, jnp.int32(msg.volume)), None
            self._write_back(s, st)
            return out
        if op == MSG_WATERMARKS:
            return self.page_rev[s]
        if op == MSG_FETCH_DELTA:
            sliced = self._slice(s)
            pr = self.page_rev[s]
            return _delta_extents(sliced.table, pr, msg.meta), (sliced, pr)
        if op == MSG_FETCH_PAGES:
            return self.pool[s][msg.extents]
        if op == MSG_PUSH_PAGES:
            self.pool = self.pool.at[s, msg.extents].set(msg.payload)
            return None
        if op == MSG_ADOPT_META:
            # msg.meta is an UNSTACKED (state, page_rev) pair (the donor's
            # shard slice); .at[s].set materialises fresh target arrays, so
            # no buffer is shared with the donor (donation safety)
            meta_state, meta_pr = msg.meta
            self._write_back(s, meta_state)
            self.page_rev = self.page_rev.at[s].set(meta_pr)
            return None
        raise ValueError(f"unknown wire opcode {op}")


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
class ReplicaTransport:
    """The delivery contract between controller and one replica endpoint.

    ``post`` enqueues a message and returns a future; ``tick`` advances
    simulated time by one step (a no-op for in-process transports);
    ``wait``/``drain`` tick until a future (or everything) delivers.
    ``sent`` counts posted messages per opcode name and ``pages_moved``
    counts pool rows through the rebuild stream — the counters the
    delta-rebuild acceptance tests assert on. ``latency_ewma`` is the
    observed delivery latency (ticks) the latency-weighted read policy
    consults."""

    name = "?"
    in_process = True            # delivery is an immediate endpoint call

    # livelock guard for wait/drain: generous, but finite — a drop rate
    # near 1.0 on a SimNetTransport would otherwise spin forever
    MAX_WAIT_TICKS = 1_000_000

    def __init__(self, endpoint):
        self.endpoint = endpoint
        self.sent: collections.Counter = collections.Counter()
        self.delivered = 0
        self.retransmits = 0
        self.pages_moved = 0
        self.latency_ewma = 0.0

    # -- accounting shared by every implementation ---------------------------
    def _account(self, msg: WireMsg) -> None:
        self.sent[MSG_NAMES[msg.op]] += 1
        if msg.op in (MSG_FETCH_PAGES, MSG_PUSH_PAGES):
            self.pages_moved += int(len(msg.extents))

    def messages_sent(self) -> int:
        return sum(self.sent.values())

    # -- the delivery surface ------------------------------------------------
    def post(self, msg: WireMsg) -> MsgFuture:          # pragma: no cover
        raise NotImplementedError

    def call(self, msg: WireMsg) -> Any:
        """Synchronous convenience: post and wait for delivery."""
        return self.post(msg).result()

    def tick(self) -> None:
        """Advance simulated time one step (no-op in-process)."""

    def pending(self) -> int:
        return 0

    def wait(self, fut: MsgFuture) -> None:
        for _ in range(self.MAX_WAIT_TICKS):
            if fut.done:
                return
            self.tick()
        raise RuntimeError(f"{self.name} transport livelocked waiting for "
                           f"{MSG_NAMES[fut.msg.op]} (drop rate too high?)")

    def drain(self) -> None:
        for _ in range(self.MAX_WAIT_TICKS):
            if not self.pending():
                return
            self.tick()
        raise RuntimeError(f"{self.name} transport livelocked draining")

    def cancel_pending(self) -> int:
        """Tear down undelivered messages (the controller cutting the
        connection to a replica it just declared failed — in-flight ops to
        a dead replica are lost, and rebuild resyncs whatever landed)."""
        return 0


class LocalTransport(ReplicaTransport):
    """In-process delivery: ``post`` executes the message on the endpoint
    immediately — the same jitted dispatch sequence, in the same order, as
    the pre-transport direct-call path (bit-identical by construction)."""

    name = "local"

    def post(self, msg: WireMsg) -> MsgFuture:
        self._account(msg)
        fut = MsgFuture(self, msg)
        fut.value = self.endpoint.execute(msg)
        fut.done = True
        self.delivered += 1
        return fut


class DeviceTransport(LocalTransport):
    """LocalTransport over a device-resident (optionally shard-stacked)
    endpoint. The engines whose data plane is a compiled program
    (fused/sharded/ring) thread the endpoint pytrees through the step
    directly — this transport carries their control-plane and rebuild
    traffic, and the stacked endpoint IS what ``device_state`` exposes."""

    name = "device"


class SimNetTransport(ReplicaTransport):
    """A simulated network link to one replica.

    - every message is delivered ``latency`` ticks after it was posted,
    - at most ``window`` messages may be in flight; posting past the window
      *blocks* (ticks until a slot frees) — bounded-in-flight backpressure,
    - ``drop`` loses a delivery attempt with the given probability; the
      message stays at the queue head and redelivers after another latency
      period (TCP-style retransmit: FIFO order survives, ``retransmits``
      counts the loss),
    - ``reorder`` swaps the two head messages with the given probability
      when both are due — deliberate FIFO breakage for fault-injection
      tests (defaults off; ordering guarantees do not survive it).

    Deterministic under ``seed``. ``latency_ewma`` tracks observed delivery
    latency for the latency-weighted read policy.
    """

    name = "simnet"
    in_process = False

    def __init__(self, endpoint, *, latency: int = 2, window: int = 8,
                 drop: float = 0.0, reorder: float = 0.0, seed: int = 0):
        super().__init__(endpoint)
        if latency < 1:
            raise ValueError(f"latency must be >= 1 tick, got {latency}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.latency = latency
        self.window = window
        self.drop = drop
        self.reorder = reorder
        self.rng = np.random.default_rng(seed)
        self.now = 0
        self.queue: collections.deque = collections.deque()  # [fut, due]

    def post(self, msg: WireMsg) -> MsgFuture:
        for _ in range(self.MAX_WAIT_TICKS):
            if len(self.queue) < self.window:
                break
            self.tick()                      # backpressure: window is full
        else:
            raise RuntimeError("simnet window never freed (livelock)")
        self._account(msg)
        fut = MsgFuture(self, msg)
        fut.posted_at = self.now
        self.queue.append([fut, self.now + self.latency])
        return fut

    def tick(self) -> None:
        self.now += 1
        while self.queue and self.queue[0][1] <= self.now:
            if (self.reorder and len(self.queue) > 1
                    and self.queue[1][1] <= self.now
                    and self.rng.random() < self.reorder):
                self.queue[0], self.queue[1] = self.queue[1], self.queue[0]
            entry = self.queue[0]
            if self.drop and self.rng.random() < self.drop:
                # lost on the wire: retransmit after another latency period;
                # later messages wait behind it (in-order delivery)
                self.retransmits += 1
                entry[1] = self.now + self.latency
                break
            self.queue.popleft()
            fut = entry[0]
            fut.value = self.endpoint.execute(fut.msg)
            fut.done = True
            self.delivered += 1
            lat = float(self.now - fut.posted_at)
            self.latency_ewma = (lat if self.delivered == 1 else
                                 0.8 * self.latency_ewma + 0.2 * lat)

    def pending(self) -> int:
        return len(self.queue)

    def cancel_pending(self) -> int:
        n = len(self.queue)
        for fut, _ in self.queue:
            fut.done = True
            fut.cancelled = True
        self.queue.clear()
        return n


# ---------------------------------------------------------------------------
# the registry (the backend-registry pattern applied to transports)
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[..., ReplicaTransport]] = {}


def register_transport(name: str, factory: Optional[Callable] = None, *,
                       override: bool = False):
    """Register ``factory(endpoint, **opts) -> ReplicaTransport`` under
    ``name``. Usable directly or as a decorator. Duplicate names raise (the
    uniform registry contract); embedders that mean to shadow a built-in
    pass ``override=True``."""
    def _put(f):
        if name in _REGISTRY and not override:
            raise ValueError(
                f"duplicate transport {name!r} (registered: "
                f"{', '.join(available_transports())}); pass override=True "
                "to replace")
        _REGISTRY[name] = f
        return f
    if factory is None:
        return _put
    return _put(factory)


def available_transports() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_transport(name: str, endpoint, **opts) -> ReplicaTransport:
    """Instantiate the transport registered under ``name`` for one replica
    endpoint. ``opts`` are implementation knobs (simnet: latency / window /
    drop / reorder / seed)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r} (registered: "
            f"{', '.join(available_transports())})") from None
    return factory(endpoint, **opts)


register_transport("local", LocalTransport)
register_transport("device", DeviceTransport)
register_transport("simnet", SimNetTransport)
