"""The fused device-resident engine step (see docs/ARCHITECTURE.md).

The paper removes per-request host hops from Longhorn's I/O path three ways:
a multi-queue ublk frontend, the restructured slot-array protocol, and the
direct-to-disk DBS store. ``engine.Engine`` reproduces each layer, but its
``pump()`` still crosses the host *between* layers every batch: slot ids are
``device_get``'d out of admission, and the write path dispatches separate
jitted programs for control-plane resolution, CoW data movement, and reads.

``fused_step`` is the jax analogue of fusing the whole protocol: ONE compiled
program per batch geometry performs

    slot admission  ->  write_pages control-plane resolution (per replica)
                    ->  CoW copies + payload stores, mirrored across all
                        replicas (a REGISTERED KERNEL, kernels/dbs: the
                        ``dbs_rw`` Pallas scatter, or the XLA reference)
                    ->  round-robin read gathers (the same kernel's read)
                    ->  slot retirement

with no intermediate ``device_get``. The host's only jobs are moving raw
request arrays in (``MultiQueueFrontend.drain_batch``) and completed
payloads out (one ``device_get`` at completion). Admission state — the
``SlotTable``, every replica ``DBSState``, and the payload pools — stays on
device across ``pump()`` iterations.

The unfused multi-call path survives as the ladder's ``comm="slots"``
baseline; the benchmark column ``+fused`` measures exactly this change.

``step_core``/``step_core_read`` are the un-jitted bodies, written to be
``jax.vmap``-safe over a leading *shard* axis (core/sharded.py stacks S
independent engines and dispatches one vmapped program for all of them).
Vmap-safety is why they take an optional **traced** ``healthy`` mask: under
vmap the round-robin cursor and the per-replica health bits differ per
shard, so replica selection cannot be a Python-level branch (the host-side
filtering ``ReplicaGroup.device_state`` does for the single-engine path).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dbs, slots
from repro.core.transport import stamp_page_rev
from repro.kernels.dbs.registry import make_kernel


@jax.tree_util.register_dataclass
@dataclass
class FusedBatch:
    """Fixed-shape admitted-request batch: the raw arrays the host moves in.

    All lane arrays are (B,) with inert padding lanes marked want=False, so
    one program compiles per (B, payload) geometry regardless of how many
    requests actually arrived — the Messages-Array idiom end to end.
    """
    want: jnp.ndarray       # (B,) bool  lane carries a real request
    is_write: jnp.ndarray   # (B,) bool  write (True) vs read (False)
    volume: jnp.ndarray     # (B,) int32
    page: jnp.ndarray       # (B,) int32
    block: jnp.ndarray      # (B,) int32 block offset within the page
    payload: jnp.ndarray    # (B, *payload) write payloads (zeros for reads)
    queue: jnp.ndarray      # (B,) int32 admission queue per lane
    step: jnp.ndarray       # ()   int32 admission step (fairness/arrival)


def _cow_apply(pool, ops: dbs.WriteOps, payload, block_offsets, kernel: str):
    """Data plane of a mirrored write batch — CoW extent copies + payload
    block stores — dispatched through the KERNEL REGISTRY (kernels/dbs):
    ``kernel`` names a registered ``DBSKernel`` (``pallas`` — the dbs_rw
    write kernel owns the whole plane; ``xla`` — apply_write_ops, the old
    ``cow="ref"`` path; ``ref`` — pure-jnp row composition; ``copy`` — the
    PR-3 dbs_copy + XLA-scatter hybrid). All entries assume the engine pool
    convention: ReplicaGroup pools carry one extra extent row past the
    allocator's range as the masked-lane dump, so the Pallas paths stay
    fully input/output-aliased (no concat/slice copies of the pool)."""
    return make_kernel(kernel).write(pool, ops, payload, block_offsets)


def step_core(table: slots.SlotTable, states: Tuple[dbs.DBSState, ...],
              pools: Tuple[jnp.ndarray, ...],
              page_revs: Tuple[jnp.ndarray, ...], batch: FusedBatch,
              rr: jnp.ndarray, healthy=None, *, null_backend: bool = False,
              null_storage: bool = False, kernel: str = "pallas"):
    """The fused controller iteration, un-jitted (vmap-safe over shards).

    ``healthy``: None for the single-engine path (the caller passes only
    healthy replicas — ``ReplicaGroup.device_state``), or a traced (R,) bool
    mask over a *fixed* replica tuple. With the mask, writes mirror only to
    healthy replicas and reads round-robin over the healthy subset — the
    form core/sharded.py vmaps, where health differs per shard and cannot
    change the pytree structure.

    ``page_revs``: one (V, P) last-write watermark array per replica
    (``transport.stamp_page_rev``), stamped alongside the mirrored writes
    so the streamed delta rebuild (core/replication.py) works after
    in-program traffic; () with ``null_storage``.
    """
    table, ids, ok = slots.transact(table, batch.want, batch.volume,
                                    batch.queue, batch.step)
    reads = jnp.zeros_like(batch.payload)
    if null_backend or not states:
        return table, states, pools, page_revs, ok, reads

    wmask = ok & batch.is_write
    bits = jnp.uint32(1) << batch.block.astype(jnp.uint32)
    out_states, out_pools, out_prs = [], [], []
    for i, st in enumerate(states):            # mirrored write-to-all
        m = wmask if healthy is None else wmask & healthy[i]
        st, wops = dbs.write_pages(st, batch.volume, batch.page, bits, m)
        if not null_storage:
            out_pools.append(_cow_apply(pools[i], wops, batch.payload,
                                        batch.block, kernel))
            out_prs.append(stamp_page_rev(page_revs[i], batch.volume,
                                          batch.page, wops.ok, st.revision))
        out_states.append(st)

    if not null_storage:
        reads = _rr_gather(out_states, out_pools, batch, rr,
                           ok & ~batch.is_write, reads, healthy, kernel)
    return (table, tuple(out_states), tuple(out_pools), tuple(out_prs), ok,
            reads)


@partial(jax.jit, static_argnames=("null_backend", "null_storage", "kernel"),
         donate_argnums=(0, 1, 2, 3))
def fused_step(table: slots.SlotTable, states: Tuple[dbs.DBSState, ...],
               pools: Tuple[jnp.ndarray, ...],
               page_revs: Tuple[jnp.ndarray, ...], batch: FusedBatch,
               rr: jnp.ndarray, *, null_backend: bool = False,
               null_storage: bool = False, kernel: str = "pallas"):
    """One whole controller iteration as a single compiled program.

    states/pools/page_revs: one entry per healthy replica (writes are
    mirrored to all of them; reads gather from replica ``rr % R``; the
    per-page watermarks stamp with the writes). With ``null_storage`` the
    pools are untouched — pass ``pools=()``/``page_revs=()`` so the (large)
    payload arrays never enter the program at all. Returns
    ``(table', states', pools', page_revs', ok (B,) bool,
    reads (B, *payload))`` — ``ok`` marks lanes that were admitted (and
    therefore completed), and ``reads`` carries gathered payloads on read
    lanes, zeros elsewhere.

    The table, replica states, pools and watermarks are DONATED: the engine
    replaces its references with the returned pytrees every pump, so XLA
    updates the (large) pools in place instead of copying them through each
    step — callers must not touch the passed-in arrays afterwards.
    """
    return step_core(table, states, pools, page_revs, batch, rr,
                     null_backend=null_backend, null_storage=null_storage,
                     kernel=kernel)


def _rr_gather(states, pools, batch, rr, rmask, reads, healthy=None,
               kernel: str = "xla"):
    """Round-robin read: resolve + gather from replica ``rr % R``.

    ``healthy=None``: all replicas serve; ``lax.switch`` executes exactly one
    branch (one resolve + one gather per batch — the cheap single-engine
    form). With a traced ``healthy`` mask: reads come from the (rr mod H)-th
    *healthy* replica, selected with a rank-compare one-hot — every replica
    is gathered and the selection is a ``where`` chain, which is what makes
    this form vmap-safe (and is no extra cost under vmap, where a batched
    switch would execute all branches anyway).

    The gather itself is the registry ``kernel``'s ``read``: holes
    (ext < 0: never-written or unmapped pages) read as ZEROS — without the
    mask a clamped gather would leak extent 0's payload (sparse-file
    semantics; core/blockdev.py relies on this for byte-level equivalence
    with a zero-filled device).
    """
    kern = make_kernel(kernel)
    if healthy is None:
        def _read_from(i):
            def branch(_):
                ext = dbs.read_resolve(states[i], batch.volume, batch.page)
                return kern.read(pools[i], ext, batch.block)
            return branch
        vals = jax.lax.switch(rr % len(states),
                              [_read_from(i) for i in range(len(states))], 0)
    else:
        h = healthy.astype(jnp.int32)
        target = rr % jnp.maximum(jnp.sum(h), 1)
        sel = healthy & (jnp.cumsum(h) - 1 == target)    # (R,) one-hot
        vals = jnp.zeros_like(reads)
        for i in range(len(states)):
            ext = dbs.read_resolve(states[i], batch.volume, batch.page)
            vals = jnp.where(sel[i], kern.read(pools[i], ext, batch.block),
                             vals)
    return jnp.where(rmask.reshape(rmask.shape + (1,) * (vals.ndim - 1)),
                     vals, reads)


def step_core_read(table: slots.SlotTable,
                   states: Tuple[dbs.DBSState, ...],
                   pools: Tuple[jnp.ndarray, ...], batch: FusedBatch,
                   rr: jnp.ndarray, healthy=None, *,
                   null_backend: bool = False, null_storage: bool = False,
                   kernel: str = "xla"):
    """``step_core`` specialised to batches with no write lanes (un-jitted,
    vmap-safe; replica state and pools are inputs only)."""
    table, ids, ok = slots.transact(table, batch.want, batch.volume,
                                    batch.queue, batch.step)
    reads = jnp.zeros_like(batch.payload)
    if null_backend or null_storage or not states:
        return table, ok, reads
    return table, ok, _rr_gather(states, pools, batch, rr,
                                 ok & ~batch.is_write, reads, healthy,
                                 kernel)


@partial(jax.jit, static_argnames=("null_backend", "null_storage", "kernel"),
         donate_argnums=(0,))
def fused_step_read(table: slots.SlotTable, states: Tuple[dbs.DBSState, ...],
                    pools: Tuple[jnp.ndarray, ...], batch: FusedBatch,
                    rr: jnp.ndarray, *, null_backend: bool = False,
                    null_storage: bool = False, kernel: str = "xla"):
    """``fused_step`` specialised to batches with no write lanes.

    Replica state and pools are read-only here, so they are inputs only
    (and NOT donated — they stay live across read-only pumps) — returning
    them would force XLA to materialise pass-through copies of the (large)
    pools every batch, which is exactly the cost the unfused read path
    never pays. Only the slot table is donated. Returns
    ``(table', ok, reads)``.
    """
    return step_core_read(table, states, pools, batch, rr,
                          null_backend=null_backend,
                          null_storage=null_storage, kernel=kernel)


# ---------------------------------------------------------------------------
# tiered variants: the same step + per-extent access stamps for the spill
# tier (repro/durability/tier.py). The stamps array is (E+1,) int32 — row E
# is the dump slot invalid lanes scatter into — and every extent a batch
# resolves (read extents, write destinations AND CoW sources) is stamped
# with the batch step INSIDE the same program, so the clock/second-chance
# eviction sweep needs no extra device round-trip on the hot path.
# ---------------------------------------------------------------------------
def _stamp_tier(stamps, state, batch: FusedBatch, ok, cow_src=None):
    """Stamp the batch's resolved extents with the admission step.

    ``state`` is the POST-write replica-0 state, so write lanes resolve to
    their freshly allocated/CoW'd destination extents; ``cow_src`` (the
    write ops' CoW sources, pre-write extents) is stamped too — a CoW read
    is an access. Invalid lanes clamp to the dump row E, which is zeroed
    back so it never looks hot."""
    dump = stamps.shape[0] - 1
    ext = dbs.read_resolve(state, batch.volume, batch.page)
    idx = jnp.where(ok & (ext >= 0), ext, dump)
    stamps = stamps.at[idx].max(batch.step)
    if cow_src is not None:
        src = jnp.where(ok & batch.is_write & (cow_src >= 0), cow_src, dump)
        stamps = stamps.at[src].max(batch.step)
    return stamps.at[dump].set(0)


def step_core_tiered(table: slots.SlotTable,
                     states: Tuple[dbs.DBSState, ...],
                     pools: Tuple[jnp.ndarray, ...],
                     page_revs: Tuple[jnp.ndarray, ...],
                     stamps: jnp.ndarray, batch: FusedBatch,
                     rr: jnp.ndarray, *, kernel: str = "pallas"):
    """``step_core`` + tier stamping (un-jitted). The tier needs the real
    storage plane, so there are no null_backend/null_storage forms."""
    table, ids, ok = slots.transact(table, batch.want, batch.volume,
                                    batch.queue, batch.step)
    reads = jnp.zeros_like(batch.payload)
    wmask = ok & batch.is_write
    bits = jnp.uint32(1) << batch.block.astype(jnp.uint32)
    out_states, out_pools, out_prs = [], [], []
    cow_src = None
    for i, st in enumerate(states):            # mirrored write-to-all
        st, wops = dbs.write_pages(st, batch.volume, batch.page, bits, wmask)
        if cow_src is None:
            cow_src = wops.cow_src             # replicas agree (mirror-all)
        out_pools.append(_cow_apply(pools[i], wops, batch.payload,
                                    batch.block, kernel))
        out_prs.append(stamp_page_rev(page_revs[i], batch.volume,
                                      batch.page, wops.ok, st.revision))
        out_states.append(st)
    stamps = _stamp_tier(stamps, out_states[0], batch, ok, cow_src)
    reads = _rr_gather(out_states, out_pools, batch, rr,
                       ok & ~batch.is_write, reads, None, kernel)
    return (table, tuple(out_states), tuple(out_pools), tuple(out_prs),
            stamps, ok, reads)


@partial(jax.jit, static_argnames=("kernel",),
         donate_argnums=(0, 1, 2, 3, 4))
def fused_step_tiered(table: slots.SlotTable,
                      states: Tuple[dbs.DBSState, ...],
                      pools: Tuple[jnp.ndarray, ...],
                      page_revs: Tuple[jnp.ndarray, ...],
                      stamps: jnp.ndarray, batch: FusedBatch,
                      rr: jnp.ndarray, *, kernel: str = "pallas"):
    """``fused_step`` with the tier's access stamps threaded through — still
    ONE compiled program per batch geometry; the stamps ride the donation
    list like the other per-pump state."""
    return step_core_tiered(table, states, pools, page_revs, stamps, batch,
                            rr, kernel=kernel)


def step_core_read_tiered(table: slots.SlotTable,
                          states: Tuple[dbs.DBSState, ...],
                          pools: Tuple[jnp.ndarray, ...],
                          stamps: jnp.ndarray, batch: FusedBatch,
                          rr: jnp.ndarray, *, kernel: str = "xla"):
    table, ids, ok = slots.transact(table, batch.want, batch.volume,
                                    batch.queue, batch.step)
    reads = jnp.zeros_like(batch.payload)
    stamps = _stamp_tier(stamps, states[0], batch, ok, None)
    reads = _rr_gather(states, pools, batch, rr, ok & ~batch.is_write,
                       reads, None, kernel)
    return table, stamps, ok, reads


@partial(jax.jit, static_argnames=("kernel",), donate_argnums=(0, 3))
def fused_step_read_tiered(table: slots.SlotTable,
                           states: Tuple[dbs.DBSState, ...],
                           pools: Tuple[jnp.ndarray, ...],
                           stamps: jnp.ndarray, batch: FusedBatch,
                           rr: jnp.ndarray, *, kernel: str = "xla"):
    """``fused_step_read`` + tier stamping: states/pools stay inputs-only,
    the slot table and the stamps are donated."""
    return step_core_read_tiered(table, states, pools, stamps, batch, rr,
                                 kernel=kernel)
