"""The Messages Array + available-ID channel (paper §IV-C), as a JAX pytree.

Longhorn's fix for the single-loop-function bottleneck was to replace the
dynamic ``Messages Map`` (which serializes all request/response matching
through one thread) with a *fixed-size array* indexed by *pre-allocated
integer tokens* handed out through a channel. A thread that owns token ``i``
may touch slot ``i`` and nothing else — no locks, no coordinator.

That construction is exactly the static-shape discipline jit requires, so the
device-side translation is direct:

- ``ids``   : a ring buffer holding the free token ids (the Go channel),
- ``head``  : pop cursor (acquire), ``tail``: push cursor (release),
- the *Messages Array* itself is whatever fixed-size per-slot state the user
  indexes with the acquired ids (in-flight request table, extent table, ...).

Acquire/release are vectorized: a batch of k tokens moves with two scatter/
gather ops, the JAX analogue of "each thread pops its own token".

Lifecycles: ``admit``/``retire`` bracket the unfused engine iteration;
``transact`` is the fused one (docs/ARCHITECTURE.md).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class SlotRing:
    ids: jnp.ndarray    # (N,) int32 ring storage of free slot ids
    head: jnp.ndarray   # () int32, monotonically increasing pop cursor
    tail: jnp.ndarray   # () int32, monotonically increasing push cursor

    @property
    def capacity(self) -> int:
        return self.ids.shape[0]


def make_ring(n_slots: int) -> SlotRing:
    return SlotRing(ids=jnp.arange(n_slots, dtype=jnp.int32),
                    head=jnp.zeros((), jnp.int32),
                    tail=jnp.asarray(n_slots, jnp.int32))


def num_free(ring: SlotRing) -> jnp.ndarray:
    return ring.tail - ring.head


def acquire(ring: SlotRing, k: int, mask=None):
    """Pop up to ``k`` ids. ``mask`` (k,) bool marks lanes that actually want
    a token (compaction via prefix-sum keeps non-acquiring lanes inert).

    Returns (ring', ids (k,) int32 with -1 for lanes that got nothing, ok (k,)).
    """
    n = ring.capacity
    want = jnp.ones((k,), bool) if mask is None else mask
    pos = jnp.cumsum(want.astype(jnp.int32)) - 1            # lane -> offset
    avail = num_free(ring)
    ok = want & (pos < avail)
    idx = (ring.head + pos) % n
    ids = jnp.where(ok, ring.ids[idx], -1)
    taken = jnp.sum(ok.astype(jnp.int32))
    return dataclasses.replace(ring, head=ring.head + taken), ids, ok


def release(ring: SlotRing, ids: jnp.ndarray, mask=None) -> SlotRing:
    """Push ids back (lanes with mask=False or id<0 are ignored)."""
    n = ring.capacity
    ok = ids >= 0
    if mask is not None:
        ok = ok & mask
    pos = jnp.cumsum(ok.astype(jnp.int32)) - 1
    idx = jnp.where(ok, (ring.tail + pos) % n, n)            # n = dump slot
    padded = jnp.concatenate([ring.ids, jnp.zeros((1,), jnp.int32)])
    padded = padded.at[idx].set(jnp.where(ok, ids, 0))
    pushed = jnp.sum(ok.astype(jnp.int32))
    return dataclasses.replace(ring, ids=padded[:n], tail=ring.tail + pushed)


# ---------------------------------------------------------------------------
# In-flight request table = the Messages Array proper. Used by the serving
# scheduler: each live request owns one slot for its whole lifetime.
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclass
class SlotTable:
    ring: SlotRing
    active: jnp.ndarray      # (N,) bool — slot currently owned
    seq_len: jnp.ndarray     # (N,) int32 — tokens generated so far
    volume: jnp.ndarray      # (N,) int32 — DBS volume backing this request
    queue: jnp.ndarray       # (N,) int32 — admission queue the request used
    arrival: jnp.ndarray     # (N,) int32 — admission step (for fairness)
    opcode: jnp.ndarray      # (N,) int32 — ring opcode of the slot's request
    fnid: jnp.ndarray        # (N,) int32 — storage-fn id (COMPUTE slots)
    status: jnp.ndarray      # (N,) int32 — completion status (CQ mirror)


def make_table(n_slots: int) -> SlotTable:
    # every leaf owns its buffer: the fused/sharded engine steps donate the
    # whole table, and one buffer referenced by two donated leaves is an
    # XLA error ("attempt to donate the same buffer twice")
    z = lambda: jnp.zeros((n_slots,), jnp.int32)
    return SlotTable(ring=make_ring(n_slots), active=jnp.zeros((n_slots,), bool),
                     seq_len=z(), volume=z() - 1, queue=z(), arrival=z(),
                     opcode=z(), fnid=z(), status=z())


def make_sharded_table(n_shards: int, n_slots: int) -> SlotTable:
    """S independent Messages Arrays in shard-major layout: every leaf of the
    SlotTable pytree gains a leading (S,) axis, so slot ``(s, i)`` belongs to
    shard ``s`` exclusively — the layout ``jax.vmap`` maps over when one
    compiled admission program serves all shards (core/sharded.py)."""
    table = make_table(n_slots)
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (n_shards,) + (1,) * x.ndim), table)


def admit(table: SlotTable, want: jnp.ndarray, volumes: jnp.ndarray,
          queues: jnp.ndarray, step: jnp.ndarray, opcodes=None, fnids=None):
    """Admit up to len(want) requests. Returns (table', slot_ids, ok).

    ``opcodes`` (optional (k,) int32) records the ring opcode of each lane
    in the Messages Array — the SQ half of the SQ/CQ protocol
    (core/ring.py); omitted lanes record 0 (OP_NOOP). ``fnids`` (optional
    (k,) int32) records the storage-function id of COMPUTE lanes
    (repro/compute registry); omitted lanes record 0.
    """
    ring, ids, ok = acquire(table.ring, want.shape[0], want)
    # not-admitted lanes scatter out of bounds and are dropped: clamping them
    # to slot 0 would race a lane that legitimately acquired slot 0 (scatter
    # order over duplicate indices is undefined).
    idx = jnp.where(ok, ids, table.active.shape[0])
    upd = lambda a, v: a.at[idx].set(
        jnp.broadcast_to(v, idx.shape).astype(a.dtype), mode="drop")
    return dataclasses.replace(
        table, ring=ring,
        active=upd(table.active, True),
        seq_len=upd(table.seq_len, 0),
        volume=upd(table.volume, volumes),
        queue=upd(table.queue, queues),
        arrival=upd(table.arrival, jnp.broadcast_to(step, ids.shape)),
        opcode=upd(table.opcode, 0 if opcodes is None else opcodes),
        fnid=upd(table.fnid, 0 if fnids is None else fnids),
        status=upd(table.status, 0),
    ), ids, ok


def retire(table: SlotTable, ids: jnp.ndarray, mask=None,
           statuses=None) -> SlotTable:
    """Release slots. ``statuses`` (optional, aligned with ids) records each
    slot's completion status in the Messages Array's status lane — the CQ
    mirror a host-side completer can leave behind (core/ring.py scatters the
    full CQ record itself)."""
    ok = ids >= 0
    if mask is not None:
        ok = ok & mask
    idx = jnp.where(ok, ids, table.active.shape[0])
    active = table.active.at[idx].set(False, mode="drop")
    status = table.status
    if statuses is not None:
        status = status.at[idx].set(
            jnp.broadcast_to(statuses, idx.shape).astype(status.dtype),
            mode="drop")
    return dataclasses.replace(table, ring=release(table.ring, ids, mask),
                               active=active, status=status)


def n_active(table: SlotTable) -> jnp.ndarray:
    """Slots currently owned (device-side; sums over every leading shard
    axis). The Messages-Array occupancy counter behind
    ``blockdev.VolumeManager.stats`` and queue-depth introspection."""
    return jnp.sum(table.active.astype(jnp.int32))


def transact(table: SlotTable, want: jnp.ndarray, volumes: jnp.ndarray,
             queues: jnp.ndarray, step: jnp.ndarray, opcodes=None,
             fnids=None):
    """Admit a batch and immediately retire the admitted slots — the fused
    engine's slot lifecycle (see core/fused.py and docs/ARCHITECTURE.md),
    where a request is admitted, executed, and completed inside ONE compiled
    step, so its token never outlives the program that acquired it.

    The table still round-trips through the ring (arrival accounting is
    recorded, starvation behaviour matches the unfused admit/retire pair),
    but no slot id ever crosses to the host. Returns (table', slot_ids, ok).
    """
    table, ids, ok = admit(table, want, volumes, queues, step, opcodes,
                           fnids)
    return retire(table, ids, ok), ids, ok
