"""Device-side Direct Block Store (paper §IV-D), with HBM as the medium.

Faithful structure (see Fig. 5 of the paper):

- the *storage medium* is a fixed pool of **extents** (KV pages); payload
  arrays live alongside and are indexed by extent id,
- the *extent-status region* is ``extent_owner`` (owning snapshot per extent)
  plus a per-extent **block bitmap** (paper: 32 × 4 KB blocks per 1 MB extent;
  here: ``page_blocks`` tokens per page, bitmap in one uint32),
- *volume & snapshot metadata* are fixed tables (``vol_head``,
  ``snap_parent``, ``snap_vol``),
- the *superblock allocation mark* becomes the free-extent **SlotRing** — the
  Messages-Array idiom applied to allocation, so only actual allocations
  serialize (paper: "Only writes to unallocated space require serialization"),
- the **in-memory extent map** that makes reads O(1) and snapshot-count
  independent is ``table[vol, page] -> extent`` — never stored on the medium,
  rebuilt from the chain on restart (host store) exactly like DBS.

Semantics implemented on device (everything jit-traceable, functional state):
create/delete volume, snapshot, clone(=fork), copy-on-write writes, O(1)
reads, unmap. Snapshot *merge-deletion* is host-side only (checkpoint store),
as it is an offline maintenance path in the paper too.

``write_pages`` is the control plane; the data plane is either
``apply_write_ops`` (gather/scatter reference) or the Pallas ``dbs_copy``
kernel on the fused hot path (core/fused.py, docs/KERNELS.md).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.slots import SlotRing, acquire, make_ring, release

NULL = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclass
class DBSState:
    # extent-status region
    extent_owner: jnp.ndarray   # (E,) int32 snapshot id, -1 = free
    bitmap: jnp.ndarray         # (E,) uint32 allocated-block bits
    free: SlotRing              # available extent ids (superblock mark analogue)
    # volume / snapshot metadata region
    vol_head: jnp.ndarray       # (V,) int32 head snapshot, -1 = unused volume
    snap_parent: jnp.ndarray    # (S,) int32 parent snapshot, -1 root, -2 unused
    snap_vol: jnp.ndarray       # (S,) int32 owning volume
    n_snaps: jnp.ndarray        # () int32 next snapshot id (monotone)
    # in-memory flattened extent maps (one per volume)
    table: jnp.ndarray          # (V, P) int32 page -> extent, -1 = hole
    # mirroring metadata (paper §III: replica consistency "version")
    revision: jnp.ndarray       # () int32 bumped on every mutating op

    @property
    def n_extents(self) -> int:
        return self.extent_owner.shape[0]


def make_state(n_extents: int, max_volumes: int, max_pages: int,
               max_snapshots: int = 0) -> DBSState:
    s = max_snapshots or (4 * max_volumes)
    return DBSState(
        extent_owner=jnp.full((n_extents,), NULL, jnp.int32),
        bitmap=jnp.zeros((n_extents,), jnp.uint32),
        free=make_ring(n_extents),
        vol_head=jnp.full((max_volumes,), NULL, jnp.int32),
        snap_parent=jnp.full((s,), -2, jnp.int32),
        snap_vol=jnp.full((s,), NULL, jnp.int32),
        n_snaps=jnp.zeros((), jnp.int32),
        table=jnp.full((max_volumes, max_pages), NULL, jnp.int32),
        revision=jnp.zeros((), jnp.int32),
    )


def _bump(st: DBSState) -> DBSState:
    return dataclasses.replace(st, revision=st.revision + 1)


# ---------------------------------------------------------------------------
# volume lifecycle
# ---------------------------------------------------------------------------
def create_volume(st: DBSState) -> Tuple[DBSState, jnp.ndarray]:
    """New empty volume (fresh root snapshot). Returns (state, vol_id|-1)."""
    vid = jnp.argmin(st.vol_head >= 0).astype(jnp.int32)      # first -1 slot
    sid = st.n_snaps
    ok = (st.vol_head[vid] < 0) & (sid < st.snap_parent.shape[0])
    st = dataclasses.replace(
        st,
        vol_head=st.vol_head.at[vid].set(jnp.where(ok, sid, st.vol_head[vid])),
        snap_parent=st.snap_parent.at[sid].set(
            jnp.where(ok, NULL, st.snap_parent[sid])),
        snap_vol=st.snap_vol.at[sid].set(jnp.where(ok, vid, st.snap_vol[sid])),
        n_snaps=st.n_snaps + ok.astype(jnp.int32),
        table=st.table.at[vid].set(jnp.where(ok, NULL, st.table[vid])),
    )
    return _bump(st), jnp.where(ok, vid, NULL)


def snapshot(st: DBSState, vol: jnp.ndarray) -> Tuple[DBSState, jnp.ndarray]:
    """Freeze the volume head; subsequent writes copy-on-write."""
    sid = st.n_snaps
    ok = (st.vol_head[vol] >= 0) & (sid < st.snap_parent.shape[0])
    st = dataclasses.replace(
        st,
        snap_parent=st.snap_parent.at[sid].set(
            jnp.where(ok, st.vol_head[vol], st.snap_parent[sid])),
        snap_vol=st.snap_vol.at[sid].set(jnp.where(ok, vol, st.snap_vol[sid])),
        vol_head=st.vol_head.at[vol].set(
            jnp.where(ok, sid, st.vol_head[vol])),
        n_snaps=st.n_snaps + ok.astype(jnp.int32),
    )
    return _bump(st), jnp.where(ok, sid, NULL)


def clone(st: DBSState, src_vol: jnp.ndarray) -> Tuple[DBSState, jnp.ndarray]:
    """Fork a new volume from src's current state (prefix sharing).

    Implemented as: snapshot(src) (freezing shared pages), then a new volume
    whose root snapshot's parent is that snapshot and whose flattened extent
    map is a copy of src's — both volumes now CoW against the shared extents.
    """
    st, frozen = snapshot(st, src_vol)
    vid = jnp.argmin(st.vol_head >= 0).astype(jnp.int32)
    sid = st.n_snaps
    ok = ((st.vol_head[vid] < 0) & (frozen >= 0)
          & (sid < st.snap_parent.shape[0]))
    st = dataclasses.replace(
        st,
        vol_head=st.vol_head.at[vid].set(jnp.where(ok, sid, st.vol_head[vid])),
        snap_parent=st.snap_parent.at[sid].set(
            jnp.where(ok, frozen, st.snap_parent[sid])),
        snap_vol=st.snap_vol.at[sid].set(jnp.where(ok, vid, st.snap_vol[sid])),
        n_snaps=st.n_snaps + ok.astype(jnp.int32),
        table=st.table.at[vid].set(
            jnp.where(ok, st.table[src_vol], st.table[vid])),
    )
    return _bump(st), jnp.where(ok, vid, NULL)


def _free_extents(st: DBSState, mask: jnp.ndarray) -> DBSState:
    """Return masked extents to the free ring, clear their status."""
    e = st.n_extents
    ids = jnp.where(mask, jnp.arange(e, dtype=jnp.int32), -1)
    ring = release(st.free, ids)
    return dataclasses.replace(
        st, free=ring,
        extent_owner=jnp.where(mask, NULL, st.extent_owner),
        bitmap=jnp.where(mask, jnp.uint32(0), st.bitmap))


def delete_volume(st: DBSState, vol: jnp.ndarray) -> DBSState:
    """Delete the volume's snapshot chain and free all its extents.

    Extents are shared with clones via *other volumes'* snapshots, so only
    extents whose owning snapshot belongs to this volume are freed; clone
    chains keep their frozen parents (their snap_vol is the ancestor volume —
    matching Longhorn, where a volume can only be deleted once rebuilt/
    detached clones no longer reference its snapshots; the serving layer
    tracks child references and retargets snap_vol on fork).
    """
    ok = st.vol_head[vol] >= 0
    owner_vol = jnp.where(st.extent_owner >= 0,
                          st.snap_vol[st.extent_owner], NULL)
    # extents owned by this volume's snapshots, minus those referenced by any
    # other live volume's flattened table (prefix sharing from clones)
    mine = ok & (owner_vol == vol)
    live_vols = (st.vol_head >= 0) & (jnp.arange(st.vol_head.shape[0]) != vol)
    referenced = jnp.zeros((st.n_extents + 1,), bool).at[
        jnp.where(live_vols[:, None], st.table + 1, 0)].max(True)[1:]
    st = _free_extents(st, mine & ~referenced)
    snaps_of_vol = st.snap_vol == vol
    st = dataclasses.replace(
        st,
        vol_head=st.vol_head.at[vol].set(jnp.where(ok, NULL, st.vol_head[vol])),
        table=st.table.at[vol].set(jnp.where(ok, NULL, st.table[vol])),
        snap_parent=jnp.where(snaps_of_vol & ok, -2, st.snap_parent),
    )
    return _bump(st)


# ---------------------------------------------------------------------------
# I/O path
# ---------------------------------------------------------------------------
def read_resolve(st: DBSState, vol: jnp.ndarray, pages: jnp.ndarray
                 ) -> jnp.ndarray:
    """(B,) page ids -> (B,) extent ids (-1 for holes). O(1) per page and
    independent of snapshot-chain depth — the paper's headline DBS property
    (validated by tests/test_dbs_properties.py and benchmarks/table1)."""
    return st.table[vol, pages]


def _group_lanes(vol: jnp.ndarray, pages: jnp.ndarray,
                 block_bits: jnp.ndarray, mask: jnp.ndarray, max_pages: int):
    """Group write lanes that target the same (vol, page) pair.

    Returns (leader (B,) int32 — the first live lane of each group,
    is_leader (B,) bool, group_bits (B,) uint32 — the OR of the group's
    block bitmaps, meaningful on leader lanes). The (B, B) comparison is
    tiny next to the extent pools and keeps everything vmap-safe.
    """
    b = pages.shape[0]
    volb = jnp.broadcast_to(vol, pages.shape).astype(jnp.int32)
    key = volb * jnp.int32(max_pages) + pages
    same = mask[:, None] & mask[None, :] & (key[:, None] == key[None, :])
    leader = jnp.argmax(same, axis=1).astype(jnp.int32)
    is_leader = mask & (leader == jnp.arange(b, dtype=jnp.int32))
    group_bits = jax.lax.reduce(
        jnp.where(same, block_bits[None, :], jnp.uint32(0)),
        jnp.uint32(0), jax.lax.bitwise_or, (1,))
    return leader, is_leader, group_bits


def write_pages(st: DBSState, vol: jnp.ndarray, pages: jnp.ndarray,
                block_bits: jnp.ndarray, mask=None):
    """Write blocks in (possibly new) pages.

    vol: scalar volume id, or (B,) vector (one volume per lane — the serving
    engine's "one write per active sequence per step"). pages: (B,) page
    indices; block_bits: (B,) uint32 masks of blocks written. Returns
    (state, WriteOps) where WriteOps tells the data plane which extents to
    touch and which CoW copies to perform.

    Lanes targeting the same (vol, page) pair are GROUPED: the group's first
    live lane (the leader) resolves allocation/CoW once with the OR of the
    group's block bitmaps, and every member lane inherits the leader's
    destination extent — so a byte-addressed span that fans out to many
    blocks of one page (core/blockdev.py) is one allocation plus N block
    stores, exactly like the sequential one-write-per-call reference.
    Duplicate (vol, page, *block*) lanes remain undefined-order (scatter
    semantics); callers serialize overlapping-block writes across batches.
    """
    vol = jnp.asarray(vol)
    if mask is None:
        mask = jnp.ones(pages.shape, bool)
    leader, is_leader, group_bits = _group_lanes(
        vol, pages, block_bits, mask, st.table.shape[1])
    head = st.vol_head[vol]                                     # scalar or (B,)
    ext = st.table[vol, pages]                                  # (B,)
    owner = jnp.where(ext >= 0, st.extent_owner[jnp.maximum(ext, 0)], NULL)
    in_place = (ext >= 0) & (owner == head) & is_leader
    need_alloc = is_leader & ~in_place                          # hole or CoW
    ring, new_ids, got = acquire(st.free, pages.shape[0], need_alloc)
    dst = jnp.where(in_place, ext, new_ids)                     # -1 if starved
    ok = (in_place | got) & is_leader
    is_cow = ok & (~in_place) & (ext >= 0)

    safe_dst = jnp.maximum(dst, 0)
    old_bits = jnp.where(is_cow, st.bitmap[jnp.maximum(ext, 0)], jnp.uint32(0))
    new_bits = (st.bitmap[safe_dst] * in_place.astype(jnp.uint32)
                | old_bits | group_bits)
    # lanes that perform no write scatter to an out-of-bounds index and are
    # dropped: a write-back of the "current" value is NOT inert when another
    # lane targets the same slot in the batch (duplicate-index scatter order
    # is undefined, so the stale write-back can win) — e.g. the fused step
    # routes read lanes through here with mask=False, and only group leaders
    # may touch the metadata scatters at all.
    drop_ext = jnp.where(ok, safe_dst, st.n_extents)
    drop_page = jnp.where(ok, pages, st.table.shape[1])
    st = dataclasses.replace(
        st, free=ring,
        extent_owner=st.extent_owner.at[drop_ext].set(
            jnp.broadcast_to(head, drop_ext.shape), mode="drop"),
        bitmap=st.bitmap.at[drop_ext].set(new_bits, mode="drop"),
        table=st.table.at[vol, drop_page].set(dst, mode="drop"),
    )
    # expand leader results to every member lane: the data plane stores each
    # lane's block into its group's destination extent (one CoW copy per
    # group — cow_src stays leader-only)
    ok_all = mask & ok[leader]
    dst_all = dst[leader]
    ops = WriteOps(dst=jnp.where(ok_all, dst_all, NULL),
                   cow_src=jnp.where(is_cow, ext, NULL),
                   ok=ok_all)
    return _bump(st), ops


@jax.tree_util.register_dataclass
@dataclass
class WriteOps:
    dst: jnp.ndarray       # (B,) destination extents (-1 = failed/starved)
    cow_src: jnp.ndarray   # (B,) source extents to copy first (-1 = none)
    ok: jnp.ndarray        # (B,) bool


def apply_write_ops(pool: jnp.ndarray, ops: WriteOps,
                    payload: jnp.ndarray, block_offsets: jnp.ndarray
                    ) -> jnp.ndarray:
    """Data-plane half of a write: CoW copies then payload stores.

    pool: (E, page, ...); payload: (B, ...) one block per lane;
    block_offsets: (B,) position of the written block within its page.
    """
    safe_dst = jnp.maximum(ops.dst, 0)
    safe_src = jnp.maximum(ops.cow_src, 0)
    do_copy = (ops.cow_src >= 0) & ops.ok
    # only COPY lanes touch the whole-extent scatter: a write-back of the
    # "current" extent value is NOT inert when another lane of the batch
    # shares the destination (grouped same-page writes, see write_pages) —
    # the stale write-back could clobber the leader's CoW copy. Failed and
    # non-copy lanes scatter out of bounds and are dropped.
    drop_copy = jnp.where(do_copy, safe_dst, pool.shape[0])
    pool = pool.at[drop_copy].set(pool[safe_src], mode="drop")
    drop_dst = jnp.where(ops.ok, safe_dst, pool.shape[0])
    pool = pool.at[drop_dst, block_offsets].set(payload, mode="drop")
    return pool


def unmap(st: DBSState, vol: jnp.ndarray, pages: jnp.ndarray) -> DBSState:
    """Drop pages from a volume (TRIM). Extents owned by the live head are
    freed; snapshot-owned extents just unlink (data stays for the snapshot).
    Sliding-window layers use this to retire pages behind the window."""
    head = st.vol_head[vol]
    ext = st.table[vol, pages]
    valid = ext >= 0
    safe = jnp.maximum(ext, 0)
    owned_by_head = valid & (st.extent_owner[safe] == head)
    e = st.n_extents
    # scatter through a dump slot (index e) so non-owned lanes cannot clobber
    free_mask = jnp.zeros((e + 1,), bool).at[
        jnp.where(owned_by_head, ext, e)].set(True)[:e]
    st = _free_extents(st, free_mask)
    st = dataclasses.replace(
        st, table=st.table.at[vol, pages].set(jnp.where(valid, NULL, ext)))
    return _bump(st)


# ---------------------------------------------------------------------------
# introspection (host-side convenience, used by tests/engine)
# ---------------------------------------------------------------------------
def stats(st: DBSState) -> dict:
    return {
        "extents_free": int(jax.device_get(st.free.tail - st.free.head)),
        "extents_used": int(jax.device_get(jnp.sum(st.extent_owner >= 0))),
        "volumes": int(jax.device_get(jnp.sum(st.vol_head >= 0))),
        "snapshots": int(jax.device_get(st.n_snaps)),
        "revision": int(jax.device_get(st.revision)),
    }
