"""Controller<->replica layer: mirroring writes, round-robin reads, rebuild.

Paper §III: "Each write is replicated to all replicas, and each read is
served by one replica in round robin fashion"; the controller detects a
faulty replica and rebuilds it from the most up-to-date copy, using the
per-replica metadata "version" to establish consistency.

Two planes:

- **host-orchestrated replicas** (`ReplicaGroup`): R replica instances, each
  a (DBSState, payload pool) pair — possibly living on different jax devices
  or processes. Used by the serving engine and the ladder benchmarks; this is
  the literal structure of the Longhorn engine.
- **mesh collectives** (`mirror_write` / `rr_select`): the same write-to-all /
  read-one pattern expressed inside shard_map for the multi-pod data plane
  (gradient mirroring across "pod", page stripes across "model").

The fused engine step (core/fused.py) threads the replica pytrees exposed
by ``device_state``/``set_device_state`` through one compiled program —
mirroring and round-robin selection then happen inside that program. See
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dbs

# jitted data-plane ops (fixed shapes -> compiled once per batch geometry)
_write_jit = jax.jit(dbs.write_pages)
_apply_jit = jax.jit(dbs.apply_write_ops)


@jax.jit
def _read_jit(state, pool, vol, pages, block_offsets):
    ext = dbs.read_resolve(state, vol, pages)
    return pool[jnp.maximum(ext, 0), block_offsets]


# ---------------------------------------------------------------------------
# host-orchestrated replica group
# ---------------------------------------------------------------------------
@dataclass
class Replica:
    state: dbs.DBSState
    pool: jnp.ndarray            # (E, page_blocks, *payload)
    healthy: bool = True


class ReplicaGroup:
    """The controller's backend: mirrors control+data ops across replicas."""

    def __init__(self, n_replicas: int, n_extents: int, max_volumes: int,
                 max_pages: int, page_blocks: int, payload_shape=(4,),
                 dtype=jnp.float32, null_storage: bool = False):
        self.null_storage = null_storage
        self.page_blocks = page_blocks
        # pools carry ONE extra extent row past the allocator's range: the
        # fused CoW kernel's masked-lane dump (dbs_copy_pool scratch=True),
        # which keeps the kernel input/output-aliased with no pool copies.
        # dbs.make_state only ever hands out extents < n_extents.
        self.replicas: List[Replica] = [
            Replica(state=dbs.make_state(n_extents, max_volumes, max_pages),
                    pool=jnp.zeros(
                        (n_extents + 1, page_blocks) + tuple(payload_shape),
                        dtype))
            for _ in range(n_replicas)]
        self._rr = 0

    # -- control plane: mirrored to every replica ---------------------------
    def _all(self, fn: Callable[[dbs.DBSState], Tuple[dbs.DBSState, Any]]):
        outs = []
        for r in self.replicas:
            if not r.healthy:
                outs.append(None)
                continue
            r.state, out = fn(r.state)
            outs.append(out)
        first = next(o for o in outs if o is not None)
        return first

    def create_volume(self) -> int:
        return int(self._all(dbs.create_volume))

    def snapshot(self, vol: int) -> int:
        return int(self._all(lambda s: dbs.snapshot(s, jnp.int32(vol))))

    def clone(self, vol: int) -> int:
        return int(self._all(lambda s: dbs.clone(s, jnp.int32(vol))))

    def delete_volume(self, vol: int) -> None:
        self._all(lambda s: (dbs.delete_volume(s, jnp.int32(vol)), None))

    # -- fused data plane (core/fused.py) ------------------------------------
    def healthy_indices(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas) if r.healthy]

    def device_state(self):
        """(states, pools) tuples for every healthy replica — the pytrees the
        fused engine step threads through one compiled program. Nothing is
        fetched: these are device-resident arrays. With ``null_storage`` the
        pools are withheld (fused_step never touches them)."""
        idx = self.healthy_indices()
        states = tuple(self.replicas[i].state for i in idx)
        if self.null_storage:
            return states, ()
        return states, tuple(self.replicas[i].pool for i in idx)

    def set_device_state(self, states, pools) -> None:
        """Write back the fused step's outputs (healthy replicas, in the
        order ``device_state`` returned them)."""
        idx = self.healthy_indices()
        for i, st in zip(idx, states):
            self.replicas[i].state = st
        for i, pool in zip(idx, pools):
            self.replicas[i].pool = pool

    def bump_rr(self) -> int:
        """Advance and return the round-robin read cursor (shared with the
        unfused ``read`` path so interleaving the two stays fair)."""
        rr = self._rr
        self._rr += 1
        return rr

    # -- data plane ----------------------------------------------------------
    def write(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray,
              payload: jnp.ndarray, mask=None) -> None:
        """Mirror a batch of block writes to every healthy replica. The write
        completes only when all replicas acked (paper: every write creates
        multiple messages that all must execute before completion)."""
        bits = (jnp.uint32(1) << block_offsets.astype(jnp.uint32))
        vol = jnp.asarray(vol, jnp.int32)
        if mask is None:
            mask = jnp.ones(pages.shape, bool)
        for r in self.replicas:
            if not r.healthy:
                continue
            r.state, ops = _write_jit(r.state, vol, pages, bits, mask)
            if not self.null_storage:
                r.pool = _apply_jit(r.pool, ops, payload, block_offsets)

    def read(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray
             ) -> jnp.ndarray:
        """Round-robin read from one healthy replica. vol: scalar or (B,)."""
        order = [(self._rr + i) % len(self.replicas)
                 for i in range(len(self.replicas))]
        self._rr += 1
        for i in order:
            r = self.replicas[i]
            if r.healthy:
                if self.null_storage:
                    ext = dbs.read_resolve(
                        r.state, jnp.asarray(vol, jnp.int32), pages)
                    return jnp.zeros((pages.shape[0],) + r.pool.shape[2:],
                                     r.pool.dtype)
                return _read_jit(r.state, r.pool,
                                 jnp.asarray(vol, jnp.int32), pages,
                                 block_offsets)
        raise RuntimeError("no healthy replica")

    # -- fault handling ------------------------------------------------------
    def fail(self, idx: int) -> None:
        self.replicas[idx].healthy = False

    def consistent(self) -> bool:
        revs = {int(jax.device_get(r.state.revision))
                for r in self.replicas if r.healthy}
        return len(revs) == 1

    def rebuild(self, idx: int) -> None:
        """Restore a failed replica from the most up-to-date healthy copy
        (highest revision), then mark it healthy. Streams the full extent
        pool + metadata — the engine-level rebuild of paper §III."""
        donor = max((r for r in self.replicas if r.healthy),
                    key=lambda r: int(jax.device_get(r.state.revision)))
        tgt = self.replicas[idx]
        tgt.state = jax.tree.map(jnp.copy, donor.state)
        tgt.pool = jnp.copy(donor.pool)
        tgt.healthy = True


# ---------------------------------------------------------------------------
# mesh-collective forms (used inside shard_map)
# ---------------------------------------------------------------------------
def mirror_write(x: jnp.ndarray, axis: str, src_index: int = 0) -> jnp.ndarray:
    """Broadcast a written value from ``src_index`` to all replicas on an
    axis — write-to-all as a collective."""
    n = jax.lax.axis_size(axis)
    perm = [(src_index, j) for j in range(n) if j != src_index]
    out = jax.lax.ppermute(x, axis, perm)
    me = jax.lax.axis_index(axis)
    return jnp.where(me == src_index, x, out)


def rr_select(x: jnp.ndarray, axis: str, step: jnp.ndarray) -> jnp.ndarray:
    """Read-one-of-N: replica (step % N) contributes, others send zeros; a
    psum delivers the chosen replica's value everywhere."""
    n = jax.lax.axis_size(axis)
    me = jax.lax.axis_index(axis)
    chosen = (step % n) == me
    return jax.lax.psum(jnp.where(chosen, x, jnp.zeros_like(x)), axis)
