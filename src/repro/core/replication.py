"""Controller<->replica layer: write/read policies over a pluggable transport.

Paper §III: "Each write is replicated to all replicas, and each read is
served by one replica in round robin fashion"; the controller detects a
faulty replica and rebuilds it from the most up-to-date copy, using the
per-replica metadata "version" to establish consistency.

Since the transport redesign this module holds the **controller-side
policy objects**; the wire itself lives in core/transport.py:

- every replica is a *transport endpoint* (``transport.Replica`` /
  ``transport.StackedReplica``) reached only through opcode-tagged
  ``WireMsg`` messages over a registered ``ReplicaTransport``
  (local | device | simnet — ``EngineConfig.transport``),
- **write policies** decide when a mirrored write completes: ``all``
  (every healthy replica acked — the paper's default and bit-identical to
  the pre-transport path), ``quorum`` (a majority acked; stragglers catch
  up via per-link FIFO), ``async`` (write-behind: posted everywhere,
  acked immediately),
- **read policies** pick the serving replica: ``rr`` (round-robin, the
  paper's default) or ``latency`` (lowest observed link latency, queue
  depth as tiebreak),
- **rebuild is a streamed delta** through the same transport: the target
  reports its per-page revision watermarks (the endpoint's ``page_rev``
  array, stamped by ``transport.stamp_page_rev`` — held next to the
  ``DBSState``, not inside it), the
  donor computes which extents back newer pages, and only those pool rows
  cross the wire in bounded chunks (WATERMARKS -> FETCH_DELTA ->
  FETCH_PAGES/PUSH_PAGES -> ADOPT_META) — replacing the old
  whole-pool ``jnp.copy``. Transport counters (``pages_moved``) make the
  saving assertable.

Two planes, as before:

- **host-orchestrated replicas** (``ReplicaGroup``): R endpoints behind R
  transports — the loop/slots engines' storage, where the policies bite.
- **mesh collectives** (``mirror_write`` / ``rr_select``): the same
  write-to-all / read-one pattern expressed inside shard_map.

The fused engine step (core/fused.py) threads the endpoint pytrees exposed
by ``device_state``/``set_device_state`` through one compiled program —
mirroring and round-robin selection then happen inside that program, so on
the fused/sharded/ring engines the transport carries control and rebuild
traffic only (and those engines require the in-program policies:
``write_policy="all"``, ``read_policy="rr"``). ``ShardedReplicaGroup``
stacks S such groups along a leading shard axis on ``StackedReplica``
endpoints for the vmapped pool step in core/sharded.py. See
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbs
from repro.core.transport import (MSG_ADOPT_META, MSG_CLONE, MSG_CREATE,
                                  MSG_DELETE, MSG_FETCH_DELTA,
                                  MSG_FETCH_PAGES, MSG_PUSH_PAGES,
                                  MSG_QUERY_REV, MSG_READ, MSG_SNAPSHOT,
                                  MSG_UNMAP, MSG_WATERMARKS, MSG_WRITE,
                                  MsgFuture, Replica, ReplicaTransport,
                                  StackedReplica, WireMsg, make_transport)

WRITE_POLICIES = ("all", "quorum", "async")
READ_POLICIES = ("rr", "latency")

# extents per rebuild-stream message: bounds the transfer unit so a rebuild
# interleaves with (simulated) foreground traffic instead of one giant copy
REBUILD_CHUNK = 64


def _check_policies(write_policy: str, read_policy: str) -> None:
    if write_policy not in WRITE_POLICIES:
        raise ValueError(f"unknown write_policy {write_policy!r} "
                         f"(expected one of {WRITE_POLICIES})")
    if read_policy not in READ_POLICIES:
        raise ValueError(f"unknown read_policy {read_policy!r} "
                         f"(expected one of {READ_POLICIES})")


def _transport_opts(opts: Optional[Dict[str, Any]], i: int) -> Dict[str, Any]:
    """Per-replica view of the transport options: a list/tuple value is
    indexed per replica (e.g. ``latency=[1, 1, 6]`` — a straggler link), a
    scalar is shared. A *scalar* ``seed`` decorrelates as ``seed + i`` so
    replicas don't drop/reorder in lock-step; an explicit seed list is
    taken verbatim (``seed=[42, 42]`` really pins identical streams)."""
    opts = opts or {}
    out = {k: (v[i] if isinstance(v, (list, tuple)) else v)
           for k, v in opts.items()}
    if isinstance(opts.get("seed"), int):
        out["seed"] += i
    return out


class _Waiter:
    """Controller-side plumbing shared by both replica groups.

    ``_await`` is the completion-wait loop: tick the undelivered futures'
    transports until ``need`` of them have completed (all by default).
    In-process transports deliver at post time, so the loop body never
    runs there. ``wait_ticks`` accumulates the controller-observed wait
    time in simulated ticks — the quantity the write/read policies trade
    (benchmarks/ladder.py ``run_replication`` reports it).

    ``_delta_rebuild`` is THE rebuild wire sequence (flat and sharded
    groups differ only in donor selection and the ``shard`` address):
    target WATERMARKS -> donor FETCH_DELTA (only extents backing pages
    newer than the target's per-page watermarks) -> chunked
    FETCH_PAGES/PUSH_PAGES streams (``pages_moved`` counts them) ->
    ADOPT_META commit."""

    wait_ticks: int = 0
    null_storage: bool = False
    rebuild_chunk: int = REBUILD_CHUNK

    def _await(self, futs: Sequence[MsgFuture],
               need: Optional[int] = None) -> None:
        need = len(futs) if need is None else need
        for _ in range(ReplicaTransport.MAX_WAIT_TICKS):
            if sum(f.done for f in futs) >= need:
                return
            for f in futs:
                if not f.done:
                    f.transport.tick()
            self.wait_ticks += 1
        raise RuntimeError("replica transports livelocked "
                           f"({sum(f.done for f in futs)}/{need} delivered)")

    def _delta_rebuild(self, donor_t, tgt_t,
                       shard: Optional[int] = None) -> None:
        wm = tgt_t.call(WireMsg(op=MSG_WATERMARKS, shard=shard))
        ext_ids, meta = donor_t.call(
            WireMsg(op=MSG_FETCH_DELTA, meta=wm, shard=shard))
        if not self.null_storage:
            for lo in range(0, len(ext_ids), self.rebuild_chunk):
                chunk = jnp.asarray(ext_ids[lo:lo + self.rebuild_chunk])
                rows = donor_t.call(WireMsg(op=MSG_FETCH_PAGES,
                                            extents=chunk, shard=shard))
                tgt_t.call(WireMsg(op=MSG_PUSH_PAGES, extents=chunk,
                                   payload=rows, shard=shard))
        tgt_t.call(WireMsg(op=MSG_ADOPT_META, meta=meta, shard=shard))


# ---------------------------------------------------------------------------
# host-orchestrated replica group (the controller-side policy object)
# ---------------------------------------------------------------------------
class ReplicaGroup(_Waiter):
    """The controller's backend: mirrors control+data ops across replica
    transports under the configured write/read policies."""

    def __init__(self, n_replicas: int, n_extents: int, max_volumes: int,
                 max_pages: int, page_blocks: int, payload_shape=(4,),
                 dtype=jnp.float32, null_storage: bool = False,
                 transport: str = "local", write_policy: str = "all",
                 read_policy: str = "rr",
                 transport_opts: Optional[Dict[str, Any]] = None,
                 rebuild_chunk: int = REBUILD_CHUNK):
        _check_policies(write_policy, read_policy)
        self.null_storage = null_storage
        self.page_blocks = page_blocks
        self.write_policy = write_policy
        self.read_policy = read_policy
        self.transport_name = transport
        self.rebuild_chunk = rebuild_chunk
        # pools carry ONE extra extent row past the allocator's range: the
        # fused CoW kernel's masked-lane dump (dbs_copy_pool scratch=True),
        # which keeps the kernel input/output-aliased with no pool copies.
        # dbs.make_state only ever hands out extents < n_extents.
        self.replicas: List[Replica] = [
            Replica(state=dbs.make_state(n_extents, max_volumes, max_pages),
                    pool=jnp.zeros(
                        (n_extents + 1, page_blocks) + tuple(payload_shape),
                        dtype),
                    page_rev=jnp.zeros((max_volumes, max_pages), jnp.int32),
                    null_storage=null_storage)
            for _ in range(n_replicas)]
        self.transports = [
            make_transport(transport, r, **_transport_opts(transport_opts, i))
            for i, r in enumerate(self.replicas)]
        self._rr = 0

    # -- control plane: mirrored to every healthy replica ---------------------
    def _mirror_ctl(self, op: int, **kw) -> Any:
        """Post one control message to every healthy replica and wait for
        all acks (control ops always fence — a snapshot acked by some
        replicas only would diverge the mirror). Returns the first reply
        value (mirrored ops agree by construction)."""
        msg = WireMsg(op=op, **kw)
        futs = [t.post(msg) for t, r in zip(self.transports, self.replicas)
                if r.healthy]
        self._await(futs)
        return next((f.value for f in futs if f.value is not None), None)

    def create_volume(self) -> int:
        return int(self._mirror_ctl(MSG_CREATE))

    def snapshot(self, vol: int) -> int:
        return int(self._mirror_ctl(MSG_SNAPSHOT, volume=vol))

    def clone(self, vol: int) -> int:
        return int(self._mirror_ctl(MSG_CLONE, volume=vol))

    def unmap(self, vol: int, pages: jnp.ndarray) -> None:
        self._mirror_ctl(MSG_UNMAP, volume=vol,
                         pages=jnp.asarray(pages, jnp.int32))

    def delete_volume(self, vol: int) -> None:
        self._mirror_ctl(MSG_DELETE, volume=vol)

    # -- fused data plane (core/fused.py) ------------------------------------
    def healthy_indices(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas) if r.healthy]

    def device_state(self):
        """(states, pools) tuples for every healthy replica — the pytrees the
        fused engine step threads through one compiled program. Nothing is
        fetched: these are device-resident endpoint arrays (the transport is
        bypassed by design here — the step IS the data plane). With
        ``null_storage`` the pools are withheld (fused_step never touches
        them)."""
        idx = self.healthy_indices()
        states = tuple(self.replicas[i].state for i in idx)
        if self.null_storage:
            return states, ()
        return states, tuple(self.replicas[i].pool for i in idx)

    def set_device_state(self, states, pools) -> None:
        """Write back the fused step's outputs (healthy replicas, in the
        order ``device_state`` returned them)."""
        idx = self.healthy_indices()
        for i, st in zip(idx, states):
            self.replicas[i].state = st
        for i, pool in zip(idx, pools):
            self.replicas[i].pool = pool

    def device_page_revs(self):
        """Per-replica last-write watermark arrays for the fused step to
        stamp in-program (healthy replicas, ``device_state`` order; empty
        with ``null_storage`` — no data plane, nothing to delta-rebuild)."""
        if self.null_storage:
            return ()
        return tuple(self.replicas[i].page_rev
                     for i in self.healthy_indices())

    def set_device_page_revs(self, page_revs) -> None:
        for i, pr in zip(self.healthy_indices(), page_revs):
            self.replicas[i].page_rev = pr

    def bump_rr(self) -> int:
        """Advance and return the round-robin read cursor (shared with the
        unfused ``read`` path so interleaving the two stays fair)."""
        rr = self._rr
        self._rr += 1
        return rr

    # -- data plane ----------------------------------------------------------
    def write(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray,
              payload: jnp.ndarray, mask=None) -> None:
        """Mirror a batch of block writes to every healthy replica, then
        complete per the write policy:

        - ``all``: every replica acked (paper: every write creates multiple
          messages that all must execute before completion),
        - ``quorum``: a majority acked; the rest are in flight and deliver
          on later ticks (per-link FIFO keeps each replica's history
          prefix-ordered, so a subsequent read through any link still
          observes that link's full submission history),
        - ``async``: write-behind — acked at post time.
        """
        bits = (jnp.uint32(1) << block_offsets.astype(jnp.uint32))
        vol = jnp.asarray(vol, jnp.int32)
        if mask is None:
            mask = jnp.ones(pages.shape, bool)
        msg = WireMsg(op=MSG_WRITE, volume=vol, pages=pages,
                      blocks=block_offsets, bits=bits, payload=payload,
                      mask=mask)
        futs = [t.post(msg) for t, r in zip(self.transports, self.replicas)
                if r.healthy]
        if self.write_policy == "all":
            self._await(futs)
        elif self.write_policy == "quorum":
            self._await(futs, need=len(futs) // 2 + 1)
        # "async": fire-and-forget; acks land on later ticks / drain

    def _pick_replica(self) -> int:
        """Read-policy replica selection over the healthy set."""
        n = len(self.replicas)
        if self.read_policy == "latency":
            rr = self._rr
            self._rr += 1
            healthy = self.healthy_indices()
            if not healthy:
                raise RuntimeError("no healthy replica")
            # lowest observed link latency; queue depth then the rr cursor
            # break ties (so equal links still round-robin fairly)
            return min(healthy, key=lambda i: (
                self.transports[i].latency_ewma,
                self.transports[i].pending(), (i - rr) % n))
        order = [(self._rr + i) % n for i in range(n)]
        self._rr += 1
        for i in order:
            if self.replicas[i].healthy:
                return i
        raise RuntimeError("no healthy replica")

    def read(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray
             ) -> jnp.ndarray:
        """Policy-selected read from one healthy replica. vol: scalar or
        (B,). The read rides the chosen replica's link *behind* any of its
        in-flight writes (FIFO), so it observes that replica's full
        submission history even under quorum/async write policies."""
        if self.null_storage:
            # no replica serves anything: no resolve dispatch AND no rr
            # cursor burn (the layer-cut row must not skew the read
            # distribution real replicas would see — ChainedReplicas.read
            # holds the same contract)
            for r in self.replicas:
                if r.healthy:
                    return jnp.zeros((pages.shape[0],) + r.pool.shape[2:],
                                     r.pool.dtype)
            raise RuntimeError("no healthy replica")
        i = self._pick_replica()
        fut = self.transports[i].post(
            WireMsg(op=MSG_READ, volume=jnp.asarray(vol, jnp.int32),
                    pages=pages, blocks=block_offsets))
        self._await([fut])
        return fut.value

    def drain_transports(self) -> None:
        """Deliver everything still in flight on every link (write-behind
        and quorum stragglers)."""
        for t in self.transports:
            t.drain()

    # -- fault handling ------------------------------------------------------
    def _check_index(self, idx: int) -> None:
        if not 0 <= idx < len(self.replicas):
            raise IndexError(f"replica index {idx} out of range "
                             f"[0, {len(self.replicas)})")

    def fail(self, idx: int) -> None:
        """Mark a replica faulty and tear down its link (undelivered
        messages to a dead replica are lost; rebuild resyncs whatever
        landed). The controller never declares the LAST healthy replica
        dead — that is volume loss, not failover — so a group must keep one
        serving copy (paper §III: reads/writes continue on the surviving
        replicas while the failed one rebuilds)."""
        self._check_index(idx)
        survivors = [r for i, r in enumerate(self.replicas)
                     if r.healthy and i != idx]
        if self.replicas[idx].healthy and not survivors:
            raise RuntimeError(f"replica {idx} is the last healthy replica; "
                               "failing it would lose the volume")
        self.replicas[idx].healthy = False
        self.transports[idx].cancel_pending()

    def consistent(self) -> bool:
        """Healthy replicas agree on the metadata revision. The per-replica
        revision queries ride the links (behind any in-flight writes) and
        the device scalars come back in ONE ``device_get``."""
        futs = [t.post(WireMsg(op=MSG_QUERY_REV))
                for t, r in zip(self.transports, self.replicas) if r.healthy]
        self._await(futs)
        revs = jax.device_get(tuple(f.value for f in futs))
        return len({int(r) for r in revs}) == 1

    def rebuild(self, idx: int) -> None:
        """Restore a failed replica by STREAMING the delta from the most
        up-to-date healthy copy through the transport:

        1. the target reports its per-page revision watermarks (frozen at
           fail time — it stopped receiving writes),
        2. the donor (healthy, highest revision) computes which extents
           back pages newer than those watermarks,
        3. only those pool rows cross the wire, in ``rebuild_chunk``-sized
           messages (FETCH_PAGES -> PUSH_PAGES; ``pages_moved`` counts
           them),
        4. the donor's metadata state is adopted wholesale (it is tiny next
           to the pool — the paper's engine also syncs metadata cheaply and
           streams data), committing the rebuild.

        Unchanged pages need no transfer: healthy replicas execute
        identical op sequences, so the target's pre-fail extents are
        bit-identical to the donor's. Rebuilding a replica the controller
        never marked faulty is a protocol error (the paper's controller
        only schedules rebuilds for failed replicas), as is naming a
        replica that doesn't exist."""
        self._check_index(idx)
        tgt = self.replicas[idx]
        if tgt.healthy:
            raise ValueError(f"replica {idx} is healthy; only a failed "
                             "replica can be rebuilt")
        donors = self.healthy_indices()
        if not donors:
            raise RuntimeError("no healthy replica to rebuild from")
        futs = [self.transports[i].post(WireMsg(op=MSG_QUERY_REV))
                for i in donors]
        self._await(futs)
        revs = jax.device_get(tuple(f.value for f in futs))
        donor_t = self.transports[donors[int(np.argmax(
            [int(r) for r in revs]))]]
        self._delta_rebuild(donor_t, self.transports[idx])
        tgt.healthy = True


# ---------------------------------------------------------------------------
# sharded replica groups (the EnginePool backend, core/sharded.py)
# ---------------------------------------------------------------------------
class ShardedReplicaGroup(_Waiter):
    """S independent replica groups stacked along a leading shard axis.

    Each of R replicas is ONE ``StackedReplica`` transport endpoint whose
    leaves carry a leading (S,) dimension — shard ``s``'s replica ``r`` is
    ``states[r][s]`` — so the vmapped engine step (core/sharded.py) serves
    every shard's mirrored writes and round-robin reads in a single
    compiled program (the transport carries control and rebuild traffic;
    the in-program data plane mandates ``write_policy="all"`` /
    ``read_policy="rr"``). Because vmap cannot vary pytree *structure* per
    shard, replica health is a dense (S, R) bool mask threaded through the
    step as a traced argument rather than the host-side filtering
    ``ReplicaGroup.device_state`` does: a failed replica's shard slice
    simply stops receiving writes and serving reads until ``rebuild`` — a
    per-shard streamed delta through the replica's transport.

    The round-robin read cursors are a device-resident (S,) array bumped
    with a device add — no host sync on the pump path.
    """

    def __init__(self, n_shards: int, n_replicas: int, n_extents: int,
                 max_volumes: int, max_pages: int, page_blocks: int,
                 payload_shape=(4,), dtype=jnp.float32,
                 null_storage: bool = False, transport: str = "device",
                 write_policy: str = "all", read_policy: str = "rr",
                 transport_opts: Optional[Dict[str, Any]] = None,
                 rebuild_chunk: int = REBUILD_CHUNK):
        _check_policies(write_policy, read_policy)   # unknown names first
        if write_policy != "all" or read_policy != "rr":
            raise ValueError(
                "the sharded data plane mirrors writes and round-robins "
                "reads INSIDE the compiled step; write_policy="
                f"{write_policy!r}/read_policy={read_policy!r} need a "
                "host-dispatch backend (loop | slots)")
        self.n_shards = n_shards
        self.n_replicas = n_replicas
        self.null_storage = null_storage
        self.page_blocks = page_blocks
        self.rebuild_chunk = rebuild_chunk
        # "local" names the in-process call semantics; on stacked endpoints
        # that IS the device transport
        self.transport_name = "device" if transport == "local" else transport
        stack = lambda x: jnp.tile(x[None], (n_shards,) + (1,) * x.ndim)
        # one extra extent row per pool: the fused CoW kernel's masked-lane
        # dump (same convention as ReplicaGroup)
        endpoints = [
            StackedReplica(
                state=jax.tree.map(stack, dbs.make_state(
                    n_extents, max_volumes, max_pages)),
                pool=jnp.zeros((n_shards, n_extents + 1, page_blocks)
                               + tuple(payload_shape), dtype),
                page_rev=jnp.zeros((n_shards, max_volumes, max_pages),
                                   jnp.int32),
                null_storage=null_storage)
            for _ in range(n_replicas)]
        self.transports = [
            make_transport(self.transport_name, ep,
                           **_transport_opts(transport_opts, i))
            for i, ep in enumerate(endpoints)]
        self._healthy_np = np.ones((n_shards, n_replicas), bool)
        self._healthy_dev: Optional[jnp.ndarray] = None   # device-mask cache
        self._healthy_stale = False   # device mask newer than the np mirror
        self._rr = jnp.zeros((n_shards,), jnp.int32)

    # -- the stacked endpoint pytrees (legacy .states/.pools surface) --------
    @property
    def states(self) -> List[dbs.DBSState]:
        return [t.endpoint.state for t in self.transports]

    @property
    def pools(self) -> List[jnp.ndarray]:
        return [t.endpoint.pool for t in self.transports]

    @property
    def healthy(self) -> np.ndarray:
        """Host-side (S, R) health mirror. After in-band FAIL/REBUILD ops
        (core/ring.py) the *device* mask is authoritative; the mirror
        refreshes lazily here — host control paths pay the sync, never the
        pump."""
        if self._healthy_stale:
            self._healthy_np = np.asarray(jax.device_get(self._healthy_dev))
            self._healthy_stale = False
        return self._healthy_np

    def adopt_health(self, mask: jnp.ndarray) -> None:
        """Adopt the ring step's returned health mask (device-resident;
        in-band fail/rebuild mutated it inside the compiled program)."""
        self._healthy_dev = mask
        self._healthy_stale = True

    # -- control plane (wire messages to every replica; rare ops) ------------
    def _mirror_ctl(self, shard: int, op: int, **kw) -> Any:
        """Post one shard-addressed control message to EVERY replica
        (healthy or not: a failed replica is overwritten wholesale by
        ``rebuild``, and keeping all R slices in lock-step means rebuild
        can adopt metadata without replaying control ops). Returns replica
        0's reply."""
        msg = WireMsg(op=op, shard=shard, **kw)
        futs = [t.post(msg) for t in self.transports]
        self._await(futs)
        return futs[0].value

    def create_volume(self, shard: int) -> int:
        return int(jax.device_get(self._mirror_ctl(shard, MSG_CREATE)))

    def snapshot(self, shard: int, vol: int) -> int:
        return int(jax.device_get(
            self._mirror_ctl(shard, MSG_SNAPSHOT, volume=vol)))

    def clone(self, shard: int, vol: int) -> int:
        return int(jax.device_get(
            self._mirror_ctl(shard, MSG_CLONE, volume=vol)))

    def unmap(self, shard: int, vol: int, pages: jnp.ndarray) -> None:
        self._mirror_ctl(shard, MSG_UNMAP, volume=vol,
                         pages=jnp.asarray(pages, jnp.int32))

    def delete_volume(self, shard: int, vol: int) -> None:
        self._mirror_ctl(shard, MSG_DELETE, volume=vol)

    # -- fused data plane ----------------------------------------------------
    def device_state(self):
        """(states, pools, healthy): R stacked replica pytrees, R stacked
        pools, and the dense (S, R) health mask — the arguments the vmapped
        engine step threads through one compiled program. The mask's device
        copy is cached (health changes only on fail/rebuild control ops) so
        the pump path pays no per-iteration host-to-device transfer."""
        pools = () if self.null_storage else tuple(self.pools)
        if self._healthy_dev is None:
            self._healthy_dev = jnp.asarray(self.healthy)
        return tuple(self.states), pools, self._healthy_dev

    def set_device_state(self, states, pools) -> None:
        for t, st in zip(self.transports, states):
            t.endpoint.state = st
        if pools:
            for t, p in zip(self.transports, pools):
                t.endpoint.pool = p

    def device_page_revs(self):
        """Per-replica stacked (S, V, P) watermark arrays for the vmapped
        step to stamp in-program (empty with ``null_storage``)."""
        if self.null_storage:
            return ()
        return tuple(t.endpoint.page_rev for t in self.transports)

    def set_device_page_revs(self, page_revs) -> None:
        for t, pr in zip(self.transports, page_revs):
            t.endpoint.page_rev = pr

    def bump_rr(self) -> jnp.ndarray:
        """Return the (S,) read cursors and advance them — a device-side add,
        so the pump path never syncs on the cursor."""
        rr = self._rr
        self._rr = rr + 1
        return rr

    # -- host read path (verification / non-pump readback) -------------------
    def read(self, shard: int, vol: int, pages: jnp.ndarray,
             block_offsets: jnp.ndarray) -> jnp.ndarray:
        """Read a batch from one healthy replica of ``shard`` (host path,
        used by tests and tooling — the pump serves reads in-program)."""
        for r in range(self.n_replicas):
            if not self.healthy[shard, r]:
                continue
            ep = self.transports[r].endpoint
            if self.null_storage:
                return jnp.zeros((pages.shape[0],) + ep.pool.shape[3:],
                                 ep.pool.dtype)
            fut = self.transports[r].post(
                WireMsg(op=MSG_READ, shard=shard,
                        volume=jnp.asarray(vol, jnp.int32), pages=pages,
                        blocks=block_offsets))
            self._await([fut])
            return fut.value
        raise RuntimeError(f"no healthy replica in shard {shard}")

    def drain_transports(self) -> None:
        for t in self.transports:
            t.drain()

    # -- fault handling (per shard) ------------------------------------------
    def _check(self, shard: int, replica: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise IndexError(f"shard index {shard} out of range "
                             f"[0, {self.n_shards})")
        if not 0 <= replica < self.n_replicas:
            raise IndexError(f"replica index {replica} out of range "
                             f"[0, {self.n_replicas})")

    def fail(self, shard: int, replica: int) -> None:
        """Mark one shard's replica faulty. Refuses to fail the shard's
        last healthy replica (same controller semantics as
        ``ReplicaGroup.fail``): in the vmapped step an all-failed shard
        would silently drop writes and fabricate zero reads, since lane
        completion flags only track slot admission."""
        self._check(shard, replica)
        if self.healthy[shard, replica] and self.healthy[shard].sum() == 1:
            raise RuntimeError(
                f"replica {replica} is shard {shard}'s last healthy "
                "replica; failing it would lose the shard's volumes")
        self.healthy[shard, replica] = False
        self._healthy_dev = None

    def rebuild(self, shard: int, replica: int) -> None:
        """Restore shard ``shard``'s replica ``replica`` from the shard's
        most up-to-date healthy copy — the same streamed per-page-watermark
        delta as ``ReplicaGroup.rebuild``, scoped to one shard's slice and
        carried by the replica's transport."""
        self._check(shard, replica)
        if self.healthy[shard, replica]:
            raise ValueError(f"shard {shard} replica {replica} is healthy; "
                             "only a failed replica can be rebuilt")
        donors = [r for r in range(self.n_replicas) if self.healthy[shard, r]]
        if not donors:
            raise RuntimeError(f"no healthy replica in shard {shard} "
                               "to rebuild from")
        futs = [self.transports[r].post(WireMsg(op=MSG_QUERY_REV))
                for r in donors]
        self._await(futs)
        revs = jax.device_get(tuple(f.value for f in futs))   # each (S,)
        donor_t = self.transports[donors[int(np.argmax(
            [np.asarray(r)[shard] for r in revs]))]]
        self._delta_rebuild(donor_t, self.transports[replica], shard=shard)
        self.healthy[shard, replica] = True
        self._healthy_dev = None

    def consistent(self, shard: Optional[int] = None) -> bool:
        """Healthy replicas of a shard (or of every shard) agree on the
        metadata revision — every replica's stacked (S,) revision vector
        queried over its link, fetched in ONE ``device_get``."""
        futs = [t.post(WireMsg(op=MSG_QUERY_REV)) for t in self.transports]
        self._await(futs)
        revs = [np.asarray(r) for r in
                jax.device_get(tuple(f.value for f in futs))]
        shards = range(self.n_shards) if shard is None else [shard]
        for s in shards:
            vals = {int(revs[r][s]) for r in range(self.n_replicas)
                    if self.healthy[s, r]}
            if len(vals) > 1:
                return False
        return True


# ---------------------------------------------------------------------------
# mesh-collective forms (used inside shard_map)
# ---------------------------------------------------------------------------
def mirror_write(x: jnp.ndarray, axis: str, src_index: int = 0) -> jnp.ndarray:
    """Broadcast a written value from ``src_index`` to all replicas on an
    axis — write-to-all as a collective."""
    n = jax.lax.axis_size(axis)
    perm = [(src_index, j) for j in range(n) if j != src_index]
    out = jax.lax.ppermute(x, axis, perm)
    me = jax.lax.axis_index(axis)
    return jnp.where(me == src_index, x, out)


def rr_select(x: jnp.ndarray, axis: str, step: jnp.ndarray) -> jnp.ndarray:
    """Read-one-of-N: replica (step % N) contributes, others send zeros; a
    psum delivers the chosen replica's value everywhere."""
    n = jax.lax.axis_size(axis)
    me = jax.lax.axis_index(axis)
    chosen = (step % n) == me
    return jax.lax.psum(jnp.where(chosen, x, jnp.zeros_like(x)), axis)
