"""Controller<->replica layer: mirroring writes, round-robin reads, rebuild.

Paper §III: "Each write is replicated to all replicas, and each read is
served by one replica in round robin fashion"; the controller detects a
faulty replica and rebuilds it from the most up-to-date copy, using the
per-replica metadata "version" to establish consistency.

Two planes:

- **host-orchestrated replicas** (`ReplicaGroup`): R replica instances, each
  a (DBSState, payload pool) pair — possibly living on different jax devices
  or processes. Used by the serving engine and the ladder benchmarks; this is
  the literal structure of the Longhorn engine.
- **mesh collectives** (`mirror_write` / `rr_select`): the same write-to-all /
  read-one pattern expressed inside shard_map for the multi-pod data plane
  (gradient mirroring across "pod", page stripes across "model").

The fused engine step (core/fused.py) threads the replica pytrees exposed
by ``device_state``/``set_device_state`` through one compiled program —
mirroring and round-robin selection then happen inside that program.
``ShardedReplicaGroup`` stacks S such groups along a leading shard axis
(dense per-shard health mask, device-resident rr cursors) for the vmapped
pool step in core/sharded.py. See docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbs

# jitted data-plane ops (fixed shapes -> compiled once per batch geometry)
_write_jit = jax.jit(dbs.write_pages)
_apply_jit = jax.jit(dbs.apply_write_ops)


@jax.jit
def _read_jit(state, pool, vol, pages, block_offsets):
    ext = dbs.read_resolve(state, vol, pages)
    got = pool[jnp.maximum(ext, 0), block_offsets]
    # holes (never-written / unmapped pages) read as zeros — the clamped
    # gather would otherwise leak extent 0's payload (fused._rr_gather holds
    # the same contract; core/blockdev.py byte equivalence relies on it)
    return jnp.where((ext >= 0).reshape(ext.shape + (1,) * (got.ndim - 1)),
                     got, 0)


# ---------------------------------------------------------------------------
# host-orchestrated replica group
# ---------------------------------------------------------------------------
@dataclass
class Replica:
    state: dbs.DBSState
    pool: jnp.ndarray            # (E, page_blocks, *payload)
    healthy: bool = True


class ReplicaGroup:
    """The controller's backend: mirrors control+data ops across replicas."""

    def __init__(self, n_replicas: int, n_extents: int, max_volumes: int,
                 max_pages: int, page_blocks: int, payload_shape=(4,),
                 dtype=jnp.float32, null_storage: bool = False):
        self.null_storage = null_storage
        self.page_blocks = page_blocks
        # pools carry ONE extra extent row past the allocator's range: the
        # fused CoW kernel's masked-lane dump (dbs_copy_pool scratch=True),
        # which keeps the kernel input/output-aliased with no pool copies.
        # dbs.make_state only ever hands out extents < n_extents.
        self.replicas: List[Replica] = [
            Replica(state=dbs.make_state(n_extents, max_volumes, max_pages),
                    pool=jnp.zeros(
                        (n_extents + 1, page_blocks) + tuple(payload_shape),
                        dtype))
            for _ in range(n_replicas)]
        self._rr = 0

    # -- control plane: mirrored to every replica ---------------------------
    def _all(self, fn: Callable[[dbs.DBSState], Tuple[dbs.DBSState, Any]]):
        # default None: value-less mirrored ops (unmap/delete) return None on
        # every replica — a bare next() would leak StopIteration out of the
        # generator here (PEP 479 turns that into a RuntimeError in callers)
        outs = []
        for r in self.replicas:
            if not r.healthy:
                outs.append(None)
                continue
            r.state, out = fn(r.state)
            outs.append(out)
        return next((o for o in outs if o is not None), None)

    def create_volume(self) -> int:
        return int(self._all(dbs.create_volume))

    def snapshot(self, vol: int) -> int:
        return int(self._all(lambda s: dbs.snapshot(s, jnp.int32(vol))))

    def clone(self, vol: int) -> int:
        return int(self._all(lambda s: dbs.clone(s, jnp.int32(vol))))

    def unmap(self, vol: int, pages: jnp.ndarray) -> None:
        pages = jnp.asarray(pages, jnp.int32)
        self._all(lambda s: (dbs.unmap(s, jnp.int32(vol), pages), None))

    def delete_volume(self, vol: int) -> None:
        self._all(lambda s: (dbs.delete_volume(s, jnp.int32(vol)), None))

    # -- fused data plane (core/fused.py) ------------------------------------
    def healthy_indices(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas) if r.healthy]

    def device_state(self):
        """(states, pools) tuples for every healthy replica — the pytrees the
        fused engine step threads through one compiled program. Nothing is
        fetched: these are device-resident arrays. With ``null_storage`` the
        pools are withheld (fused_step never touches them)."""
        idx = self.healthy_indices()
        states = tuple(self.replicas[i].state for i in idx)
        if self.null_storage:
            return states, ()
        return states, tuple(self.replicas[i].pool for i in idx)

    def set_device_state(self, states, pools) -> None:
        """Write back the fused step's outputs (healthy replicas, in the
        order ``device_state`` returned them)."""
        idx = self.healthy_indices()
        for i, st in zip(idx, states):
            self.replicas[i].state = st
        for i, pool in zip(idx, pools):
            self.replicas[i].pool = pool

    def bump_rr(self) -> int:
        """Advance and return the round-robin read cursor (shared with the
        unfused ``read`` path so interleaving the two stays fair)."""
        rr = self._rr
        self._rr += 1
        return rr

    # -- data plane ----------------------------------------------------------
    def write(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray,
              payload: jnp.ndarray, mask=None) -> None:
        """Mirror a batch of block writes to every healthy replica. The write
        completes only when all replicas acked (paper: every write creates
        multiple messages that all must execute before completion)."""
        bits = (jnp.uint32(1) << block_offsets.astype(jnp.uint32))
        vol = jnp.asarray(vol, jnp.int32)
        if mask is None:
            mask = jnp.ones(pages.shape, bool)
        for r in self.replicas:
            if not r.healthy:
                continue
            r.state, ops = _write_jit(r.state, vol, pages, bits, mask)
            if not self.null_storage:
                r.pool = _apply_jit(r.pool, ops, payload, block_offsets)

    def read(self, vol, pages: jnp.ndarray, block_offsets: jnp.ndarray
             ) -> jnp.ndarray:
        """Round-robin read from one healthy replica. vol: scalar or (B,)."""
        if self.null_storage:
            # no replica serves anything: no resolve dispatch AND no rr
            # cursor burn (the layer-cut row must not skew the read
            # distribution real replicas would see — ChainedReplicas.read
            # holds the same contract)
            for r in self.replicas:
                if r.healthy:
                    return jnp.zeros((pages.shape[0],) + r.pool.shape[2:],
                                     r.pool.dtype)
            raise RuntimeError("no healthy replica")
        order = [(self._rr + i) % len(self.replicas)
                 for i in range(len(self.replicas))]
        self._rr += 1
        for i in order:
            r = self.replicas[i]
            if r.healthy:
                return _read_jit(r.state, r.pool,
                                 jnp.asarray(vol, jnp.int32), pages,
                                 block_offsets)
        raise RuntimeError("no healthy replica")

    # -- fault handling ------------------------------------------------------
    def _check_index(self, idx: int) -> None:
        if not 0 <= idx < len(self.replicas):
            raise IndexError(f"replica index {idx} out of range "
                             f"[0, {len(self.replicas)})")

    def fail(self, idx: int) -> None:
        """Mark a replica faulty. The controller never declares the LAST
        healthy replica dead — that is volume loss, not failover — so a
        group must keep one serving copy (paper §III: reads/writes continue
        on the surviving replicas while the failed one rebuilds)."""
        self._check_index(idx)
        survivors = [r for i, r in enumerate(self.replicas)
                     if r.healthy and i != idx]
        if self.replicas[idx].healthy and not survivors:
            raise RuntimeError(f"replica {idx} is the last healthy replica; "
                               "failing it would lose the volume")
        self.replicas[idx].healthy = False

    def consistent(self) -> bool:
        revs = {int(jax.device_get(r.state.revision))
                for r in self.replicas if r.healthy}
        return len(revs) == 1

    def rebuild(self, idx: int) -> None:
        """Restore a failed replica from the most up-to-date healthy copy
        (highest revision), then mark it healthy. Streams the full extent
        pool + metadata — the engine-level rebuild of paper §III. Rebuilding
        a replica the controller never marked faulty is a protocol error
        (the paper's controller only schedules rebuilds for failed
        replicas), as is naming a replica that doesn't exist."""
        self._check_index(idx)
        tgt = self.replicas[idx]
        if tgt.healthy:
            raise ValueError(f"replica {idx} is healthy; only a failed "
                             "replica can be rebuilt")
        donors = [r for r in self.replicas if r.healthy]
        if not donors:
            raise RuntimeError("no healthy replica to rebuild from")
        donor = max(donors,
                    key=lambda r: int(jax.device_get(r.state.revision)))
        tgt.state = jax.tree.map(jnp.copy, donor.state)
        tgt.pool = jnp.copy(donor.pool)
        tgt.healthy = True


# ---------------------------------------------------------------------------
# sharded replica groups (the EnginePool backend, core/sharded.py)
# ---------------------------------------------------------------------------
class ShardedReplicaGroup:
    """S independent replica groups stacked along a leading shard axis.

    Each of R replicas is held as ONE pytree whose leaves carry a leading
    (S,) dimension — shard ``s``'s replica ``r`` is ``states[r][s]`` — so the
    vmapped engine step (core/sharded.py) serves every shard's mirrored
    writes and round-robin reads in a single compiled program. Because vmap
    cannot vary pytree *structure* per shard, replica health is a dense
    (S, R) bool mask threaded through the step as a traced argument rather
    than the host-side filtering ``ReplicaGroup.device_state`` does: a
    failed replica's shard slice simply stops receiving writes and serving
    reads until ``rebuild``.

    The round-robin read cursors are a device-resident (S,) array bumped
    with a device add — no host sync on the pump path.
    """

    def __init__(self, n_shards: int, n_replicas: int, n_extents: int,
                 max_volumes: int, max_pages: int, page_blocks: int,
                 payload_shape=(4,), dtype=jnp.float32,
                 null_storage: bool = False):
        self.n_shards = n_shards
        self.n_replicas = n_replicas
        self.null_storage = null_storage
        self.page_blocks = page_blocks
        stack = lambda x: jnp.tile(x[None], (n_shards,) + (1,) * x.ndim)
        # one extra extent row per pool: the fused CoW kernel's masked-lane
        # dump (same convention as ReplicaGroup)
        self.states: List[dbs.DBSState] = [
            jax.tree.map(stack, dbs.make_state(n_extents, max_volumes,
                                               max_pages))
            for _ in range(n_replicas)]
        self.pools: List[jnp.ndarray] = [
            jnp.zeros((n_shards, n_extents + 1, page_blocks)
                      + tuple(payload_shape), dtype)
            for _ in range(n_replicas)]
        self._healthy_np = np.ones((n_shards, n_replicas), bool)
        self._healthy_dev: Optional[jnp.ndarray] = None   # device-mask cache
        self._healthy_stale = False   # device mask newer than the np mirror
        self._rr = jnp.zeros((n_shards,), jnp.int32)

    @property
    def healthy(self) -> np.ndarray:
        """Host-side (S, R) health mirror. After in-band FAIL/REBUILD ops
        (core/ring.py) the *device* mask is authoritative; the mirror
        refreshes lazily here — host control paths pay the sync, never the
        pump."""
        if self._healthy_stale:
            self._healthy_np = np.asarray(jax.device_get(self._healthy_dev))
            self._healthy_stale = False
        return self._healthy_np

    def adopt_health(self, mask: jnp.ndarray) -> None:
        """Adopt the ring step's returned health mask (device-resident;
        in-band fail/rebuild mutated it inside the compiled program)."""
        self._healthy_dev = mask
        self._healthy_stale = True

    # -- control plane (host-side slice/write-back; rare ops) ----------------
    def _shard_op(self, shard: int, fn):
        """Apply ``fn(state) -> (state', out)`` to shard ``shard`` of every
        replica (healthy or not: a failed replica is overwritten wholesale by
        ``rebuild``, and keeping all R slices in lock-step means rebuild can
        copy metadata without replaying control ops)."""
        outs = []
        for r in range(self.n_replicas):
            st = jax.tree.map(lambda x: x[shard], self.states[r])
            st, out = fn(st)
            self.states[r] = jax.tree.map(
                lambda full, new: full.at[shard].set(new),
                self.states[r], st)
            outs.append(out)
        return outs[0]

    def create_volume(self, shard: int) -> int:
        return int(jax.device_get(self._shard_op(shard, dbs.create_volume)))

    def snapshot(self, shard: int, vol: int) -> int:
        return int(jax.device_get(self._shard_op(
            shard, lambda s: dbs.snapshot(s, jnp.int32(vol)))))

    def clone(self, shard: int, vol: int) -> int:
        return int(jax.device_get(self._shard_op(
            shard, lambda s: dbs.clone(s, jnp.int32(vol)))))

    def unmap(self, shard: int, vol: int, pages: jnp.ndarray) -> None:
        pages = jnp.asarray(pages, jnp.int32)
        self._shard_op(shard,
                       lambda s: (dbs.unmap(s, jnp.int32(vol), pages), None))

    def delete_volume(self, shard: int, vol: int) -> None:
        self._shard_op(
            shard, lambda s: (dbs.delete_volume(s, jnp.int32(vol)), None))

    # -- fused data plane ----------------------------------------------------
    def device_state(self):
        """(states, pools, healthy): R stacked replica pytrees, R stacked
        pools, and the dense (S, R) health mask — the arguments the vmapped
        engine step threads through one compiled program. The mask's device
        copy is cached (health changes only on fail/rebuild control ops) so
        the pump path pays no per-iteration host-to-device transfer."""
        pools = () if self.null_storage else tuple(self.pools)
        if self._healthy_dev is None:
            self._healthy_dev = jnp.asarray(self.healthy)
        return tuple(self.states), pools, self._healthy_dev

    def set_device_state(self, states, pools) -> None:
        self.states = list(states)
        if pools:
            self.pools = list(pools)

    def bump_rr(self) -> jnp.ndarray:
        """Return the (S,) read cursors and advance them — a device-side add,
        so the pump path never syncs on the cursor."""
        rr = self._rr
        self._rr = rr + 1
        return rr

    # -- host read path (verification / non-pump readback) -------------------
    def read(self, shard: int, vol: int, pages: jnp.ndarray,
             block_offsets: jnp.ndarray) -> jnp.ndarray:
        """Read a batch from one healthy replica of ``shard`` (host path,
        used by tests and tooling — the pump serves reads in-program)."""
        for r in range(self.n_replicas):
            if not self.healthy[shard, r]:
                continue
            if self.null_storage:
                return jnp.zeros((pages.shape[0],) + self.pools[r].shape[3:],
                                 self.pools[r].dtype)
            st = jax.tree.map(lambda x: x[shard], self.states[r])
            return _read_jit(st, self.pools[r][shard],
                             jnp.asarray(vol, jnp.int32), pages,
                             block_offsets)
        raise RuntimeError(f"no healthy replica in shard {shard}")

    # -- fault handling (per shard) ------------------------------------------
    def _check(self, shard: int, replica: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise IndexError(f"shard index {shard} out of range "
                             f"[0, {self.n_shards})")
        if not 0 <= replica < self.n_replicas:
            raise IndexError(f"replica index {replica} out of range "
                             f"[0, {self.n_replicas})")

    def fail(self, shard: int, replica: int) -> None:
        """Mark one shard's replica faulty. Refuses to fail the shard's
        last healthy replica (same controller semantics as
        ``ReplicaGroup.fail``): in the vmapped step an all-failed shard
        would silently drop writes and fabricate zero reads, since lane
        completion flags only track slot admission."""
        self._check(shard, replica)
        if self.healthy[shard, replica] and self.healthy[shard].sum() == 1:
            raise RuntimeError(
                f"replica {replica} is shard {shard}'s last healthy "
                "replica; failing it would lose the shard's volumes")
        self.healthy[shard, replica] = False
        self._healthy_dev = None

    def rebuild(self, shard: int, replica: int) -> None:
        """Restore shard ``shard``'s replica ``replica`` from the shard's
        most up-to-date healthy copy (same protocol as
        ``ReplicaGroup.rebuild``, scoped to one shard's slice)."""
        self._check(shard, replica)
        if self.healthy[shard, replica]:
            raise ValueError(f"shard {shard} replica {replica} is healthy; "
                             "only a failed replica can be rebuilt")
        donors = [r for r in range(self.n_replicas) if self.healthy[shard, r]]
        if not donors:
            raise RuntimeError(f"no healthy replica in shard {shard} "
                               "to rebuild from")
        donor = max(donors, key=lambda r: int(
            jax.device_get(self.states[r].revision[shard])))
        self.states[replica] = jax.tree.map(
            lambda full, src: full.at[shard].set(src[shard]),
            self.states[replica], self.states[donor])
        self.pools[replica] = self.pools[replica].at[shard].set(
            self.pools[donor][shard])
        self.healthy[shard, replica] = True
        self._healthy_dev = None

    def consistent(self, shard: Optional[int] = None) -> bool:
        """Healthy replicas of a shard (or of every shard) agree on the
        metadata revision."""
        shards = range(self.n_shards) if shard is None else [shard]
        for s in shards:
            revs = {int(jax.device_get(self.states[r].revision[s]))
                    for r in range(self.n_replicas) if self.healthy[s, r]}
            if len(revs) > 1:
                return False
        return True


# ---------------------------------------------------------------------------
# mesh-collective forms (used inside shard_map)
# ---------------------------------------------------------------------------
def mirror_write(x: jnp.ndarray, axis: str, src_index: int = 0) -> jnp.ndarray:
    """Broadcast a written value from ``src_index`` to all replicas on an
    axis — write-to-all as a collective."""
    n = jax.lax.axis_size(axis)
    perm = [(src_index, j) for j in range(n) if j != src_index]
    out = jax.lax.ppermute(x, axis, perm)
    me = jax.lax.axis_index(axis)
    return jnp.where(me == src_index, x, out)


def rr_select(x: jnp.ndarray, axis: str, step: jnp.ndarray) -> jnp.ndarray:
    """Read-one-of-N: replica (step % N) contributes, others send zeros; a
    psum delivers the chosen replica's value everywhere."""
    n = jax.lax.axis_size(axis)
    me = jax.lax.axis_index(axis)
    chosen = (step % n) == me
    return jax.lax.psum(jnp.where(chosen, x, jnp.zeros_like(x)), axis)
