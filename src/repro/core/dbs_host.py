"""On-disk Direct Block Store — the checkpoint medium (paper §IV-D, Fig. 5).

A faithful single-file DBS with the paper's four regions:

  [ superblock | volume+snapshot metadata | extent status (owners+bitmaps) | data ]

- fixed-size extents of ``extent_blocks`` x ``block_size`` bytes,
- bitmap allocation, **allocation-mark serialization**: only the superblock
  write that advances the free list is ordered (fsync'd) — data writes into
  already-allocated extents are independent,
- snapshot chains with copy-on-write; **snapshot merge-deletion** (unique
  extents of a deleted snapshot merge into its child, paper semantics),
- the per-volume flattened extent map is *not* stored: it is rebuilt by
  walking the chain at open() — "reconstructed at startup and kept in memory
  for maximum efficiency",
- crash consistency: the superblock carries a revision + committed flag;
  torn writes behind the allocation mark are invisible after recovery.

Used by repro.checkpoint as the checkpoint volume store.
"""
from __future__ import annotations

import json
import os
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"DBSv1\x00\x00\x00"
SUPERBLOCK_SIZE = 4096
META_ENTRY = 64


@dataclass
class Snapshot:
    sid: int
    parent: int                 # -1 = root
    volume: str
    live: bool = True           # head of some volume (writable layer)


class DBSHost:
    def __init__(self, path: str):
        self.path = path
        self.f = None
        self.extent_blocks = 0
        self.block_size = 0
        self.n_extents = 0
        self.meta_bytes = 0
        self.revision = 0
        self.volumes: Dict[str, int] = {}          # name -> head snapshot id
        self.snapshots: Dict[int, Snapshot] = {}
        self.extent_owner: np.ndarray = None       # (E,) int32
        self.extent_page: np.ndarray = None        # (E,) int32 logical page
        self.bitmaps: np.ndarray = None            # (E,) uint32
        self.free: List[int] = []
        self.tables: Dict[str, np.ndarray] = {}    # in-memory extent maps
        self.max_pages = 0
        self.next_sid = 0

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, path: str, *, n_extents: int = 1024,
               extent_blocks: int = 32, block_size: int = 4096,
               max_pages: int = 4096, meta_bytes: int = 1 << 20) -> "DBSHost":
        d = cls(path)
        d.extent_blocks, d.block_size = extent_blocks, block_size
        d.n_extents, d.max_pages = n_extents, max_pages
        d.meta_bytes = meta_bytes
        d.extent_owner = np.full((n_extents,), -1, np.int32)
        d.extent_page = np.full((n_extents,), -1, np.int32)
        d.bitmaps = np.zeros((n_extents,), np.uint32)
        d.free = list(range(n_extents))
        d.f = open(path, "w+b")
        size = (SUPERBLOCK_SIZE + meta_bytes + d._status_bytes()
                + n_extents * extent_blocks * block_size)
        d.f.truncate(size)
        d._commit()
        return d

    @classmethod
    def open(cls, path: str) -> "DBSHost":
        d = cls(path)
        d.f = open(path, "r+b")
        d._load_superblock()
        d._load_metadata()
        d._rebuild_tables()                 # the paper's startup scan
        return d

    def close(self):
        if self.f:
            self._commit()
            self.f.close()
            self.f = None

    # ------------------------------------------------------------ superblock
    def _status_bytes(self) -> int:
        return self.n_extents * 12          # owner(4) + page(4) + bitmap(4)

    def _data_off(self, ext: int) -> int:
        return (SUPERBLOCK_SIZE + self.meta_bytes + self._status_bytes()
                + ext * self.extent_blocks * self.block_size)

    def _commit(self):
        """Serialized superblock+metadata write (the allocation-mark path)."""
        self.revision += 1
        meta = {
            "volumes": self.volumes,
            "snapshots": {str(s.sid): [s.parent, s.volume, s.live]
                          for s in self.snapshots.values()},
            "next_sid": self.next_sid,
            "free": self.free,
        }
        blob = json.dumps(meta).encode()
        if len(blob) > self.meta_bytes:
            raise IOError("metadata region overflow")
        sb = struct.pack("<8sQIIIIQ", MAGIC, self.revision, self.n_extents,
                         self.extent_blocks, self.block_size, self.max_pages,
                         len(blob)) + struct.pack("<I", self.meta_bytes)
        self.f.seek(0)
        self.f.write(sb.ljust(SUPERBLOCK_SIZE, b"\x00"))
        self.f.seek(SUPERBLOCK_SIZE)
        self.f.write(blob)
        self.f.seek(SUPERBLOCK_SIZE + self.meta_bytes)
        status = np.concatenate([
            self.extent_owner.view(np.uint8).reshape(-1),
            self.extent_page.view(np.uint8).reshape(-1),
            self.bitmaps.view(np.uint8).reshape(-1)])
        self.f.write(status.tobytes())
        self.f.flush()
        os.fsync(self.f.fileno())

    def _load_superblock(self):
        self.f.seek(0)
        raw = self.f.read(SUPERBLOCK_SIZE)
        magic, rev, ne, eb, bs, mp, blob_len = struct.unpack_from("<8sQIIIIQ", raw)
        (self.meta_bytes,) = struct.unpack_from("<I", raw, struct.calcsize("<8sQIIIIQ"))
        if magic != MAGIC:
            raise IOError(f"{self.path}: not a DBS device")
        self.revision, self.n_extents = rev, ne
        self.extent_blocks, self.block_size, self.max_pages = eb, bs, mp
        self._blob_len = blob_len

    def _load_metadata(self):
        self.f.seek(SUPERBLOCK_SIZE)
        meta = json.loads(self.f.read(self._blob_len).decode())
        self.volumes = {k: int(v) for k, v in meta["volumes"].items()}
        self.snapshots = {
            int(sid): Snapshot(int(sid), p, vol, live)
            for sid, (p, vol, live) in meta["snapshots"].items()}
        self.next_sid = meta["next_sid"]
        self.free = list(meta["free"])
        self.f.seek(SUPERBLOCK_SIZE + self.meta_bytes)
        buf = np.frombuffer(self.f.read(self._status_bytes()), np.uint8)
        e = self.n_extents
        self.extent_owner = buf[:4 * e].view(np.int32).copy()
        self.extent_page = buf[4 * e:8 * e].view(np.int32).copy()
        self.bitmaps = buf[8 * e:12 * e].view(np.uint32).copy()

    # ------------------------------------------------- in-memory extent maps
    def _chain(self, sid: int) -> List[int]:
        out = []
        while sid >= 0:
            out.append(sid)
            sid = self.snapshots[sid].parent
        return out

    def _rebuild_tables(self):
        """Walk chains oldest->newest so newer snapshots override."""
        self.tables = {}
        by_snap: Dict[int, List[int]] = {}
        for ext in range(self.n_extents):
            sid = int(self.extent_owner[ext])
            if sid >= 0:
                by_snap.setdefault(sid, []).append(ext)
        for name, head in self.volumes.items():
            table = np.full((self.max_pages,), -1, np.int32)
            for sid in reversed(self._chain(head)):
                for ext in by_snap.get(sid, ()):
                    table[self.extent_page[ext]] = ext
            self.tables[name] = table

    # -------------------------------------------------------------- control
    def create_volume(self, name: str) -> None:
        if name in self.volumes:
            raise KeyError(f"volume {name!r} exists")
        sid = self.next_sid
        self.next_sid += 1
        self.snapshots[sid] = Snapshot(sid, -1, name)
        self.volumes[name] = sid
        self.tables[name] = np.full((self.max_pages,), -1, np.int32)
        self._commit()

    def snapshot(self, name: str) -> int:
        head = self.volumes[name]
        sid = self.next_sid
        self.next_sid += 1
        self.snapshots[head].live = False
        self.snapshots[sid] = Snapshot(sid, head, name)
        self.volumes[name] = sid
        self._commit()
        return head                       # the frozen snapshot id

    def clone(self, src: str, dst: str, snapshot_id: Optional[int] = None
              ) -> None:
        """New volume from src's snapshot (default: freeze current head)."""
        if dst in self.volumes:
            raise KeyError(f"volume {dst!r} exists")
        frozen = self.snapshot(src) if snapshot_id is None else snapshot_id
        sid = self.next_sid
        self.next_sid += 1
        self.snapshots[sid] = Snapshot(sid, frozen, dst)
        self.volumes[dst] = sid
        # rebuild dst table from the chain (cheap: metadata only)
        table = np.full((self.max_pages,), -1, np.int32)
        by_page: Dict[int, int] = {}
        for s in reversed(self._chain(frozen)):
            for ext in np.nonzero(self.extent_owner == s)[0]:
                table[self.extent_page[ext]] = ext
        self.tables[dst] = table
        self._commit()

    def delete_volume(self, name: str) -> None:
        head = self.volumes.pop(name)
        self.tables.pop(name, None)
        referenced = {s.parent for s in self.snapshots.values()}
        for sid in self._chain(head):
            snap = self.snapshots[sid]
            if snap.volume != name:
                break                     # shared ancestor from a clone
            if sid in referenced and any(
                    s.parent == sid and s.volume != name
                    for s in self.snapshots.values()):
                break                     # another volume forks here
            for ext in np.nonzero(self.extent_owner == sid)[0]:
                self._free_extent(int(ext))
            del self.snapshots[sid]
        self._commit()

    def delete_snapshot(self, sid: int) -> None:
        """Merge-delete a non-head snapshot: its unique extents move into the
        child snapshot; pages shadowed by the child are freed (paper §IV-D)."""
        snap = self.snapshots[sid]
        children = [s for s in self.snapshots.values() if s.parent == sid]
        if not children:
            raise ValueError("cannot merge-delete a head snapshot")
        if len(children) > 1:
            raise ValueError("snapshot has multiple children (fork point)")
        child = children[0]
        child_pages = {int(self.extent_page[e])
                       for e in np.nonzero(self.extent_owner == child.sid)[0]}
        for ext in np.nonzero(self.extent_owner == sid)[0]:
            if int(self.extent_page[ext]) in child_pages:
                self._free_extent(int(ext))          # shadowed: free
            else:
                self.extent_owner[ext] = child.sid   # unique: merge
        child.parent = snap.parent
        del self.snapshots[sid]
        self._commit()

    def _free_extent(self, ext: int) -> None:
        self.extent_owner[ext] = -1
        self.extent_page[ext] = -1
        self.bitmaps[ext] = 0
        self.free.append(ext)

    # ----------------------------------------------------------------- I/O
    def write(self, name: str, offset: int, data: bytes) -> None:
        """Write bytes at a block-aligned offset (CoW through snapshots)."""
        bs, eb = self.block_size, self.extent_blocks
        if offset % bs or len(data) % bs:
            raise ValueError("unaligned write")
        head = self.volumes[name]
        table = self.tables[name]
        pos = 0
        dirty_meta = False
        while pos < len(data):
            page, blk = divmod((offset + pos) // bs, eb)
            n = min(eb - blk, (len(data) - pos) // bs)
            ext = int(table[page])
            owner = int(self.extent_owner[ext]) if ext >= 0 else -1
            if ext < 0 or owner != head:
                new = self.free.pop(0)               # allocation: serialized
                if ext >= 0:                         # CoW copy old content
                    self.f.seek(self._data_off(ext))
                    old = self.f.read(eb * bs)
                    self.f.seek(self._data_off(new))
                    self.f.write(old)
                    self.bitmaps[new] = self.bitmaps[ext]
                self.extent_owner[new] = head
                self.extent_page[new] = page
                table[page] = new
                ext = new
                dirty_meta = True
            bits = 0
            for i in range(n):
                bits |= 1 << (blk + i)
            self.bitmaps[ext] = np.uint32(int(self.bitmaps[ext]) | bits)
            self.f.seek(self._data_off(ext) + blk * bs)
            self.f.write(data[pos:pos + n * bs])
            pos += n * bs
        if dirty_meta:
            self._commit()                           # allocation-mark update
        else:
            self.f.flush()

    def read(self, name: str, offset: int, length: int) -> bytes:
        bs, eb = self.block_size, self.extent_blocks
        table = self.tables[name]
        out = bytearray()
        pos = 0
        while pos < length:
            page, blk = divmod((offset + pos) // bs, eb)
            n = min(eb - blk, (length - pos) // bs) or 1
            ext = int(table[page])
            if ext < 0:
                out += b"\x00" * (n * bs)
            else:
                self.f.seek(self._data_off(ext) + blk * bs)
                out += self.f.read(n * bs)
            pos += n * bs
        return bytes(out[:length])

    def unmap(self, name: str, page: int) -> None:
        table = self.tables[name]
        ext = int(table[page])
        if ext < 0:
            return
        if int(self.extent_owner[ext]) == self.volumes[name]:
            self._free_extent(ext)
        table[page] = -1
        self._commit()

    # ------------------------------------------------------------- queries
    def stats(self) -> dict:
        return {
            "volumes": sorted(self.volumes),
            "snapshots": len(self.snapshots),
            "extents_free": len(self.free),
            "extents_used": int((self.extent_owner >= 0).sum()),
            "revision": self.revision,
        }
