"""Console-script entry points (pyproject ``[project.scripts]``).

``repro-bench`` wraps the benchmark ladder (benchmarks/ladder.py) — the
paper's Tables I/II methodology plus this repo's +fused/+sharded/+ring
columns and the byte-addressed ``blockdev`` workload driven through the
public ``VolumeManager`` API. The benchmarks live next to the repo root
(not inside the installed package), so the wrapper also resolves them from
the current checkout — which is how the CI bench-smoke job runs it.
"""
from __future__ import annotations

import os
import sys


def main(argv=None) -> int:
    try:
        from benchmarks.ladder import main as ladder_main
    except ImportError:
        # running from an installed package: pick the benchmarks up from the
        # working directory (the repo checkout CI runs in)
        sys.path.insert(0, os.getcwd())
        try:
            from benchmarks.ladder import main as ladder_main
        except ImportError as e:
            print("repro-bench: cannot import benchmarks.ladder — run from "
                  f"the repository root ({e})", file=sys.stderr)
            return 2
    return ladder_main(argv)


if __name__ == "__main__":
    sys.exit(main())
