"""Computational storage: in-band storage functions (PAPERS.md,
BPF-for-storage). A COMPUTE SQE names a registered storage function by id;
the engine runs it against the device-resident extent pool inside the same
jitted step as data and control — one SQE replaces reading every page
across the host boundary. See registry.py for the registry contract,
functions.py for the five built-ins, phase.py for the ring step's compute
phase, exec.py for the host-oracle / eager device executors, and
``Volume.compute`` (core/blockdev.py) for the public byte-level surface.
"""
from repro.compute.registry import (ST_MISMATCH, StorageFn,  # noqa: F401
                                    available_storage_fns, make_storage_fn,
                                    register_storage_fn, registry_version,
                                    storage_fn_id)
from repro.compute import functions  # noqa: F401  (registers the built-ins)
