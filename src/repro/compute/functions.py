"""The five built-in storage functions.

Every function exists three times (device ``apply``, sequential jnp
``host_ref``, pure-Python ``mirror``) over one shared byte-level spec, so
bit-identity across backends is a property of the spec, not luck:

- a *byte* is ``int(lane) & 0xFF`` of a float32 payload lane (the blockdev
  byte API stores one byte per lane);
- the page checksum is a position-sensitive xor-fold
  ``XOR_j rotl32(byte_j + 1, j % 31)`` (the ``+1`` makes runs of zeros at
  different offsets distinguishable, the rotation makes it order-sensitive);
- a range checksum folds page sums the same way:
  ``XOR_p rotl32(pagesum_p, p % 31)`` over the addressed pages;
- a block checksum is the page fold applied to one block's bytes;
- the CQ ``value`` lane carries the uint32 result bit-cast to int32.

XOR folds are associative/commutative, so the device may reduce in any
order while ``host_ref`` folds strictly sequentially — same bits.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compute.registry import ST_MISMATCH, register_storage_fn

# ---------------------------------------------------------------------------
# shared jnp helpers
# ---------------------------------------------------------------------------


def _as_bytes_u32(lanes: jnp.ndarray) -> jnp.ndarray:
    """float32 byte lanes (each holding 0..255) -> uint32 byte values."""
    return lanes.astype(jnp.int32).astype(jnp.uint32) & jnp.uint32(0xFF)


def _rotl32(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    s = jnp.asarray(s, jnp.uint32) % jnp.uint32(32)
    # (32 - s) % 32 keeps the right-shift amount in [0, 31] at s == 0
    return (x << s) | (x >> ((jnp.uint32(32) - s) % jnp.uint32(32)))


def _xor_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jax.lax.reduce(x, jnp.uint32(0),
                          lambda a, b: jnp.bitwise_xor(a, b),
                          (axis % x.ndim,))


def _fold_bytes(b: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Position-sensitive xor-fold along ``axis``: XOR_j rotl32(b_j+1, j%31)."""
    axis = axis % b.ndim
    j = jnp.arange(b.shape[axis], dtype=jnp.uint32) % jnp.uint32(31)
    j = j.reshape((1,) * axis + (-1,) + (1,) * (b.ndim - axis - 1))
    return _xor_reduce(_rotl32(b + jnp.uint32(1), j), axis)


def _u32_to_i32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _in_range(P: int, page, count) -> jnp.ndarray:
    p = jnp.arange(P, dtype=jnp.int32)
    return (p >= page) & (p < page + count)


def _byte_matrix(content: jnp.ndarray) -> jnp.ndarray:
    """(P, page_blocks, *S) lanes -> (P, page_bytes) uint32 byte values."""
    P = content.shape[0]
    return _as_bytes_u32(content.reshape(P, -1))


def _block_lanes(content: jnp.ndarray, page, block) -> jnp.ndarray:
    """One block's lanes, index-clamped (callers validate addresses)."""
    pg = jnp.clip(page, 0, content.shape[0] - 1)
    bl = jnp.clip(block, 0, content.shape[1] - 1)
    return content[pg, bl]


def _zero(payload: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros_like(payload)


_FALSE = lambda: jnp.asarray(False)
_OK = lambda: jnp.int32(0)

# ---------------------------------------------------------------------------
# checksum — range fold (one SQE replaces reading every page back)
# ---------------------------------------------------------------------------


def _checksum_apply(content, page, block, arg, payload):
    b = _byte_matrix(content)
    P = content.shape[0]
    psums = _fold_bytes(b, axis=1)                              # (P,) uint32
    rot = _rotl32(psums, jnp.arange(P, dtype=jnp.uint32) % 31)
    total = _xor_reduce(jnp.where(_in_range(P, page, block), rot,
                                  jnp.uint32(0)), 0)
    return _u32_to_i32(total), _OK(), _zero(payload), _FALSE()


def _fold_bytes_seq(b: jnp.ndarray) -> jnp.ndarray:
    """Strictly sequential fold of a 1-D uint32 byte vector."""
    def body(j, acc):
        return acc ^ _rotl32(b[j] + jnp.uint32(1),
                             jnp.asarray(j, jnp.uint32) % 31)
    return jax.lax.fori_loop(0, b.shape[0], body, jnp.uint32(0))


def _checksum_ref(content, page, block, arg, payload):
    b = _byte_matrix(content)
    def body(p, acc):
        ps = _rotl32(_fold_bytes_seq(b[p]), jnp.asarray(p, jnp.uint32) % 31)
        hit = (p >= page) & (p < page + block)
        return jnp.where(hit, acc ^ ps, acc)
    total = jax.lax.fori_loop(0, content.shape[0], body, jnp.uint32(0))
    return _u32_to_i32(total), _OK(), _zero(payload), _FALSE()

# ---------------------------------------------------------------------------
# scan_count — predicate match count (arg in 0..255: byte == arg;
# arg < 0: byte != 0)
# ---------------------------------------------------------------------------


def _match(b: jnp.ndarray, arg) -> jnp.ndarray:
    tgt = arg.astype(jnp.uint32) & jnp.uint32(0xFF)
    return jnp.where(arg < 0, b != 0, b == tgt)


def _scan_count_apply(content, page, block, arg, payload):
    b = _byte_matrix(content)
    m = _match(b, arg) & _in_range(content.shape[0], page, block)[:, None]
    return m.astype(jnp.int32).sum(), _OK(), _zero(payload), _FALSE()


def _scan_count_ref(content, page, block, arg, payload):
    b = _byte_matrix(content)
    def body(p, acc):
        hit = (p >= page) & (p < page + block)
        row = _match(b[p], arg).astype(jnp.int32).sum()
        return acc + jnp.where(hit, row, 0)
    n = jax.lax.fori_loop(0, content.shape[0], body, jnp.int32(0))
    return n, _OK(), _zero(payload), _FALSE()

# ---------------------------------------------------------------------------
# filter_pages — matching page indices through the CQ payload lanes
# (value = total match count; payload = first D ascending indices, -1 pad)
# ---------------------------------------------------------------------------


def _filter_pages_apply(content, page, block, arg, payload):
    P = content.shape[0]
    D = int(payload.size)
    b = _byte_matrix(content)
    hits = jnp.any(_match(b, arg), axis=1) & _in_range(P, page, block)
    count = hits.astype(jnp.int32).sum()
    idx = jnp.sort(jnp.where(hits, jnp.arange(P, dtype=jnp.int32), P))
    if D <= P:
        sel = idx[:D]
    else:
        sel = jnp.concatenate([idx, jnp.full((D - P,), P, jnp.int32)])
    out = jnp.where(sel < P, sel, -1).astype(jnp.float32)
    return count, _OK(), out.reshape(payload.shape), _FALSE()


def _filter_pages_ref(content, page, block, arg, payload):
    P = content.shape[0]
    D = int(payload.size)
    b = _byte_matrix(content)
    lane = jnp.arange(D, dtype=jnp.int32)
    def body(p, carry):
        out, n = carry
        hit = ((p >= page) & (p < page + block)
               & jnp.any(_match(b[p], arg)))
        place = hit & (n < D)
        out = jnp.where(place & (lane == n), p, out)
        return out, n + hit.astype(jnp.int32)
    out, n = jax.lax.fori_loop(0, P, body,
                               (jnp.full((D,), -1, jnp.int32), jnp.int32(0)))
    return n, _OK(), out.astype(jnp.float32).reshape(payload.shape), _FALSE()

# ---------------------------------------------------------------------------
# compare_and_write — checksum-compare CAS riding the CoW write path:
# arg is the expected *blocksum* of the current block; on match the SQE
# payload is committed to the block (value always = actual blocksum)
# ---------------------------------------------------------------------------


def _cas_status(match) -> jnp.ndarray:
    return jnp.where(match, 0, ST_MISMATCH).astype(jnp.int32)


def _cas_apply(content, page, block, arg, payload):
    bb = _as_bytes_u32(_block_lanes(content, page, block).reshape(-1))
    bsum = _u32_to_i32(_fold_bytes(bb, 0))
    match = bsum == arg
    return bsum, _cas_status(match), _zero(payload), match


def _cas_ref(content, page, block, arg, payload):
    bb = _as_bytes_u32(_block_lanes(content, page, block).reshape(-1))
    bsum = _u32_to_i32(_fold_bytes_seq(bb))
    match = bsum == arg
    return bsum, _cas_status(match), _zero(payload), match

# ---------------------------------------------------------------------------
# verify_on_read — read one block AND return its checksum-match status
# (arg = expected blocksum; arg == 0 skips the check and just checksums)
# ---------------------------------------------------------------------------


def _verify_status(bsum, arg) -> jnp.ndarray:
    return jnp.where((arg == 0) | (bsum == arg), 0,
                     ST_MISMATCH).astype(jnp.int32)


def _verify_apply(content, page, block, arg, payload):
    blk = _block_lanes(content, page, block)
    bb = _as_bytes_u32(blk.reshape(-1))
    bsum = _u32_to_i32(_fold_bytes(bb, 0))
    return bsum, _verify_status(bsum, arg), blk.reshape(payload.shape), _FALSE()


def _verify_ref(content, page, block, arg, payload):
    blk = _block_lanes(content, page, block)
    bb = _as_bytes_u32(blk.reshape(-1))
    bsum = _u32_to_i32(_fold_bytes_seq(bb))
    return bsum, _verify_status(bsum, arg), blk.reshape(payload.shape), _FALSE()

# ---------------------------------------------------------------------------
# pure-Python mirrors over the byte-oracle shadow
# ---------------------------------------------------------------------------


def py_rotl32(x: int, s: int) -> int:
    s %= 32
    return ((x << s) | (x >> ((32 - s) % 32))) & 0xFFFFFFFF


def py_fold(bs) -> int:
    t = 0
    for j, v in enumerate(bs):
        t ^= py_rotl32((v + 1) & 0xFFFFFFFF, j % 31)
    return t


def py_i32(x: int) -> int:
    x &= 0xFFFFFFFF
    return x - (1 << 32) if x >= (1 << 31) else x


def py_blocksum(data) -> int:
    """int32 blocksum of a bytes-like block — build `compare_and_write` /
    `verify_on_read` expectations from host-side bytes."""
    return py_i32(py_fold(data))


def np_blocksum(data) -> int:
    """Vectorized twin of ``py_blocksum``: the identical rotate/XOR fold,
    numpy instead of a per-byte Python loop. The durability journal
    checksums every record body on the group-commit path, so the fold
    must not cost a Python iteration per payload byte
    (``tests/test_durability.py`` pins the two bit-identical)."""
    a = np.frombuffer(memoryview(data), np.uint8)
    if a.size == 0:
        return 0
    v = a.astype(np.uint64) + 1
    s = np.arange(a.size, dtype=np.uint64) % 31
    r = ((v << s) | (v >> ((32 - s) % 32))) & np.uint64(0xFFFFFFFF)
    return py_i32(int(np.bitwise_xor.reduce(r)))


def np_blocksum_many(blobs) -> list:
    """``np_blocksum`` over MANY non-empty blobs in one numpy pass.

    The journal group-commits a whole pump's records as one append; summing
    each ~100-byte body separately pays numpy's fixed per-call overhead per
    record, which dominates at that size. Concatenate instead, rebuild each
    byte's position-in-blob, and XOR-fold per span with ``reduceat``.
    Bit-identical to calling ``np_blocksum`` on each blob (record bodies are
    never empty — the header alone is 27 bytes)."""
    lens = np.fromiter((len(b) for b in blobs), np.int64, len(blobs))
    cat = np.frombuffer(b"".join(blobs), np.uint8)
    starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
    pos = np.arange(cat.size, dtype=np.uint64)
    pos -= np.repeat(starts, lens).astype(np.uint64)
    v = cat.astype(np.uint64) + 1
    s = pos % 31
    r = ((v << s) | (v >> ((32 - s) % 32))) & np.uint64(0xFFFFFFFF)
    return [py_i32(int(t)) for t in np.bitwise_xor.reduceat(r, starts)]


def _pages(shadow, page_bytes: int, page: int, count: int):
    n_pages = len(shadow) // page_bytes
    return range(max(page, 0), min(page + count, n_pages))


def _py_match(v: int, arg: int) -> bool:
    return v != 0 if arg < 0 else v == (arg & 0xFF)


def _checksum_mirror(shadow, page_bytes, block_bytes, page, block, arg, data):
    t = 0
    for p in _pages(shadow, page_bytes, page, block):
        ps = py_fold(shadow[p * page_bytes:(p + 1) * page_bytes])
        t ^= py_rotl32(ps, p % 31)
    return py_i32(t), 0, None


def _scan_count_mirror(shadow, page_bytes, block_bytes, page, block, arg,
                       data):
    n = 0
    for p in _pages(shadow, page_bytes, page, block):
        seg = shadow[p * page_bytes:(p + 1) * page_bytes]
        n += sum(1 for v in seg if _py_match(v, arg))
    return n, 0, None


def _filter_pages_mirror(shadow, page_bytes, block_bytes, page, block, arg,
                         data):
    hits = [p for p in _pages(shadow, page_bytes, page, block)
            if any(_py_match(v, arg)
                   for v in shadow[p * page_bytes:(p + 1) * page_bytes])]
    # the CQ payload carries block_bytes lanes -> first block_bytes indices
    return len(hits), 0, hits[:block_bytes]


def _cas_mirror(shadow, page_bytes, block_bytes, page, block, arg, data):
    off = page * page_bytes + block * block_bytes
    bsum = py_i32(py_fold(shadow[off:off + block_bytes]))
    if bsum == arg:
        shadow[off:off + block_bytes] = data
        return bsum, 0, None
    return bsum, ST_MISMATCH, None


def _verify_mirror(shadow, page_bytes, block_bytes, page, block, arg, data):
    off = page * page_bytes + block * block_bytes
    cur = bytes(shadow[off:off + block_bytes])
    bsum = py_i32(py_fold(cur))
    status = 0 if (arg == 0 or bsum == arg) else ST_MISMATCH
    return bsum, status, cur

# ---------------------------------------------------------------------------
# registration (order defines the SQE fn-lane ids: checksum=0 .. verify=4)
# ---------------------------------------------------------------------------

register_storage_fn("checksum", apply=_checksum_apply,
                    host_ref=_checksum_ref, mirror=_checksum_mirror)
register_storage_fn("scan_count", apply=_scan_count_apply,
                    host_ref=_scan_count_ref, mirror=_scan_count_mirror)
register_storage_fn("filter_pages", apply=_filter_pages_apply,
                    host_ref=_filter_pages_ref, mirror=_filter_pages_mirror)
register_storage_fn("compare_and_write", apply=_cas_apply,
                    host_ref=_cas_ref, mirror=_cas_mirror,
                    writes=True, scope="block")
register_storage_fn("verify_on_read", apply=_verify_apply,
                    host_ref=_verify_ref, mirror=_verify_mirror,
                    scope="block")
