"""The ring step's COMPUTE phase: storage functions executed in-program.

Sits between the data phase and the control tail in ``ring_step_core``
(core/ring.py). The drain policy guarantees compute lanes are contiguous
and never share a batch with control lanes (compute is its own batch rank:
data < compute < control, cut on every rank change), so — exactly like the
``_apply_vol_ops`` control tail — a ``compute_tail``-lane dynamic-slice
window anchored at the first compute lane covers all of them, and a
``lax.scan`` over the window applies submission order with a fixed trace
structure. Each lane is a masked ``lax.switch`` over the registered
storage-function table (registration order = SQE ``fn``-lane id; padding
and non-compute lanes take the noop branch).

The function input is the hole-masked full-volume lane view gathered from
the FIRST healthy replica (replicas are bit-identical by the mirrored-write
invariant, so first-healthy needs no rr fairness; the one-hot ``where``
chain is the vmap-safe selection idiom of ``_rr_gather``). The gather is a
plain XLA take — compute scans the whole volume, and the registry kernels'
paged read path buys nothing for a full-table gather.

Writes (``compare_and_write``): the drain admits at most ONE writing
compute per batch (it closes the compute window), so the commit is a single
batch-shaped mirrored CoW write using the configured registry kernel —
literally the data phase's write machinery with a one-hot mask, which is
what "riding the CoW write path" means here. The scan itself never carries
the pools.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compute import registry as sfns
from repro.core import dbs
from repro.core.fused import _cow_apply
from repro.core.transport import stamp_page_rev


def volume_content(state: dbs.DBSState, pool: jnp.ndarray,
                   vol: jnp.ndarray) -> jnp.ndarray:
    """Hole-masked (P, page_blocks, *S) lane view of one volume: never-written
    and unmapped pages (ext < 0) read as zeros, like OP_READ."""
    n_vols = state.table.shape[0]
    ext = state.table[jnp.clip(vol, 0, n_vols - 1)]          # (P,)
    got = pool[jnp.maximum(ext, 0)]                          # (P, pb, *S)
    mask = (ext >= 0).reshape((-1,) + (1,) * (got.ndim - 1))
    return jnp.where(mask, got, jnp.zeros((), pool.dtype))


def apply_compute_ops(states, pools, page_revs, healthy, batch, mask,
                      value, status, reads, *, kernel: str, tail: int):
    """Apply the batch's compute lanes in lane order. ``mask`` is
    ``ok & (op == OP_COMPUTE)``. Returns updated
    ``(states, pools, page_revs, value, status, reads)``."""
    table = sfns.device_table()
    n_fns = len(table)
    b_n = batch.op.shape[0]
    k = min(tail, b_n)
    start = jnp.clip(jnp.argmax(mask), 0, b_n - k)
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, k)
    vol_w, page_w, blk_w = sl(batch.volume), sl(batch.page), sl(batch.block)
    fn_w, arg_w, pay_w = sl(batch.fn), sl(batch.arg), sl(batch.payload)
    live_w = sl(mask)            # edge-clamped data lanes are masked out

    # first-healthy replica selection (one-hot where chain: vmap-safe)
    h = healthy
    sel = h & (jnp.cumsum(h.astype(jnp.int32)) - 1 == 0)

    def content_of(vol):
        out = jnp.zeros_like(volume_content(states[0], pools[0], vol))
        for r in range(len(states)):
            out = jnp.where(sel[r],
                            volume_content(states[r], pools[r], vol), out)
        return out

    n_vols = states[0].table.shape[0]

    def lane(carry, xs):
        vol, page, blk, fid, arg, pay, live = xs
        live = live & (vol >= 0) & (vol < n_vols)
        content = content_of(vol)
        branch = jnp.where(live, jnp.clip(fid, 0, n_fns - 1) + 1, 0)

        def b_noop(_):
            return (jnp.int32(-1), jnp.int32(0), jnp.zeros_like(pay),
                    jnp.asarray(False))

        def b_fn(entry):
            def b(_):
                v, st, out, dw = entry.apply(content, page, blk, arg, pay)
                return (v.astype(jnp.int32), st.astype(jnp.int32),
                        out.astype(pay.dtype), jnp.asarray(dw))
            return b

        v, st, out, dw = jax.lax.switch(
            branch, [b_noop] + [b_fn(e) for e in table], None)
        return carry, (v, st, out, dw & live)

    _, (vals, stts, outs, do_ws) = jax.lax.scan(
        lane, None, (vol_w, page_w, blk_w, fn_w, arg_w, pay_w, live_w))

    value = jax.lax.dynamic_update_slice_in_dim(
        value, jnp.where(live_w, vals, sl(value)), start, axis=0)
    status = jax.lax.dynamic_update_slice_in_dim(
        status, jnp.where(live_w, stts, sl(status)), start, axis=0)
    live_b = live_w.reshape((-1,) + (1,) * (outs.ndim - 1))
    reads = jax.lax.dynamic_update_slice_in_dim(
        reads, jnp.where(live_b, outs, sl(reads)), start, axis=0)

    if any(e.writes for e in table):
        # single CAS commit (at most one do_write lane per batch): scatter
        # the window's one-hot write mask back to batch shape and run the
        # data phase's mirrored CoW write against it
        first_w = do_ws & (jnp.cumsum(do_ws.astype(jnp.int32)) == 1)
        wmask = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((b_n,), bool), first_w, start, axis=0)
        bits = jnp.uint32(1) << batch.block.astype(jnp.uint32)
        out_states, out_pools, out_prs = [], [], []
        for i, st in enumerate(states):
            st, wops = dbs.write_pages(st, batch.volume, batch.page, bits,
                                       wmask & healthy[i])
            out_pools.append(_cow_apply(pools[i], wops, batch.payload,
                                        batch.block, kernel))
            out_prs.append(stamp_page_rev(page_revs[i], batch.volume,
                                          batch.page, wops.ok, st.revision))
            out_states.append(st)
        states, pools, page_revs = (tuple(out_states), tuple(out_pools),
                                    tuple(out_prs))

    return states, pools, page_revs, value, status, reads
