"""The storage-function registry: named in-band compute offloads.

Mirrors the backend (``core/backends.py``), transport (``core/transport.py``)
and kernel (``kernels/dbs/registry.py``) registries: a name resolves to a
:class:`StorageFn` record, ``available_storage_fns()`` lists what is known,
unknown lookups and duplicate registrations raise the same uniform
``ValueError`` shape as the other three registries.

A storage function is a small *vmap-safe* jnp program executed inside the
fused step against the extent pool — the computational-storage analogue of
the paper's in-band control ops (BPF-for-storage, PAPERS.md): instead of
reading every page across the host boundary and computing there, one COMPUTE
SQE carries the function id + immediate argument down, the engine runs the
function against the device-resident bytes, and the CQ value/payload lanes
carry the (scalar, block-sized) result back up.

Each entry has three synchronized implementations:

``apply``     the device program: vmap-safe, traced into the ring step's
              compute phase (and into the eager per-call executor for the
              fused/sharded backends).
``host_ref``  a pure-jnp *sequential* reference (``lax.fori_loop`` style,
              no data-parallel folds) — the host-oracle backend runs this,
              and bit-identity device-vs-host is the acceptance gate.
``mirror``    a pure-Python function over the harness byte oracle's
              ``bytearray`` shadow — what the chaos harness and the
              hypothesis property suite check every result against.

``apply`` / ``host_ref`` share one signature::

    fn(content, page, block, arg, payload)
        -> (value i32, status i32, out (*S,) f32, do_write bool)

where ``content`` is the hole-masked ``(P, page_blocks, *S)`` float32 lane
view of one volume (holes read as zeros, exactly like OP_READ), ``page`` /
``block`` are the SQE address lanes (for ``scope="range"`` functions,
``page`` is the first page and ``block`` the page *count*; for
``scope="block"`` functions they address one block), ``arg`` is the int32
immediate and ``payload`` the SQE payload lanes. A function with
``writes=True`` may return ``do_write=True`` to commit ``payload`` to the
addressed block through the normal CoW write path (compare-and-write).

``mirror`` has signature ``mirror(shadow, page_bytes, block_bytes, page,
block, arg, data) -> (value, status, aux)`` and mutates ``shadow`` in place
when the device function would commit a write.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# Protocol constant, mirrored from core/ring.py (which imports this package;
# the compute package never imports ring): positive CQ status meaning "the
# function ran but its predicate did not hold" (CAS expectation miss,
# verify_on_read checksum mismatch). Unlike the negative ST_ERR family this
# is NOT an I/O error — IOFuture.result() only raises on status < 0.
ST_MISMATCH = 1

_SCOPES = ("range", "block")


@dataclass(frozen=True)
class StorageFn:
    """One registered storage function (see module docstring for contracts)."""
    name: str
    apply: Callable        # device program, vmap-safe
    host_ref: Callable     # pure-jnp sequential reference (host oracle)
    mirror: Callable       # pure-Python bytearray-shadow reference
    writes: bool = False   # may commit a CoW write (closes the compute window)
    scope: str = "range"   # "range": (page, count) span; "block": one block


_REGISTRY: Dict[str, StorageFn] = {}
_VERSION: int = 0  # bumped on every (re)registration — keys compiled programs


def available_storage_fns() -> Tuple[str, ...]:
    """Registered storage-function names, in registration (= fn id) order."""
    return tuple(_REGISTRY)


def _known() -> str:
    return ", ".join(available_storage_fns()) or "<none>"


def register_storage_fn(name: str, *, apply: Callable,
                        host_ref: Optional[Callable] = None,
                        mirror: Optional[Callable] = None,
                        writes: bool = False, scope: str = "range",
                        override: bool = False) -> StorageFn:
    """Register ``name``. ``host_ref`` defaults to ``apply`` (fine when the
    device program is already sequential-order-insensitive); ``mirror``
    defaults to None (harness/property checking then skips the function).
    Duplicate names raise unless ``override=True`` — same contract as the
    backend/transport/kernel registries."""
    global _VERSION
    if scope not in _SCOPES:
        raise ValueError(f"storage fn scope must be one of {_SCOPES}, "
                         f"got {scope!r}")
    if name in _REGISTRY and not override:
        raise ValueError(f"duplicate storage function {name!r} (registered: "
                         f"{_known()}); pass override=True to replace")
    entry = StorageFn(name=name, apply=apply,
                      host_ref=host_ref if host_ref is not None else apply,
                      mirror=mirror, writes=writes, scope=scope)
    _REGISTRY[name] = entry
    _VERSION += 1
    return entry


def make_storage_fn(name: str) -> StorageFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown storage function {name!r} "
                         f"(registered: {_known()})") from None


def storage_fn_id(name: str) -> int:
    """Stable small-int id staged into the SQE ``fn`` lane."""
    make_storage_fn(name)  # uniform unknown-name error
    return list(_REGISTRY).index(name)


def fn_writes(fnid: int) -> bool:
    """Whether the function behind ``fnid`` may commit a write (drain-time
    batching rule: a writing compute closes the batch's compute window)."""
    fns = list(_REGISTRY.values())
    return fns[fnid].writes if 0 <= fnid < len(fns) else False


def device_table() -> Tuple[StorageFn, ...]:
    """Registration-ordered entries — the ``lax.switch`` branch table the
    ring step's compute phase is traced against."""
    return tuple(_REGISTRY.values())


def registry_version() -> int:
    """Monotonic registration counter. Compiled ring programs bake the
    branch table in, so engines key their program cache on this and retrace
    when a storage function is (re)registered after first compile."""
    return _VERSION
