"""Per-call storage-function executors for the non-ring backends.

The ring backend runs storage functions *in-band* (a COMPUTE SQE through
``phase.apply_compute_ops`` inside the jitted ring step). The other
backends get the same results through two eager paths:

- **host oracle** (``backend="host"``): ``host_compute`` runs the entry's
  *sequential* ``host_ref`` against the backend's state/pool — the
  bit-exact reference every other backend is gated against. The host
  backend executes it from its FIFO queue (core/backends.py pump), so
  ordering semantics match the ring exactly.
- **device backends** (fused / sharded / slots / loop): ``device_compute``
  flushes nothing itself (callers flush), slices the replica plane out of
  the engine's storage group, and runs one jitted program — the entry's
  device ``apply`` on the first healthy replica's hole-masked volume view,
  plus the mirrored CoW commit for writing functions (compare_and_write)
  through the configured registry kernel.

Both return host scalars; the blockdev layer wraps them in ComputeResult.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compute.phase import volume_content
from repro.compute.registry import make_storage_fn
from repro.core import dbs
from repro.core.transport import stamp_page_rev
from repro.kernels.dbs.registry import make_kernel


def host_compute(state, pool, req, payload_shape):
    """Run ``req`` (a compute Request) sequentially against the host
    backend's single-replica plane. Returns
    ``(value, status, out, state', pool')``."""
    entry = make_storage_fn(req.fn)
    pay = (jnp.asarray(req.payload, jnp.float32).reshape(payload_shape)
           if req.payload is not None
           else jnp.zeros(tuple(payload_shape), jnp.float32))
    content = volume_content(state, pool, jnp.int32(req.volume))
    val, stt, out, do_w = entry.host_ref(content, jnp.int32(req.page),
                                         jnp.int32(req.block),
                                         jnp.int32(req.arg), pay)
    if bool(do_w):
        state, wops = dbs.write_pages(
            state, jnp.int32(req.volume), jnp.asarray([req.page], jnp.int32),
            jnp.asarray([jnp.uint32(1) << req.block], jnp.uint32),
            jnp.asarray([True]))
        pool = dbs.apply_write_ops(pool, wops, pay[None],
                                   jnp.asarray([req.block], jnp.int32))
    return int(val), int(stt), np.asarray(out), state, pool


@partial(jax.jit, static_argnames=("fn_name", "kernel"))
def _exec_replicated(states, pools, page_revs, vol, page, block, arg,
                     payload, *, fn_name: str, kernel: str):
    """One storage-function call against a healthy replica tuple: apply on
    the first replica's volume view, mirrored CoW commit on all of them."""
    entry = make_storage_fn(fn_name)
    content = volume_content(states[0], pools[0], vol)
    val, stt, out, do_w = entry.apply(content, page, block, arg, payload)
    vol1, page1 = vol[None], page[None]
    bits1 = (jnp.uint32(1) << jnp.clip(block, 0, 31).astype(jnp.uint32))[None]
    wmask = do_w[None]
    kern = make_kernel(kernel)
    n_states, n_pools, n_prs = [], [], []
    for st, pool, pr in zip(states, pools, page_revs):
        st2, wops = dbs.write_pages(st, vol1, page1, bits1, wmask)
        n_pools.append(kern.write(pool, wops, payload[None], block[None]))
        n_prs.append(stamp_page_rev(pr, vol1, page1, wops.ok, st2.revision))
        n_states.append(st2)
    return (val.astype(jnp.int32), stt.astype(jnp.int32), out,
            tuple(n_states), tuple(n_pools), tuple(n_prs))


def device_compute(engine, vid: int, fn_name: str, page: int, block: int,
                   arg: int, payload) -> Tuple[int, int, np.ndarray]:
    """Execute one storage-function call against a flushed device backend
    (fused / sharded / slots / loop). ``vid`` is the global volume id."""
    cfg = engine.cfg
    if cfg.null_backend or cfg.null_storage:
        raise ValueError("storage functions need a real DBS data plane "
                         "(null_backend/null_storage hold no bytes)")
    storage = getattr(engine, "backend", None)
    if storage is None or not hasattr(storage, "device_state"):
        raise ValueError(
            f"backend comm={cfg.comm!r} storage={cfg.storage!r} cannot "
            "execute storage functions (no DBS replica plane)")
    entry = make_storage_fn(fn_name)
    kernel = getattr(engine, "_kernel", None) or "xla"
    pay = (jnp.asarray(payload, jnp.float32).reshape(cfg.payload_shape)
           if payload is not None
           else jnp.zeros(tuple(cfg.payload_shape), jnp.float32))

    if hasattr(storage, "states"):               # ShardedReplicaGroup
        n_sh = storage.n_shards
        shard, local = vid % n_sh, vid // n_sh
        states, pools, _h = storage.device_state()
        prs = storage.device_page_revs()
        hrow = np.asarray(storage.healthy[shard])
        hidx = [r for r in range(storage.n_replicas) if hrow[r]]
        if not hidx:
            raise RuntimeError(f"shard {shard} has no healthy replica")
        take = lambda t: jax.tree.map(lambda x: x[shard], t)
        val, stt, out, st2, pool2, pr2 = _exec_replicated(
            tuple(take(states[r]) for r in hidx),
            tuple(pools[r][shard] for r in hidx),
            tuple(prs[r][shard] for r in hidx),
            jnp.int32(local), jnp.int32(page), jnp.int32(block),
            jnp.int32(arg), pay, fn_name=fn_name, kernel=kernel)
        if entry.writes:
            states, pools, prs = list(states), list(pools), list(prs)
            for j, r in enumerate(hidx):
                states[r] = jax.tree.map(
                    lambda full, new: full.at[shard].set(new),
                    states[r], st2[j])
                pools[r] = pools[r].at[shard].set(pool2[j])
                prs[r] = prs[r].at[shard].set(pr2[j])
            storage.set_device_state(tuple(states), tuple(pools))
            storage.set_device_page_revs(tuple(prs))
    else:                                        # ReplicaGroup
        states, pools = storage.device_state()   # healthy replicas only
        if not states:
            raise RuntimeError("no healthy replica to compute against")
        prs = storage.device_page_revs()
        val, stt, out, st2, pool2, pr2 = _exec_replicated(
            states, pools, prs, jnp.int32(vid), jnp.int32(page),
            jnp.int32(block), jnp.int32(arg), pay,
            fn_name=fn_name, kernel=kernel)
        if entry.writes:
            storage.set_device_state(st2, pool2)
            storage.set_device_page_revs(pr2)
    v, s, o = jax.device_get((val, stt, out))
    return int(v), int(s), np.asarray(o)
