import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Elastic-restart demonstration: train on one mesh, lose nodes, resume on a
smaller mesh from the same replicated DBS checkpoint.

Run:  python -m repro.launch.elastic
(sets 8 placeholder devices; meshes (4,2) -> (2,2) simulate losing half the
data-parallel width.)
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import ReplicatedCheckpoint
from repro.configs import ExecutionPlan, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.distributed.planner import Planner
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.training.train_step import make_train_step


def run_steps(mesh, cfg, plan, params, opt_state, data_iter, n):
    planner = Planner(mesh, cfg, plan)
    shard = lambda tree: jax.device_put(
        tree, planner.shardings(tree))
    params = shard(params)
    _, step = make_train_step(cfg, plan, total_steps=100, warmup=2)
    jstep = jax.jit(step, donate_argnums=(0, 1))
    loss = None
    for _ in range(n):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = jstep(params, opt_state, batch)
        loss = float(m["loss"])
    return params, opt_state, loss


def main():
    cfg = smoke_config("granite-3-8b")
    plan = ExecutionPlan(remat="none", compute_dtype="float32")
    dirs = ["/tmp/elastic/a", "/tmp/elastic/b"]
    for d in dirs:
        shutil.rmtree(d, ignore_errors=True)
        os.makedirs(d)
    data = iter(SyntheticLM(cfg.vocab_size, 8, 16))

    mesh1 = make_mesh((4, 2), ("data", "model"))
    print(f"phase 1: mesh {dict(mesh1.shape)}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.training.optimizer import make_optimizer
    opt_init, _ = make_optimizer("adamw", total_steps=100, warmup=2)
    opt = opt_init(params)
    params, opt, loss1 = run_steps(mesh1, cfg, plan, params, opt, data, 4)
    print(f"  loss after 4 steps: {loss1:.4f}")
    ck = ReplicatedCheckpoint(dirs, capacity_bytes=1 << 26)
    ck.save("train", 4, {"params": params, "opt": opt})
    ck.close()
    print("  checkpointed to 2 replicas")

    # "half the data-parallel hosts died": resume on a (2,2) mesh
    mesh2 = make_mesh((2, 2), ("data", "model"))
    print(f"phase 2: mesh {dict(mesh2.shape)} (elastic restart)")
    ck2 = ReplicatedCheckpoint(dirs, capacity_bytes=1 << 26)
    like = {"params": jax.device_get(params), "opt": jax.device_get(opt)}
    step, blob = ck2.restore("train", like=like)
    planner2 = Planner(mesh2, cfg, plan)
    params2 = jax.device_put(blob["params"],
                             planner2.shardings(blob["params"]))
    params2, opt2, loss2 = run_steps(mesh2, cfg, plan, params2, blob["opt"],
                                     data, 4)
    print(f"  resumed at step {step}, loss after 4 more: {loss2:.4f}")
    assert loss2 < loss1 + 0.2
    ck2.close()
    print("elastic restart OK")


if __name__ == "__main__":
    main()
