"""Production mesh builders. Importing this module never touches jax device
state — meshes are built inside functions only."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Elastic variant: any (data, model) / (pod, data, model) factorization
    of the currently visible devices (used by tests and elastic restarts)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
