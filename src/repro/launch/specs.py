"""Cell builders: (arch x shape x mesh) -> step fn + fully-sharded input
ShapeDtypeStructs. Shared by the dry-run launcher, tests and benchmarks.

No device allocation happens here — everything is eval_shape + NamedSharding
attached to ShapeDtypeStructs (the "weak-type-correct, shardable stand-in"
pattern from the brief).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, ExecutionPlan, ShapeSpec,
                                default_plan)
from repro.distributed.collectives import make_sharded_paged_decode
from repro.distributed.planner import Planner, batch_axes, pool_stride
from repro.models import (decode_step, init_cache, init_params, prefill)
from repro.training.train_step import make_train_step


def _sds(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        shapes_tree, shardings_tree)


def _cast_float(shapes_tree, dtype):
    def f(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, jnp.dtype(dtype))
        return s
    return jax.tree.map(f, shapes_tree)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class Cell:
    name: str
    step: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs (sharded)
    donate: Tuple[int, ...]
    tokens_per_step: int           # for MODEL_FLOPS accounting
    kind: str                      # train | prefill | decode
    plan: ExecutionPlan


def token_shape(cfg: ArchConfig, batch: int, seq: int) -> Tuple[int, ...]:
    return (batch, seq, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, seq)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               plan: Optional[ExecutionPlan] = None) -> Cell:
    n_chips = math.prod(mesh.shape.values())
    n_batch_shards = math.prod(mesh.shape[a] for a in batch_axes(mesh))
    plan = plan or default_plan(cfg, shape, n_chips,
                                data_shards=n_batch_shards)
    if plan.moe_pad_to and cfg.moe is not None:
        pad = math.ceil(cfg.moe.n_experts / plan.moe_pad_to) * plan.moe_pad_to
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts_padded=pad))
    planner = Planner(mesh, cfg, plan)

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda k: init_params(k, cfg), key)
    if plan.unstack_params and shape.kind != "train":
        from repro.models.model import unstack_params
        params_shapes = jax.eval_shape(
            lambda p: unstack_params(p, cfg), params_shapes)
    params_shapes = _cast_float(params_shapes, plan.param_dtype)
    param_specs = planner.tree_specs(params_shapes)
    params_sds = _sds(params_shapes, _ns(mesh, param_specs))

    gb, seq = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        from repro.training.optimizer import make_optimizer
        opt_init, _ = make_optimizer(plan.optimizer)
        opt_shapes = jax.eval_shape(opt_init, params_shapes)
        opt_specs = planner.opt_specs(param_specs, params_shapes,
                                      plan.optimizer)
        opt_sds = _sds(opt_shapes, _ns(mesh, opt_specs))
        tshape = token_shape(cfg, gb, seq)
        bspec = planner.data_spec(tshape)
        tok = jax.ShapeDtypeStruct(tshape, jnp.int32,
                                   sharding=NamedSharding(mesh, bspec))
        batch = {"tokens": tok, "labels": tok}
        _, step = make_train_step(cfg, plan)
        return Cell(name=f"{cfg.name}:{shape.name}", step=step,
                    args=(params_sds, opt_sds, batch), donate=(0, 1),
                    tokens_per_step=gb * seq, kind="train", plan=plan)

    if shape.kind == "prefill":
        caches_shapes = jax.eval_shape(
            lambda: init_cache(cfg, gb, seq, paged=False,
                               dtype=jnp.dtype(plan.compute_dtype)))
        cache_specs = planner.cache_specs(caches_shapes)
        caches_sds = _sds(caches_shapes, _ns(mesh, cache_specs))
        tshape = token_shape(cfg, gb, seq)
        tok = jax.ShapeDtypeStruct(
            tshape, jnp.int32,
            sharding=NamedSharding(mesh, planner.data_spec(tshape)))

        def step(params, tokens, caches):
            return prefill(params, tokens, cfg, plan, caches)

        return Cell(name=f"{cfg.name}:{shape.name}", step=step,
                    args=(params_sds, tok, caches_sds), donate=(2,),
                    tokens_per_step=gb * seq, kind="prefill", plan=plan)

    # ---- decode ------------------------------------------------------------
    baxes = batch_axes(mesh)
    bsize = math.prod(mesh.shape[a] for a in baxes)
    batch_shardable = gb % bsize == 0 and gb >= bsize
    stride = pool_stride(mesh, batch_shardable)
    caches_shapes = jax.eval_shape(
        lambda: init_cache(cfg, gb, seq, paged=True,
                           dtype=jnp.dtype(plan.compute_dtype),
                           page_owner_stride=stride))
    cache_specs = planner.cache_specs(caches_shapes)
    caches_sds = _sds(caches_shapes, _ns(mesh, cache_specs))
    bspec = NamedSharding(mesh, P(baxes) if batch_shardable else P())
    tshape = (gb, cfg.n_codebooks) if cfg.n_codebooks > 1 else (gb,)
    tok = jax.ShapeDtypeStruct(tshape, jnp.int32, sharding=bspec)
    pos = jax.ShapeDtypeStruct((gb,), jnp.int32, sharding=bspec)
    paged_fn = make_sharded_paged_decode(
        mesh, batch_shardable, stripe_slice=plan.paged_stripe_slice)

    def step(params, tokens, positions, caches):
        return decode_step(params, tokens, positions, cfg, plan, caches,
                           paged_decode_fn=paged_fn)

    return Cell(name=f"{cfg.name}:{shape.name}", step=step,
                args=(params_sds, tok, pos, caches_sds), donate=(3,),
                tokens_per_step=gb, kind="decode", plan=plan)


# ---------------------------------------------------------------------------
# memory accounting (analytic, backend-independent)
# ---------------------------------------------------------------------------
def per_device_bytes(mesh: Mesh, sds_tree) -> float:
    n_dev = math.prod(mesh.shape.values())

    def one(s):
        if not hasattr(s, "sharding") or s.sharding is None:
            return s.size * s.dtype.itemsize
        spec = s.sharding.spec
        shards = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        return s.size * s.dtype.itemsize / shards

    return sum(one(s) for s in jax.tree.leaves(sds_tree))
