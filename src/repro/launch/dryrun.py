import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the production
mesh is built from 512 placeholder CPU devices, every cell's step function is
lowered with fully-sharded ShapeDtypeStructs, compiled by the SPMD
partitioner, and the compiled artifact is mined for the roofline terms
(FLOPs / bytes from cost_analysis, collective operand bytes from the
post-SPMD HLO). Results land in a JSON consumed by benchmarks/roofline.py
and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.json
"""
import argparse
import json
import math
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, per_device_bytes
from repro.utils import hlo as hlo_utils

from repro.utils.machine import machine_profile

# machine peaks: detected-or-overridable (utils/machine.py); the v5e
# assignment-brief numbers remain the fallback
_PROFILE = None


def _peaks():
    global _PROFILE
    if _PROFILE is None:
        _PROFILE = machine_profile()
    return _PROFILE


ACCOUNTING_OVERRIDES = dict(scan_layers=False, microbatches=1,
                            unroll_scans=True)


def accounting_variants(cfg):
    """Reduced-depth variants + a linear combiner for exact-by-extrapolation
    accounting of train/prefill cells (per-layer costs are depth-invariant;
    XLA:CPU cost_analysis cannot see scan trip counts, and fully unrolling
    40-62 layers is too slow on one core — so we compile 2-3 shallow
    *unrolled* variants and extrapolate).
    """
    import dataclasses as dc
    from repro.models.blocks import layer_schedule
    name = cfg.name
    if name.startswith("hymba"):
        v = [dc.replace(cfg, n_layers=4, global_layer_indices=(0,)),
             dc.replace(cfg, n_layers=6, global_layer_indices=(0,)),
             dc.replace(cfg, n_layers=4, global_layer_indices=(0, 1))]
        n_global = len(cfg.global_layer_indices)
        n_swa = cfg.n_layers - n_global

        def combine(m4, m6, m4g2):
            per_swa = (m6 - m4) / 2.0
            d_global = m4g2 - m4
            return m4 + per_swa * (n_swa - 3) + d_global * (n_global - 1)
        return v, combine
    if cfg.moe is not None and cfg.n_dense_layers:      # deepseek: 3 dense + N moe
        v = [dc.replace(cfg, n_layers=cfg.n_dense_layers + 1),
             dc.replace(cfg, n_layers=cfg.n_dense_layers + 2)]
        n_moe = cfg.n_layers - cfg.n_dense_layers

        def combine(m1, m2):
            return m1 + (m2 - m1) * (n_moe - 1)
        return v, combine
    unit = len(cfg.layer_pattern)
    reps, tail = divmod(cfg.n_layers, unit)
    v = [dc.replace(cfg, n_layers=unit), dc.replace(cfg, n_layers=2 * unit)]

    def combine(m1, m2):
        per_unit = m2 - m1
        return m1 + per_unit * (reps - 1) + per_unit * (tail / unit)
    return v, combine


def _measure(cfg, shape, mesh, plan_overrides):
    """Lower+compile one variant; return raw metrics."""
    import contextlib
    import dataclasses as _dc
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    if plan_overrides:
        cell = build_cell(cfg, shape, mesh,
                          plan=_dc.replace(cell.plan, **plan_overrides))
    ctx = contextlib.nullcontext()
    if cell.plan.constrain_activations:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.planner import batch_axes
        from repro.distributed import runtime
        ctx = runtime.activation_sharding(
            NamedSharding(mesh, P(batch_axes(mesh))))
    jitted = jax.jit(cell.step, donate_argnums=cell.donate)
    with ctx:
        lowered = jitted.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    coll = hlo_utils.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "coll_bytes": sum(v["bytes"] for v in coll.values()),
        "mem": compiled.memory_analysis(),
    }, cell, t_lower, t_compile


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan_overrides=None, accounting: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())

    if accounting:
        # fully-unrolled lowering: no while loops, so cost_analysis sees every
        # op execution (XLA:CPU does not multiply scan bodies by trip count)
        plan_overrides = {**ACCOUNTING_OVERRIDES, **(plan_overrides or {})}

    t_lower = t_compile = 0.0
    if accounting and shape.kind in ("train", "prefill") and not \
            (plan_overrides or {}).get("no_extrapolate"):
        # depth extrapolation: 2-3 shallow unrolled compiles, combined
        variants, combine = accounting_variants(cfg)
        measures = []
        cell = None
        for vcfg in variants:
            m, cell, tl, tc = _measure(vcfg, shape, mesh, plan_overrides)
            measures.append(m)
            t_lower += tl
            t_compile += tc
        flops = float(combine(*[m["flops"] for m in measures]))
        bytes_acc = float(combine(*[m["bytes"] for m in measures]))
        coll_bytes = float(combine(*[m["coll_bytes"] for m in measures]))
        kinds = set().union(*[m["coll"].keys() for m in measures])
        coll = {k: {f: float(combine(*[m["coll"].get(k, {}).get(f, 0.0)
                                       for m in measures]))
                    for f in ("count", "bytes")} for k in kinds}
        mem = None
    else:
        po = dict(plan_overrides or {})
        po.pop("no_extrapolate", None)
        m, cell, t_lower, t_compile = _measure(cfg, shape, mesh, po)
        flops, bytes_acc = m["flops"], m["bytes"]
        coll, coll_bytes = m["coll"], m["coll_bytes"]
        mem = m["mem"]

    from repro.configs.base import model_flops
    toks = cell.tokens_per_step
    useful = model_flops(cfg, toks) if cell.kind == "train" else \
        2.0 * cfg.active_param_count() * toks

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "kind": cell.kind,
        "plan": {k: getattr(cell.plan, k) for k in
                 ("microbatches", "remat", "optimizer", "fsdp", "param_dtype",
                  "logits_chunk", "attn_impl")},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # per-device program costs (SPMD: one device's share)
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "model_flops_total": useful,
        "hlo_useful_ratio": useful / max(flops * n_chips, 1.0),
        # roofline terms (seconds)
        "t_compute": flops / _peaks().peak_flops,
        "t_memory": bytes_acc / _peaks().hbm_bw,
        "t_collective": coll_bytes / _peaks().link_bw,
        "analytic_state_bytes_per_device": per_device_bytes(mesh, cell.args),
    }
    terms = {"compute": out["t_compute"], "memory": out["t_memory"],
             "collective": out["t_collective"]}
    out["bottleneck"] = max(terms, key=terms.get)
    out["roofline_fraction"] = out["t_compute"] / max(sum(terms.values()), 1e-30)
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            out["mem_" + attr] = getattr(mem, attr, None)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accounting", action="store_true",
                    help="fully-unrolled lowering for exact cost_analysis")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}:{shape}:{'multi' if mp else 'single'}"
                try:
                    r = run_cell(arch, shape, mp, accounting=args.accounting)
                    r["status"] = "skipped" if "skipped" in r else "ok"
                except Exception as e:  # noqa: BLE001 — record and continue
                    r = {"arch": arch, "shape": shape, "multi_pod": mp,
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc(limit=6)}
                r["multi_pod"] = mp
                results.append(r)
                if r["status"] == "ok":
                    print(f"OK    {tag:54s} compile={r['compile_s']:7.1f}s "
                          f"bottleneck={r['bottleneck']:10s} "
                          f"roofline={r['roofline_fraction']:.3f}", flush=True)
                elif r["status"] == "skipped":
                    print(f"SKIP  {tag:54s} {r['skipped'][:60]}", flush=True)
                else:
                    print(f"ERROR {tag:54s} {r['error'][:90]}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_err = sum(r["status"] == "error" for r in results)
    print(f"cells: {len(results)}  errors: {n_err}")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
