"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, smoke_config
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--queues", type=int, default=2)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.attention_free:
        print(f"note: {cfg.name} is attention-free; the paged-DBS path is "
              "inapplicable (DESIGN.md §Arch-applicability) — serving uses "
              "its O(1) recurrent state.")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=args.slots, max_len=128,
                      n_queues=args.queues)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(8,) if cfg.n_codebooks == 1
                              else (8, cfg.n_codebooks))
        eng.submit(GenRequest(req_id=rid, prompt=prompt,
                              max_new=args.max_new))
    outs = eng.run(max_steps=args.requests * args.max_new + 20)
    for rid, toks in sorted(outs.items()):
        print(f"req {rid}: {toks}")


if __name__ == "__main__":
    main()
