"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced (smoke) configs end-to-end; on a real
fleet the same entry point runs the full config (the dry-run proves the
sharded program compiles for the production mesh).
"""
from __future__ import annotations

import argparse
import os

from repro.configs import ALL_ARCHS, ExecutionPlan, get_config, smoke_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    plan = ExecutionPlan(remat="block", compute_dtype="float32",
                         logits_chunk=0)
    dirs = None
    if args.ckpt_dir:
        dirs = [os.path.join(args.ckpt_dir, d) for d in "ab"]
        for d in dirs:
            os.makedirs(d, exist_ok=True)
    data = Prefetcher(SyntheticLM(cfg.vocab_size, args.batch, args.seq,
                                  codebooks=cfg.n_codebooks), depth=2)
    tr = Trainer(cfg, plan, data, ckpt_dirs=dirs, ckpt_every=args.ckpt_every,
                 total_steps=args.steps, warmup=max(2, args.steps // 10))
    hist = tr.run(args.steps)
    print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"({tr.straggler_events} straggler events)")
    if tr.ckpt:
        tr.ckpt.close()
    data.close()


if __name__ == "__main__":
    main()
