"""Loss + train step: chunked cross-entropy, microbatch accumulation, remat.

The chunked CE never materializes the full (B, S, V) logits tensor — it scans
over sequence chunks (checkpointed), which for 256k-vocab archs (gemma2/3) is
the difference between a 17 GB and a ~70 MB logits footprint per microbatch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ExecutionPlan
from repro.models import forward, mtp_hidden
from repro.models.layers import lm_logits
from repro.training.optimizer import make_optimizer

Params = Any
MTP_WEIGHT = 0.1
AUX_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# chunked cross-entropy
# ---------------------------------------------------------------------------
def chunked_cross_entropy(params_embed: Params, h: jnp.ndarray,
                          labels: jnp.ndarray, cfg: ArchConfig,
                          chunk: int = 0) -> jnp.ndarray:
    """h: (B,S,D); labels: (B,S) or (B,S,K). Returns mean NLL over tokens."""
    b, s, _ = h.shape
    if chunk <= 0 or s % chunk or s <= chunk:
        return _ce_block(params_embed, h, labels, cfg)
    n = s // chunk
    hc = h.reshape(b, n, chunk, h.shape[-1]).swapaxes(0, 1)
    lc = (labels.reshape((b, n, chunk) + labels.shape[2:])).swapaxes(0, 1)

    def body(carry, xs):
        hh, ll = xs
        return carry + _ce_block(params_embed, hh, ll, cfg) * (1.0 / n), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (hc, lc))
    return total


def _ce_block(params_embed, h, labels, cfg) -> jnp.ndarray:
    logits = lm_logits(params_embed, h, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            plan: ExecutionPlan) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    tokens, labels = batch["tokens"], batch["labels"]
    h, aux = forward(params, tokens, cfg, plan)
    chunk = plan.logits_chunk
    loss = chunked_cross_entropy(params["embed"], h, labels, cfg, chunk)
    metrics = {"ce": loss}
    if cfg.moe is not None and not cfg.moe.router_aux_free:
        loss = loss + AUX_WEIGHT * aux
        metrics["aux"] = aux
    if cfg.mtp_depth and "mtp" in params:
        h_mtp = mtp_hidden(params, h, tokens, cfg, plan)
        # predict token t+2 from position t (labels already = t+1 shift)
        mtp_loss = chunked_cross_entropy(
            params["embed"], h_mtp[:, :-1], labels[:, 2:], cfg, chunk)
        loss = loss + MTP_WEIGHT * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# train step (with microbatch gradient accumulation)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, plan: ExecutionPlan,
                    optimizer: Optional[str] = None, **opt_overrides
                    ) -> Tuple[Callable, Callable]:
    """Returns (init_opt_state_fn, train_step_fn).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    batch tensors have leading dim = global_batch; with plan.microbatches > 1
    the step scans over microbatch slices accumulating grads (constant
    memory in the number of microbatches).
    """
    opt_name = optimizer or plan.optimizer
    opt_init, opt_update = make_optimizer(opt_name, **opt_overrides)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, plan)
        return grads, metrics

    def train_step(params, opt_state, batch):
        mb = plan.microbatches
        if mb <= 1:
            grads, metrics = grads_of(params, batch)
        else:
            def slice_mb(x, i):
                per = x.shape[0] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * per, per, axis=0)

            def body(carry, i):
                acc = carry
                micro = jax.tree.map(lambda x: slice_mb(x, i), batch)
                g, m = grads_of(params, micro)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            acc, ms = jax.lax.scan(body, zeros, jnp.arange(mb))
            grads = jax.tree.map(lambda g: (g / mb).astype(g.dtype), acc)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        new_params, new_opt, gnorm = opt_update(grads, opt_state, params)
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return opt_init, train_step
