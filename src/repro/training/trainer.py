"""Trainer: the fault-tolerant training loop.

- periodic checkpoints to a ReplicatedCheckpoint (CoW snapshot per save),
- automatic resume from the newest valid replica version on restart
  (crash/preemption recovery),
- elastic restart: restore accepts a different mesh's shardings,
- step-deadline accounting: steps slower than ``deadline_factor`` x the
  running median are logged as straggler events (on a real fleet this is the
  signal to evict/replace a slow host; here it drives the metric surfaced in
  benchmarks and tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import ReplicatedCheckpoint
from repro.configs.base import ArchConfig, ExecutionPlan
from repro.models import init_params
from repro.training.train_step import make_train_step


class Trainer:
    def __init__(self, cfg: ArchConfig, plan: ExecutionPlan, data: Iterator,
                 *, ckpt_dirs: Optional[List[str]] = None,
                 ckpt_every: int = 50, seed: int = 0,
                 deadline_factor: float = 3.0, **opt_overrides):
        self.cfg, self.plan = cfg, plan
        self.data = data
        self.ckpt_every = ckpt_every
        self.deadline_factor = deadline_factor
        opt_init, step = make_train_step(cfg, plan, **opt_overrides)
        self.step_fn = jax.jit(step, donate_argnums=(0, 1))
        self.params = init_params(jax.random.PRNGKey(seed), cfg)
        self.opt_state = opt_init(self.params)
        self.step = 0
        self.ckpt = (ReplicatedCheckpoint(ckpt_dirs, capacity_bytes=1 << 28)
                     if ckpt_dirs else None)
        self.history: List[Dict[str, float]] = []
        self.straggler_events = 0
        self._durations: List[float] = []
        if self.ckpt is not None:
            self._try_resume()

    # ----------------------------------------------------------- checkpoints
    def _try_resume(self):
        try:
            step, blob = self.ckpt.restore(
                "train", {"params": self.params, "opt": self.opt_state})
            self.params, self.opt_state = blob["params"], blob["opt"]
            self.step = step
            print(f"[trainer] resumed from step {step}")
        except Exception:
            pass                                   # fresh start

    def _save(self):
        if self.ckpt is not None:
            self.ckpt.save("train", self.step,
                           {"params": self.params, "opt": self.opt_state})

    # ------------------------------------------------------------------ loop
    def run(self, num_steps: int) -> List[Dict[str, float]]:
        it = iter(self.data)
        target = self.step + num_steps
        while self.step < target:
            batch = next(it)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self._durations.append(dt)
            med = float(np.median(self._durations[-20:]))
            if len(self._durations) > 5 and dt > self.deadline_factor * med:
                self.straggler_events += 1
                metrics["straggler"] = 1.0
            metrics["step_time_s"] = dt
            metrics["step"] = self.step
            self.history.append(metrics)
            self.step += 1
            if self.ckpt_every and self.step % self.ckpt_every == 0:
                self._save()
        self._save()
        return self.history
