"""Optimizers (no external deps): AdamW and Adafactor, schedules, clipping.

Adafactor (factored second moments) is the default for >60B-param configs:
its state is ~1 byte/param instead of AdamW's 8, which is what lets e.g.
deepseek-v3-671b fit the 512-chip mesh (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    max_grad_norm: float = 1.0


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    count = state["count"] + 1
    lr = warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps)(count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_p = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8          # t^-decay second-moment decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup: int = 100
    total_steps: int = 10_000
    max_grad_norm: float = 1.0


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Params) -> Dict[str, Any]:
    def st(x):
        if _factored(x.shape):
            return {"vr": jnp.zeros(x.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(x, jnp.float32)}
    return {"slots": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: AdafactorConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    count = state["count"] + 1
    t = count.astype(jnp.float32)
    beta = 1.0 - t ** (-cfg.decay)
    lr = warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps)(count)

    def upd(g, slot, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps
        if "vr" in slot:
            vr = beta * slot["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * slot["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            new_slot = {"vr": vr, "vc": vc}
        else:
            vhat = beta * slot["v"] + (1 - beta) * g2
            new_slot = {"v": vhat}
        u = g32 * jax.lax.rsqrt(vhat + cfg.eps)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p32
        return new_slot, (p32 - lr * u).astype(p.dtype)

    is_slot = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state["slots"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_slots = treedef.unflatten([o[0] for o in out])
    new_p = treedef.unflatten([o[1] for o in out])
    return new_p, {"slots": new_slots, "count": count}, gnorm


# ---------------------------------------------------------------------------
# uniform facade
# ---------------------------------------------------------------------------
def make_optimizer(name: str, **overrides):
    """Returns (init_fn, update_fn(grads, state, params))."""
    if name == "adamw":
        cfg = AdamWConfig(**overrides)
        return adamw_init, partial(adamw_update, cfg)
    if name == "adafactor":
        cfg = AdafactorConfig(**overrides)
        return adafactor_init, partial(adafactor_update, cfg)
    raise ValueError(f"unknown optimizer {name!r}")
