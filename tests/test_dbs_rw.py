"""The ``dbs_rw`` kernel family + the kernel registry (ISSUE 7).

Four contracts:

1. every REGISTERED kernel's write/read data plane is bit-identical to the
   ``xla`` reference (``apply_write_ops`` + the hole-masked gather) over
   parametrized geometries — multi-block spans, holes/unmapped pages,
   duplicate-dst write groups, failed lanes, scratch-row masking — in
   interpret mode, and under ``vmap`` (the sharded path's form),
2. the registry API mirrors the backend/transport registries
   (register/make/available, ``EngineConfig(kernel=...)`` validation, the
   legacy ``cow`` axis resolution),
3. ``kernel="pallas"`` threads END TO END: byte-oracle equivalence with
   ``kernel="xla"`` through the public ``VolumeManager`` API on the
   fused/sharded/ring backends, and one chaos-harness scenario,
4. ``ops.dbs_copy`` resolves its interpret mode per CALL (the stale
   module-level ``@jax.jit`` capture is fixed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request, dbs
from repro.core.blockdev import VolumeManager
from repro.kernels.dbs import (available_kernels, dbs_rw_read_pool,
                               dbs_rw_write_pool, make_kernel,
                               register_kernel, resolve_kernel_name)
from repro.kernels.dbs.registry import _REGISTRY, DBSKernel

KEY = jax.random.PRNGKey(0)


def _assert_rows_equal(out, ref, *, excl_dump=True):
    e = out.shape[0]
    n = e - 1 if excl_dump else e
    np.testing.assert_array_equal(np.asarray(out[:n]), np.asarray(ref[:n]))


# ---------------------------------------------------------------------------
# 1. bit-equivalence over geometries (every registered kernel vs xla)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e,page,d,b", [
    (16, 4, 8, 8),       # the crafted-lane geometry
    (33, 8, 16, 12),     # odd extent count, wider rows
    (9, 2, 4, 16),       # more lanes than live extents (heavy grouping)
])
@pytest.mark.parametrize("kernel", ["pallas", "ref", "copy"])
def test_write_matches_xla_crafted(kernel, e, page, d, b):
    """Crafted WriteOps with every lane species: CoW, in-place, dup-dst
    groups (leader carries cow_src — the write_pages convention), failed
    (dst=-1) and masked lanes. Row e-1 is the engine's reserved scratch."""
    ks = jax.random.split(KEY, 3)
    pool = jax.random.normal(ks[0], (e, page, d))
    payload = jax.random.normal(ks[1], (b, d))
    lane = jnp.arange(b, dtype=jnp.int32)
    # pair lanes 4k+1 onto lane 4k's dst (duplicate-dst groups)
    dst = jnp.where(lane % 4 == 1, lane - 1, lane) * 3 % (e - 1)
    cow_src = jnp.where(lane % 4 == 0, (dst + 5) % (e - 1), -1)
    cow_src = cow_src.astype(jnp.int32)
    ok = lane % 7 != 6
    dst = jnp.where(lane % 11 == 10, -1, dst).astype(jnp.int32)  # failed
    ops = dbs.WriteOps(dst=dst, cow_src=jnp.where(dst >= 0, cow_src, -1),
                       ok=ok & (dst >= 0))
    blocks = (lane * 5) % page          # multi-block spans within a group
    ref = make_kernel("xla").write(pool, ops, payload, blocks)
    out = make_kernel(kernel).write(pool, ops, payload, blocks)
    _assert_rows_equal(out, ref)
    # scratch-row masking: no masked/failed lane leaked into a live row
    untouched = set(range(e - 1)) - {int(x) for x in np.asarray(dst) if x >= 0}
    for i in untouched:
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(pool[i]))


@pytest.mark.parametrize("kernel", ["pallas", "ref", "copy"])
def test_write_matches_xla_on_write_pages_ops(kernel):
    """Ops produced by the real control plane, CoW pressure included."""
    st = dbs.make_state(64, 2, 16)
    st, vol = dbs.create_volume(st)
    pool = jax.random.normal(KEY, (65, 8, 4))   # +1 scratch row
    pages = jnp.arange(8) % 5                    # duplicate pages -> groups
    bits = jnp.full((8,), 1, jnp.uint32)
    st, ops = dbs.write_pages(st, vol, pages, bits)
    payload = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    blocks = jnp.arange(8, dtype=jnp.int32) % 8
    pool = dbs.apply_write_ops(pool, ops, payload, blocks)
    st, _ = dbs.snapshot(st, vol)
    mask = jnp.arange(8) % 2 == 0               # masked lanes ride along
    st, ops = dbs.write_pages(st, vol, pages, bits, mask)
    assert bool(jnp.any(ops.cow_src >= 0)), "expected CoW lanes"
    payload2 = jax.random.normal(jax.random.PRNGKey(3), (8, 4))
    ref = make_kernel("xla").write(pool, ops, payload2, blocks)
    out = make_kernel(kernel).write(pool, ops, payload2, blocks)
    _assert_rows_equal(out, ref)


@pytest.mark.parametrize("e,page,d,b", [(16, 4, 8, 8), (33, 8, 16, 20)])
@pytest.mark.parametrize("kernel", ["pallas", "ref", "copy"])
def test_read_matches_xla_with_holes(kernel, e, page, d, b):
    """Hole lanes (ext < 0 — never-written or unmapped pages) must read as
    zeros, not as clamped extent 0's payload."""
    pool = jax.random.normal(KEY, (e, page, d))
    lane = jnp.arange(b, dtype=jnp.int32)
    ext = jnp.where(lane % 3 == 0, -1, (lane * 7) % e).astype(jnp.int32)
    blocks = (lane * 3) % page
    ref = make_kernel("xla").read(pool, ext, blocks)
    out = make_kernel(kernel).read(pool, ext, blocks)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert not np.asarray(out[0]).any()          # ext=-1 lane is zeros


def test_rw_pool_wrappers_multidim_payload():
    """The pool wrappers flatten/restore trailing payload dims."""
    e, page, shape, b = 10, 4, (2, 3), 6
    pool = jax.random.normal(KEY, (e, page) + shape)
    payload = jax.random.normal(jax.random.PRNGKey(1), (b,) + shape)
    lane = jnp.arange(b, dtype=jnp.int32)
    ops = dbs.WriteOps(dst=lane, cow_src=jnp.full((b,), -1, jnp.int32),
                       ok=jnp.ones((b,), bool))
    blocks = lane % page
    out = dbs_rw_write_pool(pool, ops, payload, blocks)
    ref = make_kernel("xla").write(pool, ops, payload, blocks)
    _assert_rows_equal(out, ref)
    ext = jnp.asarray([0, -1, 2, 5, -1, 3], jnp.int32)
    got = dbs_rw_read_pool(pool, ext, blocks)
    assert got.shape == (b,) + shape
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(make_kernel("xla").read(
                                      pool, ext, blocks)))


def test_write_and_read_vmap_safe():
    """The sharded path vmaps the step over a leading shard axis — kernels
    must produce per-shard results identical to the unmapped calls."""
    e, page, d, b, s = 12, 4, 8, 6, 3
    pools = jax.random.normal(KEY, (s, e, page, d))
    payloads = jax.random.normal(jax.random.PRNGKey(1), (s, b, d))
    lane = jnp.arange(b, dtype=jnp.int32)
    ops = dbs.WriteOps(dst=(lane * 2) % (e - 1),
                       cow_src=jnp.where(lane % 2 == 0, (lane + 3) % (e - 1),
                                         -1).astype(jnp.int32),
                       ok=lane % 5 != 4)
    blocks = lane % page
    ext = jnp.where(lane % 3 == 0, -1, lane).astype(jnp.int32)
    kern = make_kernel("pallas")
    vw = jax.vmap(lambda p, pay: kern.write(p, ops, pay, blocks))
    vr = jax.vmap(lambda p: kern.read(p, ext, blocks))
    w, r = vw(pools, payloads), vr(pools)
    for i in range(s):
        _assert_rows_equal(w[i], kern.write(pools[i], ops, payloads[i],
                                            blocks), excl_dump=False)
        np.testing.assert_array_equal(np.asarray(r[i]),
                                      np.asarray(kern.read(pools[i], ext,
                                                           blocks)))


# ---------------------------------------------------------------------------
# hypothesis property test (self-skips where hypothesis isn't installed)
# ---------------------------------------------------------------------------
def test_write_read_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st_

    E, PAGE, D, B = 12, 4, 8, 10

    @settings(max_examples=25, deadline=None)
    @given(data=st_.data())
    def prop(data):
        dst = jnp.asarray(data.draw(st_.lists(
            st_.integers(-1, E - 2), min_size=B, max_size=B)), jnp.int32)
        ok = jnp.asarray(data.draw(st_.lists(
            st_.booleans(), min_size=B, max_size=B)))
        cow = jnp.asarray(data.draw(st_.lists(
            st_.integers(-1, E - 2), min_size=B, max_size=B)), jnp.int32)
        blocks = jnp.asarray(data.draw(st_.lists(
            st_.integers(0, PAGE - 1), min_size=B, max_size=B)), jnp.int32)
        ext = jnp.asarray(data.draw(st_.lists(
            st_.integers(-1, E - 1), min_size=B, max_size=B)), jnp.int32)
        # normalize to the write_pages convention: cow_src only on the
        # FIRST live lane of each dst group (the group leader)
        live = ok & (dst >= 0)
        same = live[None, :] & live[:, None] & (dst[None, :] == dst[:, None])
        leader = jnp.argmax(same, axis=1)
        is_leader = live & (leader == jnp.arange(B))
        ops = dbs.WriteOps(dst=dst, cow_src=jnp.where(is_leader, cow, -1),
                           ok=ok)
        pool = jax.random.normal(KEY, (E, PAGE, D))
        payload = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        ref = make_kernel("xla").write(pool, ops, payload, blocks)
        for name in ("pallas", "ref"):
            out = make_kernel(name).write(pool, ops, payload, blocks)
            _assert_rows_equal(out, ref)
        rref = make_kernel("xla").read(pool, ext, blocks)
        for name in ("pallas", "ref"):
            got = make_kernel(name).read(pool, ext, blocks)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(rref))

    prop()


# ---------------------------------------------------------------------------
# 2. the registry API
# ---------------------------------------------------------------------------
def test_registry_lists_and_rejects():
    names = available_kernels()
    for built_in in ("pallas", "xla", "ref", "copy"):
        assert built_in in names
    with pytest.raises(ValueError, match="unknown kernel"):
        make_kernel("nope")
    with pytest.raises(ValueError, match="unknown kernel"):
        Engine(EngineConfig(kernel="nope"))
    with pytest.raises(ValueError):
        register_kernel("broken", lambda *a: None)       # read= missing


def test_register_custom_kernel_roundtrip():
    xla = make_kernel("xla")
    calls = []

    def write(pool, ops, payload, blocks):
        calls.append("w")
        return xla.write(pool, ops, payload, blocks)

    try:
        register_kernel("traced", write, read=xla.read)
        assert "traced" in available_kernels()
        eng = Engine(EngineConfig(comm="fused", kernel="traced",
                                  payload_shape=(8,), n_extents=64,
                                  max_pages=32, batch=8))
        vol = eng.create_volume()
        eng.submit(Request(req_id=0, kind="write", volume=vol, page=0,
                           block=0, payload=jnp.ones((8,))))
        assert eng.drain() == 1
        assert calls, "custom kernel was not dispatched"
    finally:
        _REGISTRY.pop("traced", None)


def test_resolve_kernel_name_legacy_cow():
    """kernel= wins; kernel="auto" follows the legacy cow axis."""
    assert resolve_kernel_name(EngineConfig(kernel="ref")) == "ref"
    assert resolve_kernel_name(EngineConfig(cow="pallas")) == "pallas"
    assert resolve_kernel_name(EngineConfig(cow="ref")) == "xla"
    auto = resolve_kernel_name(EngineConfig())
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "xla")
    assert isinstance(make_kernel(auto), DBSKernel)


# ---------------------------------------------------------------------------
# 3. end-to-end: pallas == xla volume bytes through the public API
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,shards", [("fused", 1), ("sharded", 2),
                                            ("ring", 2)])
def test_blockdev_bytes_pallas_vs_xla(backend, shards):
    """Identical op streams through two VolumeManagers differing only in
    ``kernel=``: full-device reads must be byte-identical, and both must
    match a host bytearray shadow (the byte oracle)."""
    def mgr(kernel):
        return VolumeManager(backend=backend, n_shards=shards, kernel=kernel,
                             payload_elems=8, page_blocks=4, max_pages=8,
                             n_extents=256, max_volumes=8, batch=16,
                             n_replicas=2)

    mgrs = {k: mgr(k) for k in ("pallas", "xla")}
    vols = {k: m.create() for k, m in mgrs.items()}
    shadow = bytearray(mgrs["pallas"].capacity)

    def pat(seed, n):
        return bytes((seed * 37 + i) % 251 for i in range(n))

    def write(off, data):
        for k in mgrs:
            vols[k].pwrite(off, data)
        shadow[off:off + len(data)] = data

    write(0, pat(1, 17))            # unaligned tail
    write(5, pat(2, 11))            # unaligned head+tail (read-modify-write)
    write(24, pat(3, 48))           # page-crossing span
    for k in mgrs:
        vols[k].snapshot()
    write(13, pat(4, 9))            # CoW overwrite
    write(40, pat(5, 24))           # CoW page-crossing
    for m in mgrs.values():
        m.flush()
    got = {k: vols[k].read(0, mgrs[k].capacity) for k in mgrs}
    assert got["pallas"] == got["xla"]
    assert got["pallas"] == bytes(shadow)
    for m in mgrs.values():
        m.close()


def test_harness_scenario_kernel_pallas():
    """One chaos-harness scenario on the ring backend with the Pallas
    kernels: the byte oracle must hold end to end (registry -> EngineConfig
    -> ring_step_core -> dbs_rw)."""
    from repro.harness import run_scenario
    res = run_scenario("control/ring", n_ops=60, kernel="pallas")
    res.raise_if_failed()
    assert res.checked_reads > 0


# ---------------------------------------------------------------------------
# 4. the stale-interpret fix (per-call resolution)
# ---------------------------------------------------------------------------
def test_dbs_copy_resolves_interpret_per_call(monkeypatch):
    """The old module-level ``@jax.jit`` captured ``default_interpret()`` at
    first trace; after that, backend changes silently reused the stale mode.
    Now every call must consult ``default_interpret`` (the static arg keys
    the jit cache)."""
    from repro.kernels.dbs import ops
    calls = []
    real = ops.default_interpret
    monkeypatch.setattr(ops, "default_interpret",
                        lambda: (calls.append(1), real())[1])
    pool = jnp.zeros((4, 2, 8))
    idx = jnp.asarray([0, 1], jnp.int32)
    mask = jnp.ones((2,), bool)
    ops.dbs_copy(pool, idx, idx, mask)
    n = len(calls)
    assert n >= 1
    ops.dbs_copy(pool, idx, idx, mask)      # same shapes: jit cache hit...
    assert len(calls) == n + 1              # ...but the mode is re-resolved
