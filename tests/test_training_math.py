"""Numerical equivalences: chunked paths vs direct computations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ExecutionPlan, get_config, smoke_config
from repro.models import attention as A
from repro.models import ssm
from repro.models.layers import init_moe, apply_moe

KEY = jax.random.PRNGKey(0)


def _qkv(b=2, s=64, h=4, kv=2, hd=32, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("window,cap", [(0, 0.0), (16, 0.0), (0, 30.0),
                                        (24, 50.0)])
def test_chunked_equals_dense(window, cap):
    q, k, v, pos = _qkv()
    dense = A.dense_attention(q, k, v, pos, pos, window=window, logit_cap=cap)
    chunked = A.chunked_attention(q, k, v, pos, pos, window=window,
                                  logit_cap=cap, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16, 24])
def test_banded_equals_dense(window):
    q, k, v, pos = _qkv(s=128)
    dense = A.dense_attention(q, k, v, pos, pos, window=window)
    banded = A.banded_attention(q, k, v, pos, pos, window=window, q_chunk=16)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_split_kv_merge_equals_full():
    """FlashDecoding merge over page stripes == full attention (the math
    behind the distributed paged-DBS read)."""
    b, h, kv, hd, s = 2, 4, 2, 32, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    q_pos = jnp.full((b, 1), s - 1, jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = A.decode_attention(q, k, v, q_pos, k_pos)

    parts = []
    n_shards = 4
    for r in range(n_shards):
        # stripe r sees positions where (pos // 8) % n_shards == r
        mask_pos = jnp.where((k_pos // 8) % n_shards == r, k_pos,
                             jnp.iinfo(jnp.int32).max)
        parts.append(A.decode_partial(q, k, v, q_pos, mask_pos))
    o = jnp.stack([p[0] for p in parts])
    m = jnp.stack([p[1] for p in parts])
    l = jnp.stack([p[2] for p in parts])
    merged = A.merge_partials(o, m, l)
    bshape = merged.shape
    merged = merged.reshape(bshape[0], bshape[1] * bshape[2], 1, -1
                            ).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(merged, np.float32),
                               np.asarray(full, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_chunked_ce_equals_direct():
    from repro.models.layers import init_embeddings
    from repro.training.train_step import chunked_cross_entropy, _ce_block
    cfg = smoke_config("granite-3-8b")
    emb = init_embeddings(KEY, cfg)
    h = jax.random.normal(KEY, (2, 32, cfg.d_model))
    labels = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    direct = _ce_block(emb, h, labels, cfg)
    chunked = chunked_cross_entropy(emb, h, labels, cfg, chunk=8)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_mamba_chunked_equals_stepwise():
    cfg = smoke_config("hymba-1.5b")
    p = ssm.init_mamba(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y_chunk, st_chunk = ssm.mamba_forward(p, x, chunk=8)
    # step-by-step
    st = ssm.mamba_init_state(p, 2, x.dtype)
    ys = []
    for t in range(32):
        y, st = ssm.mamba_step(p, x[:, t:t + 1], st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(st_chunk[1]), np.asarray(st[1]),
                               rtol=5e-4, atol=5e-4)


def test_rwkv_chunked_equals_stepwise():
    cfg = smoke_config("rwkv6-3b")
    p = ssm.init_rwkv6(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    st0 = ssm.rwkv6_init_state(cfg, 2, x.dtype)
    y_chunk, stc = ssm.rwkv6_time_mix(p, x, st0, cfg, chunk=8)
    st = dict(st0)
    ys = []
    for t in range(32):
        y, upd = ssm.rwkv6_time_mix(p, x[:, t:t + 1], st, cfg, chunk=1)
        st = {**st, **upd}
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-3, atol=1e-3)


def test_moe_dropless_routes_every_token():
    cfg = smoke_config("granite-moe-3b-a800m")
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0
    # grads flow through ragged_dot
    g = jax.grad(lambda xx: apply_moe(p, xx, cfg)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_optimizers_descend_quadratic():
    from repro.training.optimizer import make_optimizer
    target = jnp.asarray([1.5, -2.0, 0.5])

    for name in ("adamw", "adafactor"):
        init, update = make_optimizer(name, lr=0.1, warmup=1,
                                      total_steps=200, weight_decay=0.0)
        params = {"w": jnp.zeros((3,)), "m": jnp.zeros((4, 4))}
        state = init(params)
        for _ in range(120):
            grads = {"w": params["w"] - target,
                     "m": params["m"] - jnp.eye(4)}
            params, state, gnorm = update(grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=0.15)
        np.testing.assert_allclose(np.asarray(params["m"]),
                                   np.asarray(jnp.eye(4)), atol=0.15)


@pytest.mark.skipif(not hasattr(jax, "shard_map"),
                    reason="repro.distributed.collectives needs top-level "
                           "jax.shard_map, unavailable in this jax")
def test_gradient_compression_roundtrip():
    from repro.distributed.collectives import compress_int8, decompress_int8
    x = jax.random.normal(KEY, (128,)) * 3.0
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(s) * 0.51 + 1e-6)
