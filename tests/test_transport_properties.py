"""Property test: fail -> streamed delta rebuild under random write load
(hypothesis-driven; skipped when hypothesis is not installed).

Random block-aligned byte writes are driven through the public
``VolumeManager`` API on the host-dispatch engine, with a replica FAILED
mid-stream, more writes landing on the survivor, and the failed replica
DELTA-REBUILT through the transport — parametrized over every registered
transport (local | device | simnet-with-drop). After the rebuild, reads
are forced onto EACH replica in turn and must be byte-equivalent to a
host-side bytearray oracle; the transport's ``pages_moved`` counter must
equal the distinct pages written while the replica was down (the delta),
strictly fewer than the allocated total whenever pre-fail-only pages
exist (ISSUE 5 acceptance).
"""
import pytest

from repro.core.blockdev import VolumeManager

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

BB = 8          # block_bytes
PB = 4          # page_blocks -> page_bytes = 32
PAGES = 12      # capacity = 384 bytes

# one block-aligned write: (page, block, seed) — aligned spans keep the
# oracle trivial and the fan-out one SQE per op (no RMW reads in the mix)
_W = st.tuples(st.integers(0, PAGES - 1), st.integers(0, PB - 1),
               st.integers(0, 250))

_MGRS = {}


def _pat(seed: int) -> bytes:
    return bytes((seed * 31 + i) % 251 for i in range(BB))


def _mgr(transport: str) -> VolumeManager:
    if transport not in _MGRS:      # reuse: keeps the jitted programs warm
        opts = (dict(latency=2, window=8, drop=0.2, seed=11)
                if transport == "simnet" else None)
        _MGRS[transport] = VolumeManager(
            backend="slots", transport=transport, transport_opts=opts,
            payload_elems=BB, page_blocks=PB, max_pages=PAGES,
            n_extents=1024, max_volumes=16, batch=16)
    return _MGRS[transport]


@pytest.mark.parametrize("transport", ["local", "device", "simnet"])
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(pre=st.lists(_W, max_size=12), post=st.lists(_W, max_size=12))
def test_property_fail_delta_rebuild_under_load(transport, pre, post):
    mgr = _mgr(transport)
    group = mgr.engine.backend
    v = mgr.create()
    ref = bytearray(mgr.capacity)
    try:
        for page, block, seed in pre:
            off = (page * PB + block) * BB
            v.pwrite(off, _pat(seed))
            ref[off:off + BB] = _pat(seed)
        mgr.flush()

        mgr.engine.control("fail", replica=1)     # mid-stream failure
        for page, block, seed in post:
            off = (page * PB + block) * BB
            v.pwrite(off, _pat(seed))
            ref[off:off + BB] = _pat(seed)
        mgr.flush()

        moved0 = group.transports[1].pages_moved
        mgr.engine.control("rebuild", replica=1)  # streamed delta
        moved = group.transports[1].pages_moved - moved0

        post_pages = {p for p, _, _ in post}
        all_pages = post_pages | {p for p, _, _ in pre}
        assert moved == len(post_pages), \
            "delta must move exactly the pages written while down"
        if all_pages - post_pages:
            assert moved < len(all_pages), \
                "delta must beat a full copy when pre-fail-only pages exist"

        # byte-equivalence vs the oracle, forced onto EACH replica
        assert v.read(0, mgr.capacity) == bytes(ref)
        for serve, bench in ((1, 0), (0, 1)):
            mgr.engine.control("fail", replica=bench)
            assert v.read(0, mgr.capacity) == bytes(ref), \
                f"replica {serve} diverged from the oracle"
            mgr.engine.control("rebuild", replica=bench)
        assert group.consistent()
    finally:
        mgr.delete(v)
