"""Property tests: random traces x random crash points through the
durability subsystem.

Seeded-random interleavings of ``pwrite``/``discard``/``flush`` and CRASH
points — the manager abandoned mid-trace (optionally with a half-written
record torn onto the journal tail) and recovered from the WAL — must leave
every byte equal to a host bytearray oracle, (a) under plain journal
replay, (b) with crashes racing an incremental delta export (recovery
installs the newest section and replays only the sealed tail), and (c)
with the cold-extent spill tier over-subscribed, so crashes land between
spill/fill cycles and recovery rebuilds a tiered pool.

The generator is a hand-rolled ``random.Random`` walk rather than
hypothesis (not in the image): every trace is reproducible from its seed
parameter alone.
"""
import os
import random
import shutil
import tempfile

import numpy as np
import pytest

from repro.core.blockdev import VolumeManager
from repro.core.transport import MSG_WRITE, WireMsg
from repro.durability import SnapshotExport, recover
from repro.durability.journal import encode_record

BB = 8          # block_bytes
PB = 4          # page_blocks -> page_bytes = 32
PAGES = 8       # capacity = 256 bytes
_CAP = PAGES * PB * BB


def _kw(**kw):
    base = dict(backend="fused", payload_elems=BB, page_blocks=PB,
                max_pages=PAGES, n_extents=128, max_volumes=8, batch=16,
                n_replicas=2)
    base.update(kw)
    return base


def _gen_ops(seed: int, n: int = 12):
    """One reproducible random trace: writes/discards/flushes with crash
    points sprinkled in, plus a guaranteed trailing crash on odd seeds so
    every other trace ends in recovery."""
    rng = random.Random(seed)
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < 0.50:
            ops.append(("write", rng.randrange(_CAP),
                        rng.randint(1, 3 * PB * BB), rng.randrange(251)))
        elif r < 0.70:
            ops.append(("discard", rng.randrange(_CAP),
                        rng.randint(1, 3 * PB * BB)))
        elif r < 0.85:
            ops.append(("flush",))
        else:
            ops.append(("crash", rng.random() < 0.5))
    if seed % 2:
        ops.append(("crash", seed % 4 == 1))
    return ops


def _tear(jp: str) -> None:
    """Append half a valid record: a crash mid-group-commit."""
    rec = encode_record(10 ** 9, WireMsg(
        op=MSG_WRITE, volume=0, pages=np.asarray([0], np.int32),
        blocks=np.asarray([0], np.int32),
        payload=np.zeros((1, BB), np.float32)))
    with open(jp, "ab") as f:
        f.write(rec[:len(rec) // 2])


def _drive(ops, *, tier=None, export_every: int = 0) -> None:
    tmp = tempfile.mkdtemp(prefix="repro-dur-prop-")
    jp = os.path.join(tmp, "wal.dbsj")
    xp = os.path.join(tmp, "inc.dbsx")
    kw = _kw(**({} if tier is None else {"tier": tier}))
    mgr = VolumeManager(journal=jp, **kw)
    exp = SnapshotExport(xp) if export_every else None
    vid = mgr.create().vid
    ref = bytearray(mgr.capacity)
    n_mut = 0
    try:
        for op in ops:
            if op[0] == "write":
                _, off, n, seed = op
                n = min(n, _CAP - off)
                data = bytes((seed + i) % 251 for i in range(n))
                mgr.pwrite(vid, off, data)
                ref[off:off + n] = data
                n_mut += 1
            elif op[0] == "discard":
                _, off, n = op
                n = min(n, _CAP - off)
                mgr.discard(vid, off, n)
                ref[off:off + n] = bytes(n)
                n_mut += 1
            elif op[0] == "flush":
                mgr.flush()
            else:                                     # crash
                mgr.flush(durable=True)
                if op[1]:
                    _tear(jp)
                use_exp = xp if exp is not None and exp.sections else None
                mgr = recover(jp, export=use_exp, **kw)
                assert mgr.open(vid).read(0, _CAP) == bytes(ref)
            if (export_every and n_mut
                    and n_mut % export_every == 0 and op[0] != "crash"):
                exp.export(mgr, journal=mgr._journal)
        mgr.flush()
        assert mgr.open(vid).read(0, _CAP) == bytes(ref)
    finally:
        mgr.close()
        shutil.rmtree(tmp, ignore_errors=True)


@pytest.mark.parametrize("seed", range(10))
def test_property_random_crash_replay(seed):
    _drive(_gen_ops(seed))


@pytest.mark.parametrize("seed", range(10, 18))
def test_property_crash_racing_delta_export(seed):
    _drive(_gen_ops(seed), export_every=2)


@pytest.mark.parametrize("seed", range(20, 28))
def test_property_crash_between_spill_fill_cycles(seed):
    _drive(_gen_ops(seed), tier=3)                    # 3 of 8 extents hot
