"""Chaos-harness acceptance tests (ISSUE 6).

Four groups:

- **generator determinism** — traces and chaos schedules are pure
  functions of their seeds (no engine involved),
- **replay determinism** — one ``(trace_seed, chaos_seed)`` pair replayed
  through the full chaos/simnet scenario twice produces identical per-op
  completion ticks, oracle state and digest (the seed-threading fix:
  simnet's drop/reorder stream is derived from ``chaos_seed``),
- **tail-latency invariant** — under a straggler link the
  latency-weighted read policy must beat rr on P99 controller wait ticks
  and stay inside the harness bounds (the ``--check`` gate, asserted),
- **chaos edge cases** — hand-crafted event schedules for the races the
  generator only sometimes hits: rebuild racing in-flight write-behind
  traffic, quorum loss then recovery, unmap/clone racing a rebuild
  stream. Each asserts byte-oracle equivalence on every surviving
  replica and that no ``IOFuture`` hangs (``HarnessResult.ok`` covers
  both: the runner records a failure for any undone future after a full
  flush).
"""
import pytest

from repro.harness import (ChaosConfig, ChaosEvent, TraceConfig, TraceOp,
                           run, schedule_chaos)
from repro.harness.runner import (P99_BOUND, P999_BOUND, run_scenario)
from repro.harness.traces import generate_trace

GEO = dict(block_bytes=16, page_blocks=4, n_pages=32)   # capacity 2048 B


# ---------------------------------------------------------------------------
# generator determinism (no engine)
# ---------------------------------------------------------------------------
def test_trace_generator_deterministic():
    cfg = TraceConfig(n_ops=64, unaligned_frac=0.2)
    a = generate_trace(7, cfg, **GEO)
    b = generate_trace(7, cfg, **GEO)
    assert a == b
    assert generate_trace(8, cfg, **GEO) != a
    cap = GEO["n_pages"] * GEO["page_blocks"] * GEO["block_bytes"]
    for op in a:
        assert op.kind in ("read", "write")
        assert 0 <= op.off and op.off + op.nbytes <= cap and op.nbytes > 0
    assert a[-1].last_in_burst


def test_chaos_schedule_deterministic_and_indexed():
    cfg = ChaosConfig(n_events=12)
    kw = dict(n_ops=100, n_replicas=3, n_volumes=4, capacity=2048)
    a = schedule_chaos(3, cfg, **kw)
    assert a == schedule_chaos(3, cfg, **kw)
    assert a != schedule_chaos(4, cfg, **kw)
    assert all(1 <= ev.index < 100 for ev in a)
    assert [ev.index for ev in a] == sorted(ev.index for ev in a)


def test_chaos_schedule_no_replica_faults_single_replica():
    evs = schedule_chaos(0, ChaosConfig(n_events=16), n_ops=64,
                         n_replicas=1, n_volumes=2, capacity=2048)
    assert all(ev.action not in ("fail", "rebuild", "quorum_loss",
                                 "recover") for ev in evs)


# ---------------------------------------------------------------------------
# replay determinism (satellite: simnet seed threading)
# ---------------------------------------------------------------------------
def test_replay_determinism_chaos_simnet():
    """Identical ``(trace_seed, chaos_seed, transport_opts)`` must replay
    byte-identically: same per-op completion ticks, same digest, same
    applied/skipped event lists — including simnet's drop/reorder
    decisions, which the harness seeds from ``chaos_seed``."""
    a = run_scenario("chaos/simnet", trace_seed=5, chaos_seed=9, n_ops=60)
    b = run_scenario("chaos/simnet", trace_seed=5, chaos_seed=9, n_ops=60)
    assert a.ok, a.oracle_failures + a.harness_failures
    assert a.completion_ticks == b.completion_ticks
    assert a.digest == b.digest
    assert a.events_applied == b.events_applied
    assert a.events_skipped == b.events_skipped
    assert a.counters == b.counters


# ---------------------------------------------------------------------------
# tail-latency invariant (satellite: straggler gate)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_straggler_latency_policy_beats_rr_p99():
    rr = run_scenario("straggler/rr", trace_seed=3, chaos_seed=0, n_ops=120)
    lat = run_scenario("straggler/latency", trace_seed=3, chaos_seed=0,
                       n_ops=120)
    assert rr.ok and lat.ok
    rr_p99 = rr.wait["read"]["p99"]
    lat_p99 = lat.wait["read"]["p99"]
    assert rr.wait["read"]["count"] > 50          # singleton bursts landed
    assert lat_p99 < rr_p99, \
        f"latency-weighted P99 {lat_p99} must beat rr {rr_p99} wait ticks"
    assert lat_p99 <= P99_BOUND
    assert lat.wait["read"]["p999"] <= P999_BOUND


# ---------------------------------------------------------------------------
# chaos edge cases (hand-crafted schedules)
# ---------------------------------------------------------------------------
def _writes(indices, vol=0, stride=64, nbytes=32, flush_at=()):
    """Block-aligned writes walking the volume; flush only at ``flush_at``
    (everything else stays in one open burst so chaos events race
    genuinely in-flight traffic)."""
    cap = GEO["n_pages"] * GEO["page_blocks"] * GEO["block_bytes"]
    return [TraceOp(index=i, kind="write", vol=vol,
                    off=(i * stride) % (cap - nbytes), nbytes=nbytes,
                    last_in_burst=(i in flush_at))
            for i in indices]


def _run_edge(events, *, write_policy="async", n_ops=20):
    ops = _writes(range(n_ops), flush_at={n_ops - 1})
    return run(trace_seed=11, chaos_seed=0, trace=TraceConfig(n_volumes=2),
               trace_ops=ops, chaos_events=events, backend="slots",
               n_replicas=3, transport="simnet", write_policy=write_policy,
               transport_opts=dict(latency=3, window=64, seed=4))


def test_fail_then_rebuild_racing_inflight_write_behind():
    """Fail a replica mid-burst, then rebuild it while the survivors'
    write-behind traffic from the same burst is still on the links — the
    rebuild stream rides FIFO behind it. Oracle equivalence must hold on
    every replica afterwards."""
    res = _run_edge([ChaosEvent(5, "fail", replica=2),
                     ChaosEvent(12, "rebuild", replica=2)])
    assert res.ok, res.oracle_failures + res.harness_failures
    assert [e.split()[1] for e in res.events_applied] == ["fail", "rebuild"]


def test_quorum_loss_then_recovery():
    """Fail down to a single survivor under quorum writes, keep writing
    degraded, then recover with back-to-back delta rebuilds from the lone
    survivor."""
    res = _run_edge([ChaosEvent(6, "quorum_loss", replica=0),
                     ChaosEvent(14, "recover")],
                    write_policy="quorum")
    assert res.ok, res.oracle_failures + res.harness_failures
    kinds = [e.split()[1] for e in res.events_applied]
    assert kinds == ["quorum_loss", "recover"]


def test_unmap_and_clone_racing_rebuild_stream():
    """Discard and clone land between a fail and its rebuild, so the
    rebuild's delta stream races both the unmap and the CoW fork; the
    clone's shadow must equal the source's at the (flushed) clone point
    and every replica must converge."""
    res = _run_edge([ChaosEvent(4, "fail", replica=1),
                     ChaosEvent(8, "discard", vol=0, off=64, nbytes=256),
                     ChaosEvent(10, "clone", vol=0),
                     ChaosEvent(15, "rebuild", replica=1)])
    assert res.ok, res.oracle_failures + res.harness_failures
    kinds = [e.split()[1] for e in res.events_applied]
    assert kinds == ["fail", "discard", "clone", "rebuild"]
    # the clone's full-capacity verification read happened too
    assert res.checked_reads >= 2


def test_hung_future_is_reported_not_deadlocked():
    """The no-hung-IOFuture check is a *recorded failure*, not a hang: a
    run over a healthy engine must report zero such failures while having
    actually exercised the check on every burst."""
    res = run(trace_seed=2, chaos_seed=0,
              trace=TraceConfig(n_ops=40, n_volumes=2, mean_burst=4),
              backend="slots", n_replicas=2, transport="local")
    assert res.harness_failures == []
    assert res.completed > 0 and len(res.completion_ticks) == 40
