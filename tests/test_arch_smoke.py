"""Per-architecture smoke tests: reduced same-family configs on CPU.

Each arch: forward shapes + finiteness, one train step (loss finite,
decreases over 2 steps), prefill+decode consistency with the paged-DBS
cache path (decode logits after prefill == forward logits of the extended
sequence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ExecutionPlan, smoke_config
from repro.models import (decode_step, default_block_tables, forward,
                          init_cache, init_params, prefill, with_block_tables)
from repro.models.layers import lm_logits
from repro.models.model import param_count_actual
from repro.training.train_step import make_train_step

PLAN = ExecutionPlan(remat="block", attn_impl="chunked",
                     compute_dtype="float32", microbatches=1, logits_chunk=0)


def _tokens(cfg, key, b, s):
    shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    assert param_count_actual(params) > 0
    b, s = 2, 32
    tokens = _tokens(cfg, key, b, s)
    h, aux = forward(params, tokens, cfg, PLAN)
    assert h.shape == (b, s, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all(), "NaN in forward"

    batch = {"tokens": tokens, "labels": tokens}
    opt_init, step = make_train_step(cfg, PLAN, total_steps=8, warmup=1)
    opt = opt_init(params)
    jstep = jax.jit(step)
    p1, opt, m1 = jstep(params, opt, batch)
    p2, opt, m2 = jstep(p1, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.05, \
        f"loss not improving: {float(m1['loss'])} -> {float(m2['loss'])}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode(t+1 | prefill(0..t)) must equal forward(0..t+1) at position t+1.

    This exercises the whole storage path: paged pools, DBS block tables,
    ring caches for sliding-window layers, recurrent states for SSM archs.
    """
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b = 2
    s = 2 * cfg.page_blocks          # page-aligned prompt
    tokens = _tokens(cfg, key, b, s + 1)
    prompt, nxt = tokens[:, :s], tokens[:, s]

    caches = init_cache(cfg, b, s + cfg.page_blocks, paged=True,
                        dtype=jnp.float32)
    caches = with_block_tables(
        caches, default_block_tables(cfg, b, s + cfg.page_blocks))
    _, caches = prefill(params, prompt, cfg, PLAN, caches)
    pos = jnp.full((b,), s, jnp.int32)
    logits_dec, _ = decode_step(params, nxt, pos, cfg, PLAN, caches)

    h, _ = forward(params, tokens, cfg, PLAN)
    from repro.models.layers import rms_norm
    hN = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps,
                  gemma_style=cfg.name.startswith("gemma"))
    logits_fwd = lm_logits(params["embed"], hN, cfg)[:, 0]
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd, np.float32), rtol=2e-3, atol=2e-3)


def test_layer_schedule_covers_all_layers():
    from repro.configs import get_config
    from repro.models.blocks import layer_schedule
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        segs = layer_schedule(cfg)
        total = sum(seg.count * len(seg.sigs) for seg in segs)
        assert total == cfg.n_layers, (arch, total, cfg.n_layers)


def test_full_configs_match_assignment():
    """Exact assigned dimensions for every arch (guards against drift)."""
    from repro.configs import get_config
    expect = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("granite-moe-3b-a800m").moe.n_experts == 40
    assert get_config("granite-moe-3b-a800m").moe.top_k == 8
    assert get_config("deepseek-v3-671b").moe.n_experts == 256
    assert get_config("hymba-1.5b").ssm.state_dim == 16
