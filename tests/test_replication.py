"""ReplicaGroup contracts: null-storage read dispatch count and
fail/rebuild validation (paper §III controller semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dbs
from repro.core.replication import ReplicaGroup


def _group(**kw):
    base = dict(n_replicas=2, n_extents=64, max_volumes=4, max_pages=32,
                page_blocks=8, payload_shape=(4,))
    base.update(kw)
    return ReplicaGroup(**base)


def test_null_storage_read_dispatches_nothing(monkeypatch):
    """Regression: the null-storage read path used to dispatch (and
    discard) a read_resolve per batch — a dead device op on the layer-cut
    row whose whole point is measuring the stack WITHOUT storage work."""
    g = _group(null_storage=True)
    vol = g.create_volume()
    calls = []
    real = dbs.read_resolve
    monkeypatch.setattr(dbs, "read_resolve",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    out = g.read(vol, jnp.arange(8, dtype=jnp.int32),
                 jnp.zeros((8,), jnp.int32))
    assert out.shape == (8, 4)
    np.testing.assert_allclose(np.asarray(out), 0.0)
    assert calls == [], f"null-storage read dispatched {len(calls)} resolves"


def test_null_storage_read_leaves_rr_alone():
    """Null-storage reads consult no replica, so they must not burn the
    round-robin cursor either — the layer-cut row would otherwise skew the
    read distribution the real replicas see (ChainedReplicas.read holds
    the same contract; see tests/test_ring.py)."""
    g = _group(null_storage=True)
    vol = g.create_volume()
    before = g._rr
    g.read(vol, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), jnp.int32))
    g.read(vol, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), jnp.int32))
    assert g._rr == before


def test_null_storage_read_matches_real_read_shape():
    real = _group()
    null = _group(null_storage=True)
    for g in (real, null):
        vol = g.create_volume()
        g.write(vol, jnp.arange(4, dtype=jnp.int32),
                jnp.zeros((4,), jnp.int32), jnp.ones((4, 4)))
    a = real.read(0, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), jnp.int32))
    b = null.read(0, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), jnp.int32))
    assert a.shape == b.shape and a.dtype == b.dtype


def test_fail_validates_index():
    g = _group()
    with pytest.raises(IndexError):
        g.fail(2)
    with pytest.raises(IndexError):
        g.fail(-1)
    g.fail(1)                                   # in range: fine
    assert not g.replicas[1].healthy


def test_rebuild_rejects_healthy_replica():
    g = _group()
    vol = g.create_volume()
    g.write(vol, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), jnp.int32),
            jnp.ones((4, 4)))
    with pytest.raises(ValueError):
        g.rebuild(0)                            # nothing failed
    with pytest.raises(IndexError):
        g.rebuild(9)
    g.fail(0)
    g.write(vol, jnp.arange(4, dtype=jnp.int32), jnp.ones((4,), jnp.int32),
            jnp.full((4, 4), 2.0))              # replica 0 misses this
    g.rebuild(0)                                # valid: was failed
    assert g.replicas[0].healthy and g.consistent()


def test_fail_refuses_last_healthy_replica():
    """Failing every replica is volume loss, not failover — the controller
    keeps one serving copy (a write would otherwise silently ack-and-drop
    in the fused step, whose ok flags only track slot admission)."""
    g = _group()
    g.fail(0)
    with pytest.raises(RuntimeError):
        g.fail(1)
    g.rebuild(0)
    g.fail(1)                                   # fine again: 0 is healthy
    assert g.replicas[0].healthy and not g.replicas[1].healthy
