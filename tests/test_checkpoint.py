"""Checkpoint store: on-disk DBS semantics, crash recovery, replication,
elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, ReplicatedCheckpoint
from repro.core.dbs_host import DBSHost


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (64, 32)),
            "b": jnp.arange(7, dtype=jnp.float32),
            "nested": {"e": jax.random.normal(k, (16, 8)).astype(jnp.bfloat16)}}


def _assert_tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_save_restore_roundtrip(tmp_path):
    st = CheckpointStore(str(tmp_path / "ck.dbs"), capacity_bytes=1 << 24)
    t0 = _tree(0)
    st.save("train", 10, t0)
    step, back = st.restore("train", like=t0)
    assert step == 10
    _assert_tree_eq(t0, back)
    # version history via snapshots
    t1 = _tree(1)
    st.save("train", 20, t1)
    step, back = st.restore("train", like=t0)
    assert step == 20
    _assert_tree_eq(t1, back)
    st.close()


def test_crash_torn_write_recovers_previous_version(tmp_path):
    path = str(tmp_path / "ck.dbs")
    st = CheckpointStore(path, capacity_bytes=1 << 24)
    t0 = _tree(0)
    st.save("train", 10, t0)
    # simulate a torn save: corrupt the live head's header block only
    st.dev.write("train", 0, b"\xff" * 4096)
    st.close()
    st2 = CheckpointStore(path, capacity_bytes=1 << 24)
    step, back = st2.restore("train", like=t0)
    assert step == 10                      # fell back to the frozen snapshot
    _assert_tree_eq(t0, back)
    st2.close()


def test_reopen_rebuilds_tables(tmp_path):
    path = str(tmp_path / "ck.dbs")
    st = CheckpointStore(path, capacity_bytes=1 << 24)
    t0 = _tree(3)
    st.save("train", 5, t0)
    st.close()
    st2 = CheckpointStore(path, capacity_bytes=1 << 24)   # open() path
    step, back = st2.restore("train", like=t0)
    assert step == 5
    _assert_tree_eq(t0, back)
    st2.close()


def test_replicated_write_all_fail_rebuild(tmp_path):
    dirs = [str(tmp_path / d) for d in "abc"]
    for d in dirs:
        os.makedirs(d)
    rc = ReplicatedCheckpoint(dirs, capacity_bytes=1 << 24)
    t0 = _tree(0)
    rc.save("train", 7, t0)
    assert rc.consistent()
    rc.fail(0)
    step, back = rc.restore("train", like=t0)     # survives replica loss
    assert step == 7
    _assert_tree_eq(t0, back)
    rc.rebuild(0)
    assert rc.consistent()
    step, back = rc.stores[0].restore("train", like=t0)
    assert step == 7
    rc.close()


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType not available in this jax")
def test_elastic_restore_resharding(tmp_path):
    """Restore onto a different (1-device) mesh sharding — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    st = CheckpointStore(str(tmp_path / "ck.dbs"), capacity_bytes=1 << 24)
    t0 = _tree(0)
    st.save("train", 3, t0)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), t0)
    step, back = st.restore("train", like=t0, shardings=shardings)
    assert step == 3
    _assert_tree_eq(t0, back)
    for leaf in jax.tree.leaves(back):
        assert isinstance(leaf.sharding, NamedSharding)
    st.close()


def test_dbs_host_cow_and_merge(tmp_path):
    path = str(tmp_path / "dev.img")
    d = DBSHost.create(path, n_extents=64, extent_blocks=8, block_size=512,
                       max_pages=64)
    d.create_volume("v")
    data1 = bytes(np.random.default_rng(0).integers(0, 255, 8 * 512,
                                                    dtype=np.uint8))
    d.write("v", 0, data1)
    d.snapshot("v")
    data2 = bytes(np.random.default_rng(1).integers(0, 255, 512,
                                                    dtype=np.uint8))
    d.write("v", 512, data2)               # CoW within the first extent
    assert d.read("v", 0, 512) == data1[:512]
    assert d.read("v", 512, 512) == data2
    # clone isolation
    d.clone("v", "f")
    d.write("f", 0, data2)
    assert d.read("v", 0, 512) == data1[:512]
    assert d.read("f", 512, 512) == data2
    d.delete_volume("f")
    # merge-delete the frozen middle snapshot
    head = d.volumes["v"]
    mid = d.snapshots[head].parent
    d.delete_snapshot(mid)
    assert d.read("v", 0, 512) == data1[:512]
    assert d.read("v", 512, 512) == data2
    d.close()
