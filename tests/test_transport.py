"""The controller<->replica transport layer (core/transport.py) and the
policy objects over it (core/replication.py).

Contracts:

1. **registry** — local/device/simnet are registered; unknown names raise;
   embedders can register their own transport.
2. **wire accounting** — every controller->replica interaction is a counted
   message; nothing bypasses the boundary on the host-orchestrated path.
3. **delta rebuild** — after a partial-overwrite workload the streamed
   rebuild moves EXACTLY the post-fail pages (strictly fewer than a full
   copy), on the host group, the fused engine (in-program watermark
   stamping), and the sharded pool (per-shard slices); content is
   bit-identical to the donor afterwards.
4. **simnet** — latency-delayed delivery, bounded-window backpressure,
   FIFO-preserving drop/retransmit, deterministic under seed.
5. **write/read policies** — quorum acks on a majority (straggler catches
   up over FIFO), async is write-behind, latency-weighted reads avoid the
   slow link; every policy converges to the ``all`` end state after drain.
6. **config threading** — EngineConfig/VolumeManager reach the group;
   in-program backends (fused/sharded/ring) reject host-only policies.
7. satellites — ``IOFuture.result()`` caches (no re-assembly, no re-flush),
   ``ReplicaGroup.consistent()`` fetches once, ``VolumeManager`` context
   manager drains on exit and rejects I/O after ``close()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request, transport
from repro.core.blockdev import VolumeManager
from repro.core.replication import ReplicaGroup
from repro.core.transport import (MSG_WRITE, LocalTransport, SimNetTransport,
                                  WireMsg, available_transports,
                                  register_transport)

PAY = (4,)


def _group(**kw):
    base = dict(n_replicas=2, n_extents=256, max_volumes=4, max_pages=64,
                page_blocks=8, payload_shape=PAY)
    base.update(kw)
    return ReplicaGroup(**base)


def _w(g, vol, pages, val):
    pages = jnp.asarray(pages, jnp.int32)
    g.write(vol, pages, jnp.zeros(pages.shape, jnp.int32),
            jnp.full((pages.shape[0],) + PAY, float(val)))


def _r(g, vol, pages):
    pages = jnp.asarray(pages, jnp.int32)
    return np.asarray(jax.device_get(
        g.read(vol, pages, jnp.zeros(pages.shape, jnp.int32))))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_names_and_unknown():
    names = available_transports()
    assert {"local", "device", "simnet"} <= set(names)
    with pytest.raises(ValueError, match="unknown transport"):
        _group(transport="carrier-pigeon")


def test_registry_custom_transport():
    calls = []

    @register_transport("counting-local")
    class CountingLocal(LocalTransport):
        def post(self, msg):
            calls.append(msg.op)
            return super().post(msg)

    try:
        g = _group(transport="counting-local")
        vol = g.create_volume()
        _w(g, vol, [0, 1], 1.0)
        assert calls and MSG_WRITE in calls
        np.testing.assert_allclose(_r(g, vol, [0, 1]), 1.0)
    finally:
        transport._REGISTRY.pop("counting-local", None)


def test_policy_validation():
    with pytest.raises(ValueError, match="write_policy"):
        _group(write_policy="most")
    with pytest.raises(ValueError, match="read_policy"):
        _group(read_policy="nearest")


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------
def test_every_interaction_is_a_counted_message():
    g = _group()
    vol = g.create_volume()
    _w(g, vol, [0, 1, 2], 1.0)
    _r(g, vol, [0])
    g.snapshot(vol)
    g.unmap(vol, jnp.asarray([2], jnp.int32))
    assert g.consistent()
    for i, t in enumerate(g.transports):
        assert t.sent["CREATE"] == 1
        assert t.sent["WRITE"] == 1          # one mirrored batch each
        assert t.sent["SNAPSHOT"] == 1
        assert t.sent["UNMAP"] == 1
        assert t.sent["QUERY_REV"] == 1      # consistent()
    # the single read went to exactly one replica (round-robin)
    assert sum(t.sent["READ"] for t in g.transports) == 1


# ---------------------------------------------------------------------------
# delta rebuild (the ISSUE 5 acceptance assertion)
# ---------------------------------------------------------------------------
def test_delta_rebuild_moves_only_post_fail_pages():
    g = _group()
    vol = g.create_volume()
    _w(g, vol, list(range(32)), 1.0)         # 32 allocated extents
    g.fail(1)
    _w(g, vol, [3, 4, 5, 6, 40], 7.0)        # 4 overwrites + 1 new page
    moved0 = g.transports[1].pages_moved
    g.rebuild(1)
    moved = g.transports[1].pages_moved - moved0
    # exactly the 5 post-fail pages crossed the wire — STRICTLY fewer than
    # the 33 allocated extents a full copy would stream
    assert moved == 5
    assert moved < 33
    assert g.consistent()
    # the rebuilt replica serves the missed writes (force reads onto it)
    g.fail(0)
    np.testing.assert_allclose(_r(g, vol, [3, 40]), 7.0)
    np.testing.assert_allclose(_r(g, vol, [0, 31]), 1.0)
    g.rebuild(0)


def test_delta_rebuild_covers_clone_shared_extents():
    """Regression: a clone's watermark row must inherit the source's
    (``transport.clone_page_rev``). Otherwise an extent whose only table
    reference is the clone's row (source CoW-diverged after the clone)
    never beats the target's zero watermarks, and the rebuilt replica
    silently serves the clone stale pre-fail data while ``consistent()``
    still passes."""
    g = _group()
    vol = g.create_volume()
    _w(g, vol, [0], 1.0)
    g.fail(1)
    _w(g, vol, [0], 2.0)                     # replica 1 misses this
    cvol = g.clone(vol)                      # clone shares page 0's extent
    _w(g, vol, [0], 3.0)                     # source CoWs to a new extent
    g.rebuild(1)
    assert g.consistent()
    g.fail(0)                                # force reads onto the rebuilt
    np.testing.assert_allclose(_r(g, vol, [0]), 3.0)
    np.testing.assert_allclose(_r(g, cvol, [0]), 2.0)
    g.rebuild(0)


def test_inband_clone_then_host_delta_rebuild():
    """The same clone hazard through the ring's IN-BAND clone opcode: the
    control-tail scan carries the watermark arrays so the clone row copy
    happens inside the compiled program."""
    eng = Engine(EngineConfig(comm="ring", n_shards=1, storage="dbs",
                              payload_shape=PAY, n_extents=256, max_pages=64,
                              batch=16))
    vol = eng.create_volume()
    pay = jnp.ones(PAY)

    def write(page, val):
        eng.submit(Request(req_id=page, kind="write", volume=vol, page=page,
                           block=0, payload=val * pay))
        eng.drain()

    write(0, 1.0)
    eng.pool.backend.fail(0, 1)
    write(0, 2.0)                            # replica 1 misses this
    cvol = eng.clone(vol)                    # in-band CLONE SQE
    assert cvol >= 0
    write(0, 3.0)                            # source CoWs away
    eng.pool.backend.rebuild(0, 1)           # host-side streamed delta
    assert eng.pool.backend.consistent()
    eng.pool.backend.fail(0, 0)              # rebuilt replica must serve
    blk = jnp.zeros((1,), jnp.int32)
    np.testing.assert_allclose(np.asarray(eng.pool.read_volume(
        vol, jnp.asarray([0], jnp.int32), blk))[:, 0], 3.0)
    np.testing.assert_allclose(np.asarray(eng.pool.read_volume(
        cvol, jnp.asarray([0], jnp.int32), blk))[:, 0], 2.0)
    eng.pool.backend.rebuild(0, 0)


def test_delta_rebuild_empty_delta_moves_nothing():
    g = _group()
    vol = g.create_volume()
    _w(g, vol, [0, 1], 2.0)
    g.fail(0)
    g.rebuild(0)                             # nothing written while failed
    assert g.transports[0].pages_moved == 0
    assert g.consistent()


def test_delta_rebuild_after_fused_engine_traffic():
    """The fused step stamps watermarks IN-PROGRAM; the host-side streamed
    rebuild must see them."""
    eng = Engine(EngineConfig(comm="fused", storage="dbs", payload_shape=PAY,
                              n_extents=256, max_pages=64, batch=16))
    vol = eng.create_volume()
    pay = jnp.ones(PAY)
    for i in range(24):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=pay))
    eng.drain()
    eng.control("fail", replica=1)
    for i in range(6):                       # replica 1 misses these
        eng.submit(Request(req_id=100 + i, kind="write", volume=vol,
                           page=i, block=0, payload=2 * pay))
    eng.drain()
    g = eng.backend
    moved0 = g.transports[1].pages_moved
    eng.control("rebuild", replica=1)
    assert g.transports[1].pages_moved - moved0 == 6
    assert g.consistent()
    # rebuilt replica's mapped extents are bit-identical to the donor's
    table = np.asarray(jax.device_get(g.replicas[0].state.table))
    ids = np.unique(table[table >= 0])
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(g.replicas[0].pool[ids])),
        np.asarray(jax.device_get(g.replicas[1].pool[ids])))


def test_delta_rebuild_sharded_pool():
    """Per-shard streamed delta through the stacked device transport, after
    vmapped in-program traffic."""
    eng = Engine(EngineConfig(comm="sharded", n_shards=2, storage="dbs",
                              payload_shape=PAY, n_extents=256, max_pages=64,
                              batch=16))
    vols = [eng.create_volume() for _ in range(2)]
    pay = jnp.ones(PAY)
    for i in range(16):
        for v in vols:
            eng.submit(Request(req_id=i * 2 + v, kind="write", volume=v,
                               page=i, block=0, payload=pay))
    eng.drain()
    pool = eng.pool
    sick_shard = vols[0] % 2
    pool.backend.fail(sick_shard, 1)
    for i in range(4):                       # shard 0's replica 1 misses
        eng.submit(Request(req_id=900 + i, kind="write", volume=vols[0],
                           page=i, block=0, payload=3 * pay))
    eng.drain()
    t1 = pool.backend.transports[1]
    moved0 = t1.pages_moved
    pool.backend.rebuild(sick_shard, 1)
    assert t1.pages_moved - moved0 == 4
    assert pool.backend.consistent()
    # other shard untouched by the rebuild: its two replica slices agree
    other = 1 - sick_shard
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(pool.backend.pools[0][other])),
        np.asarray(jax.device_get(pool.backend.pools[1][other])))


# ---------------------------------------------------------------------------
# simnet semantics
# ---------------------------------------------------------------------------
def test_simnet_latency_and_window():
    ep_g = _group()                          # donor of a real endpoint
    t = SimNetTransport(ep_g.replicas[0], latency=3, window=2)
    f1 = t.post(WireMsg(op=transport.MSG_QUERY_REV))
    f2 = t.post(WireMsg(op=transport.MSG_QUERY_REV))
    assert not f1.done and t.pending() == 2
    t.tick(), t.tick()
    assert not f1.done                       # latency 3: not yet
    t.tick()
    assert f1.done and f2.done
    # window backpressure: a third post while two are queued must tick
    # until a slot frees (here: immediately, queue already drained)
    f3 = t.post(WireMsg(op=transport.MSG_QUERY_REV))
    assert t.pending() == 1
    t.drain()
    assert f3.done and t.delivered == 3


def test_simnet_drop_retransmits_in_order():
    g = _group(transport="simnet",
               transport_opts=dict(latency=1, window=4, drop=0.3, seed=7))
    vol = g.create_volume()
    for i in range(8):
        _w(g, vol, [i], float(i + 1))        # policy "all": waits acks
    g.drain_transports()
    assert g.consistent()
    for i in range(8):
        np.testing.assert_allclose(_r(g, vol, [i]), float(i + 1))
    assert any(t.retransmits > 0 for t in g.transports), \
        "drop=0.3 over 30+ deliveries should have retransmitted"


def test_simnet_reorder_injection_delivers_everything():
    g = _group(transport="simnet", write_policy="async",
               transport_opts=dict(latency=1, window=8, reorder=0.5,
                                   seed=3))
    vol = g.create_volume()
    for i in range(6):
        _w(g, vol, [i], 1.0)                 # async: queues build up
    g.drain_transports()
    for t in g.transports:
        assert t.pending() == 0 and t.delivered >= 7   # CREATE + 6 writes


# ---------------------------------------------------------------------------
# write/read policies
# ---------------------------------------------------------------------------
def _straggler_group(**kw):
    return _group(n_replicas=3, transport="simnet",
                  transport_opts=dict(latency=[1, 1, 6], window=4), **kw)


def test_quorum_acks_on_majority_then_converges():
    g = _straggler_group(write_policy="quorum")
    vol = g.create_volume()
    _w(g, vol, [0, 1], 5.0)
    # the two fast links acked; the straggler still holds the write
    assert g.transports[2].pending() >= 1
    g.drain_transports()
    assert g.consistent()
    for rep in range(3):                     # every replica converged
        g._rr = rep                          # steer the rr pick
        np.testing.assert_allclose(_r(g, vol, [0, 1]), 5.0)


def test_async_is_write_behind_and_fifo_read_sees_own_link():
    g = _straggler_group(write_policy="async")
    vol = g.create_volume()
    _w(g, vol, [0], 9.0)
    assert all(t.pending() >= 1 for t in g.transports)   # acked at post
    # a read through any link queues BEHIND that link's write (FIFO)
    np.testing.assert_allclose(_r(g, vol, [0]), 9.0)
    g.drain_transports()
    assert g.consistent()


def test_latency_weighted_reads_avoid_the_straggler():
    g = _straggler_group(read_policy="latency")
    vol = g.create_volume()
    _w(g, vol, [0], 1.0)                     # seeds every link's ewma
    before = g.transports[2].sent["READ"]
    for _ in range(12):
        _r(g, vol, [0])
    assert g.transports[2].sent["READ"] == before, \
        "latency policy must not route reads to the 6x-slower link"
    # and the fast links share them (tie-broken round-robin)
    assert g.transports[0].sent["READ"] > 0
    assert g.transports[1].sent["READ"] > 0


def test_policies_match_all_end_state():
    """Every policy converges to the same replica contents as ``all``."""
    ref = _group(n_replicas=3)
    states = {}
    for policy in ("all", "quorum", "async"):
        g = _straggler_group(write_policy=policy)
        for grp in ((ref,) if policy == "all" else ()) + (g,):
            vol = grp.create_volume()
            for i in range(6):
                _w(grp, vol, [i % 4], float(i))
            grp.drain_transports()
        states[policy] = [np.asarray(jax.device_get(r.pool))
                          for r in g.replicas]
        assert g.consistent()
    for policy in ("quorum", "async"):
        for a, b in zip(states["all"], states[policy]):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# config threading
# ---------------------------------------------------------------------------
def test_engineconfig_threads_transport_to_the_group():
    eng = Engine(EngineConfig(comm="slots", storage="dbs", payload_shape=PAY,
                              transport="simnet", write_policy="quorum",
                              read_policy="latency", n_replicas=3,
                              transport_opts=dict(latency=2, window=16)))
    g = eng.backend
    assert all(isinstance(t, SimNetTransport) for t in g.transports)
    assert g.write_policy == "quorum" and g.read_policy == "latency"
    vol = eng.create_volume()
    pay = jnp.ones(PAY)
    for i in range(8):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=pay))
        eng.submit(Request(req_id=100 + i, kind="read", volume=vol, page=i,
                           block=0))
    assert eng.drain() == 16


def test_inprogram_backends_reject_host_policies():
    for comm in ("fused", "sharded", "ring"):
        with pytest.raises(ValueError, match="write_policy|IN-PROGRAM"):
            Engine(EngineConfig(comm=comm, storage="dbs",
                                write_policy="quorum"))
        with pytest.raises(ValueError):
            Engine(EngineConfig(comm=comm, storage="dbs",
                                read_policy="latency"))


def test_volumemanager_threads_transport():
    with VolumeManager(backend="slots", transport="simnet",
                       write_policy="quorum", n_replicas=3, payload_elems=8,
                       page_blocks=4, max_pages=16,
                       transport_opts=dict(latency=1)) as vm:
        g = vm.engine.backend
        assert all(isinstance(t, SimNetTransport) for t in g.transports)
        v = vm.create()
        v.write(10, b"over the wire")
        assert v.read(10, 13) == b"over the wire"


# ---------------------------------------------------------------------------
# satellites
# ---------------------------------------------------------------------------
def test_iofuture_result_is_cached(monkeypatch):
    """Repeated ``result()`` returns the cached assembly: no re-assemble,
    no re-flush (ISSUE 5 satellite)."""
    vm = VolumeManager(backend="slots", payload_elems=8, page_blocks=4,
                       max_pages=16)
    v = vm.create()
    v.write(0, b"cache me")
    fut = v.pread(0, 8)
    first = fut.result()
    assert first == b"cache me"
    flushes = []
    monkeypatch.setattr(vm, "flush",
                        lambda: (flushes.append(1), 0)[1])
    # poison the underlying requests: a re-assembly would now differ
    for r in fut._reqs:
        r.result = None
    assert fut.result() is first
    assert fut.result() == b"cache me"
    assert flushes == [], "cached result must not drive the pump again"
    assert fut.done()


def test_consistent_batches_revision_fetch(monkeypatch):
    """One device_get for the whole group, not one per healthy replica
    (ISSUE 5 satellite)."""
    g = _group(n_replicas=4)
    vol = g.create_volume()
    _w(g, vol, [0, 1], 1.0)
    gets = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (gets.append(1), real(x))[1])
    assert g.consistent()
    assert len(gets) == 1, f"consistent() fetched {len(gets)} times"


def test_volumemanager_close_drains_inflight():
    """Context-manager exit drains in-flight I/O; the closed manager
    rejects new submissions but keeps futures resolvable (ISSUE 5
    satellite)."""
    with VolumeManager(backend="ring", payload_elems=8, page_blocks=4,
                       max_pages=16) as vm:
        v = vm.create()
        fut = v.pwrite(0, b"bye")
        rfut = v.pread(0, 3)
        assert not fut.done()                # still queued, no flush yet
    assert vm.closed
    assert fut.done() and rfut.done()        # close() drained them
    assert rfut.result() == b"bye"
    assert vm.close() == 0                   # idempotent
    with pytest.raises(ValueError, match="closed"):
        v.pwrite(0, b"nope")
    with pytest.raises(ValueError, match="closed"):
        vm.pread(v, 0, 1)
    with pytest.raises(ValueError, match="closed"):
        vm.create()
    assert vm.flush() == 0                   # flush stays a callable no-op


def test_close_drains_write_behind_transports():
    vm = VolumeManager(backend="slots", transport="simnet",
                       write_policy="async", payload_elems=8, page_blocks=4,
                       max_pages=16, transport_opts=dict(latency=3))
    v = vm.create()
    v.pwrite(0, b"straggler")
    vm.close()
    g = vm.engine.backend
    assert all(t.pending() == 0 for t in g.transports)
    assert g.consistent()
