"""The SQ/CQ ring protocol (core/ring.py).

Contracts:

1. **data path** — ``comm="ring"`` reaches byte-identical volume contents
   vs ``comm="fused"`` on a mixed CoW workload, and delivers read results
   with status/latency from the CQ.
2. **in-band control** — a random interleaving of WRITE/SNAPSHOT/CLONE/
   UNMAP submitted through the ring is bit-identical to the host-side
   ``dbs.snapshot/clone/unmap`` sequential reference (full DBS metadata,
   revision counter excepted — its granularity is per-program by design)
   and content-identical to the ``ChainedStore`` reference walk.
3. **in-band FAIL/REBUILD** — mid-drain on the sharded pool, exact: data
   intact, the rebuilt replica serves missed writes, protocol violations
   surface as CQE statuses without mutating the health mask.
4. **dispatch accounting** — one traced program per (batch geometry, class
   signature), no extra host dispatch per control op, and exactly one
   ``device_get`` per pump even with control lanes aboard.
5. satellites: unified ``Request.result``/``status`` across every comm
   mode; ``ChainedReplicas`` volume-id agreement and null-storage rr fixes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request, UpstreamEngine, dbs
from repro.core.ring import (ST_ERR, ST_HEALTHY, ST_LAST, ST_OK,
                             RingEngine)

PAY = (8,)


def _cfg(**kw):
    base = dict(comm="ring", storage="dbs", payload_shape=PAY, n_extents=256,
                max_pages=64, batch=16, n_replicas=2, n_shards=1,
                max_volumes=16)
    base.update(kw)
    return EngineConfig(**base)


def _pay(v: float) -> jnp.ndarray:
    return jnp.full(PAY, float(v))


# ---------------------------------------------------------------------------
# host-side sequential reference: one DBSState+pool driven op by op
# ---------------------------------------------------------------------------
class HostRef:
    def __init__(self, n_extents=256, max_volumes=16, max_pages=64,
                 page_blocks=32):
        self.st = dbs.make_state(n_extents, max_volumes, max_pages)
        self.pool = jnp.zeros((n_extents + 1, page_blocks) + PAY, jnp.float32)

    def write(self, vol, page, block, payload):
        self.st, ops = dbs.write_pages(
            self.st, jnp.int32(vol), jnp.asarray([page], jnp.int32),
            jnp.asarray([1 << block], jnp.uint32), jnp.asarray([True]))
        self.pool = dbs.apply_write_ops(self.pool, ops, payload[None],
                                        jnp.asarray([block], jnp.int32))

    def snapshot(self, vol):
        self.st, sid = dbs.snapshot(self.st, jnp.int32(vol))
        return int(sid)

    def clone(self, vol):
        self.st, vid = dbs.clone(self.st, jnp.int32(vol))
        return int(vid)

    def unmap(self, vol, page):
        self.st = dbs.unmap(self.st, jnp.int32(vol),
                            jnp.asarray([page], jnp.int32))

    def delete(self, vol):
        self.st = dbs.delete_volume(self.st, jnp.int32(vol))

    def read(self, vol, page, block):
        ext = int(self.st.table[vol, page])
        if ext < 0:
            return np.zeros(PAY, np.float32)
        return np.asarray(self.pool[ext, block])


def _ring_state(eng, replica):
    """Shard 0's replica state/pool of a ring engine (S=1 tests)."""
    st = jax.tree.map(lambda x: x[0], eng.pool.backend.states[replica])
    return st, eng.pool.backend.pools[replica][0]


def _assert_states_equal(a: dbs.DBSState, b: dbs.DBSState, msg=""):
    """Bit-exact DBS metadata equality, revision excepted (the ring bumps it
    once per batched write_pages, the sequential reference once per op)."""
    for f in dataclasses.fields(dbs.DBSState):
        if f.name == "revision":
            continue
        for la, lb in zip(jax.tree.leaves(getattr(a, f.name)),
                          jax.tree.leaves(getattr(b, f.name))):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=f"{msg} field {f.name}")


def _masked_read(st: dbs.DBSState, pool, vol, page, block):
    ext = int(st.table[vol, page])
    if ext < 0:
        return np.zeros(PAY, np.float32)
    return np.asarray(pool[ext, block])


# ---------------------------------------------------------------------------
# 1. data path: ring == fused, results delivered from the CQ
# ---------------------------------------------------------------------------
def test_ring_matches_fused_volume_contents():
    engs = [Engine(_cfg(comm="fused")), Engine(_cfg())]
    vols = [e.create_volume() for e in engs]
    for i in range(60):
        for e, v in zip(engs, vols):
            e.submit(Request(req_id=i, kind="write", volume=v, page=i % 48,
                             block=i % 8, payload=_pay(i + 1)))
    for e in engs:
        assert e.drain() == 60
    for e, v in zip(engs, vols):
        e.snapshot(v)
    for i in range(30):                      # CoW overwrites + reads mixed in
        for e, v in zip(engs, vols):
            e.submit(Request(req_id=i, kind="write", volume=v, page=i % 24,
                             block=(i * 3) % 8, payload=_pay(1000 + i)))
            e.submit(Request(req_id=i + 500, kind="read", volume=v,
                             page=i % 24, block=0))
    assert [e.drain() for e in engs] == [60, 60]
    pages = jnp.arange(48, dtype=jnp.int32)
    for blk in range(8):
        offs = jnp.full((48,), blk, jnp.int32)
        a = engs[0].backend.read(vols[0], pages, offs)
        b = engs[1].pool.read_volume(vols[1], pages, offs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=f"block {blk}")
    assert engs[1].pool.backend.consistent()


def test_ring_read_results_status_latency():
    eng = Engine(_cfg())
    vol = eng.create_volume()
    w = Request(req_id=0, kind="write", volume=vol, page=3, block=2,
                payload=_pay(7))
    eng.submit(w)
    eng.drain()
    r = Request(req_id=1, kind="read", volume=vol, page=3, block=2)
    eng.submit(r)
    eng.drain()
    np.testing.assert_allclose(np.asarray(r.result), np.full(PAY, 7.0))
    assert w.status == ST_OK and r.status == ST_OK
    assert w.latency == 1 and r.latency == 1


def test_ring_latency_counts_queueing_ticks():
    """Under slot pressure the drain caps at the slot count, so later lanes
    ride a later pump — the CQE latency (pump ticks) records the wait."""
    eng = Engine(_cfg(n_slots=4, batch=8))
    vol = eng.create_volume()
    reqs = [Request(req_id=i, kind="write", volume=vol, page=i, block=0,
                    payload=_pay(i)) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    assert eng.drain() == 8
    lats = sorted(r.latency for r in reqs)
    assert lats[0] == 1 and lats[-1] > 1    # 4 slots: half requeued at least


def test_requeue_preserves_queue_order():
    """Slot pressure must never reorder a queue: the drain caps at the slot
    count (a transact pump starts with every slot free, so a capped batch
    cannot starve), and any requeue path restores back-to-front."""
    eng = Engine(_cfg(n_queues=1, n_slots=4, batch=8))
    reqs = [Request(req_id=i, kind="noop") for i in range(8)]
    for r in reqs:
        eng.submit(r)
    assert eng.pool.pump() == 4             # capped at the 4 slots
    q = eng.pool.frontend.queues[0][0]
    assert [r.req_id for r in q] == [4, 5, 6, 7]
    assert eng.drain() == 4
    # requeue_all restores submission order even for an arbitrary batch
    eng.pool.frontend.requeue_all(reqs[:3])
    assert [r.req_id for r in q] == [0, 1, 2]


def test_overwrite_order_survives_slot_pressure():
    """Writes past the slot count land on later pumps — never behind a
    LATER submission (the pipelined drain launches N+1 before completing N,
    so a starved suffix of N re-entering the queues would execute after
    N+1, out of submission order; the drain cap makes that impossible)."""
    eng = Engine(_cfg(n_queues=1, n_slots=4, batch=8))
    vol = eng.create_volume()
    for i in range(8):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=_pay(100 + i)))
    for i in range(4):                      # overwrite pages 4..7
        eng.submit(Request(req_id=8 + i, kind="write", volume=vol,
                           page=4 + i, block=0, payload=_pay(200 + i)))
    assert eng.drain() == 12
    got = np.asarray(eng.pool.read_volume(
        vol, jnp.arange(8, dtype=jnp.int32), jnp.zeros(8, jnp.int32)))
    np.testing.assert_allclose(
        got[:, 0], [100, 101, 102, 103, 200, 201, 202, 203])


def test_ring_noop_barrier_completes():
    eng = Engine(_cfg())
    r = Request(req_id=0, kind="noop")
    eng.submit(r)
    assert eng.drain() == 1
    assert r.status == ST_OK


def test_ring_null_rows_complete():
    for kw in (dict(null_backend=True), dict(null_storage=True)):
        eng = Engine(_cfg(**kw))
        vol = eng.create_volume()
        for i in range(40):
            eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                               volume=vol, page=i % 64, block=0,
                               payload=jnp.ones(PAY)))
        assert eng.drain() == 40, kw


# ---------------------------------------------------------------------------
# 2. in-band control == host-side sequence == chained-store walk
# ---------------------------------------------------------------------------
def _interleaving(seed, n_ops, n_base=3, pages=48):
    """Random op stream. Writes draw pages from a per-volume permutation so
    no (vol, page) pair repeats within an admission batch window (the
    documented write_pages batch precondition, same as the fused path)."""
    rng = np.random.default_rng(seed)
    perm = {v: rng.permutation(pages) for v in range(n_base)}
    counters = {v: 0 for v in range(n_base)}
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        vol = int(rng.integers(0, n_base))
        if r < 0.72:
            page = int(perm[vol][counters[vol] % pages])
            counters[vol] += 1
            ops.append(("write", vol, page, int(rng.integers(0, 8))))
        elif r < 0.84:
            ops.append(("snapshot", vol))
        elif r < 0.92:
            ops.append(("clone", vol))
        else:
            ops.append(("unmap", vol, int(perm[vol][rng.integers(0, pages)])))
    return ops


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_inband_control_matches_host_sequence_and_chained_walk(seed):
    from repro.core.engine import ChainedStore
    n_base, pages = 3, 48
    ops = _interleaving(seed, 110, n_base, pages)

    # ring engine: everything (data AND control) through the one SQE path;
    # n_queues=1 keeps a single totally-ordered submission stream
    eng = Engine(_cfg(n_queues=1, n_slots=256, max_pages=pages,
                      page_blocks=32))
    ring_vols = [eng.create_volume() for _ in range(n_base)]
    assert ring_vols == list(range(n_base))
    ctl_reqs = []
    for i, op in enumerate(ops):
        if op[0] == "write":
            _, vol, page, block = op
            eng.submit(Request(req_id=i, kind="write", volume=vol, page=page,
                               block=block, payload=_pay(i + 1)))
        else:
            kind, vol = op[0], op[1]
            r = Request(req_id=i, kind=kind, volume=vol,
                        page=op[2] if kind == "unmap" else 0)
            ctl_reqs.append((op, r))
            eng.submit(r)
    assert eng.drain() == len(ops)

    # host-side sequential reference + chained-store reference walk
    ref = HostRef(max_pages=pages)
    chained = ChainedStore(PAY)
    ref_ids, clone_map = [], {}          # ring vol -> chained vol
    for v in range(n_base):
        ref.st, _ = dbs.create_volume(ref.st)
        clone_map[v] = chained.create_volume()
    for i, op in enumerate(ops):
        if op[0] == "write":
            _, vol, page, block = op
            ref.write(vol, page, block, _pay(i + 1))
            chained.write(clone_map[vol], page, block,
                          np.asarray(_pay(i + 1)))
        elif op[0] == "snapshot":
            ref_ids.append(("snapshot", ref.snapshot(op[1])))
            chained.snapshot(clone_map[op[1]])
        elif op[0] == "clone":
            vid = ref.clone(op[1])
            ref_ids.append(("clone", vid))
            if vid >= 0:
                clone_map[vid] = chained.clone(clone_map[op[1]])
        else:
            ref.unmap(op[1], op[2])
            chained.unmap(clone_map[op[1]], op[2])

    # control results returned through the CQ match the reference ids
    got_ids = [(op[0], r.result) for op, r in ctl_reqs
               if op[0] in ("snapshot", "clone")]
    assert got_ids == ref_ids

    # bit-exact DBS metadata (both mirrored replicas) vs the reference
    for rep in range(2):
        st, pool = _ring_state(eng, rep)
        _assert_states_equal(st, ref.st, msg=f"replica {rep} seed {seed}")
        np.testing.assert_array_equal(np.asarray(pool), np.asarray(ref.pool),
                                      err_msg=f"pool {rep} seed {seed}")

    # content-identical to the chained-store reference walk (holes = zeros)
    st0, pool0 = _ring_state(eng, 0)
    all_vols = [v for v in clone_map]
    for vol in all_vols:
        for page in range(pages):
            for block in range(0, 8, 3):
                got = _masked_read(st0, pool0, vol, page, block)
                want = chained.read(clone_map[vol], page, block)
                want = (np.zeros(PAY, np.float32) if want is None
                        else np.asarray(want))
                np.testing.assert_allclose(
                    got, want,
                    err_msg=f"vol {vol} page {page} block {block}")


def test_inband_delete_matches_host_sequence():
    eng = Engine(_cfg(n_queues=1))
    ref = HostRef()
    va = eng.create_volume()
    vb = eng.create_volume()
    for _ in range(2):
        ref.st, _ = dbs.create_volume(ref.st)
    for i in range(12):
        vol = va if i % 2 else vb
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=_pay(i + 1)))
        ref.write(vol, i, 0, _pay(i + 1))
    eng.drain()
    eng.delete_volume(va)
    ref.delete(va)
    # deleting A freed its extents and left B intact — and the freed ids
    # recycle identically: create a new volume and write through it
    vc = eng.create_volume()
    ref.st, _ = dbs.create_volume(ref.st)
    assert vc == va                        # first free volume slot reused
    for i in range(6):
        eng.submit(Request(req_id=100 + i, kind="write", volume=vc, page=i,
                           block=1, payload=_pay(50 + i)))
        ref.write(vc, i, 1, _pay(50 + i))
    eng.drain()
    for rep in range(2):
        st, pool = _ring_state(eng, rep)
        _assert_states_equal(st, ref.st, msg=f"replica {rep}")
        np.testing.assert_array_equal(np.asarray(pool), np.asarray(ref.pool))


def test_inband_control_error_statuses():
    eng = Engine(_cfg())
    r = Request(req_id=0, kind="snapshot", volume=9)    # never created
    eng.submit(r)
    eng.drain()
    assert r.status == ST_ERR and r.result == -1


def test_control_failure_surface_matches_host_modes():
    """snapshot/clone of a dead volume report -1 on every comm mode — the
    ring's sync wrappers must not grow their own error surface."""
    ring = Engine(_cfg(n_shards=2))
    pool = Engine(_cfg(comm="sharded", n_shards=2))
    for eng in (ring, pool):
        eng.create_volume()
        assert eng.snapshot(9) == -1 or eng.snapshot(9) is None
        assert eng.clone(9) == -1


# ---------------------------------------------------------------------------
# 3. in-band FAIL/REBUILD on the sharded pool, mid-drain
# ---------------------------------------------------------------------------
def test_inband_fail_rebuild_mid_drain_sharded():
    eng = Engine(_cfg(n_shards=3))
    pool = eng.pool
    assert isinstance(pool, RingEngine)
    vols = [eng.create_volume() for _ in range(3)]
    for i in range(60):
        eng.submit(Request(req_id=i, kind="write", volume=vols[i % 3],
                           page=i % 20, block=0, payload=_pay(i + 1)))
    assert eng.drain() == 60
    baseline = {v: np.asarray(pool.read_volume(
        v, jnp.arange(20, dtype=jnp.int32), jnp.zeros(20, jnp.int32)))
        for v in vols}

    sick = vols[1] % 3
    fail_req = Request(req_id=99, kind="fail", shard=sick, block=0)
    reqs = []
    for i in range(30):                     # traffic everywhere, fail inline
        if i == 11:
            reqs.append(fail_req)
        reqs.append(Request(req_id=100 + i, kind="write", volume=vols[i % 3],
                            page=20 + (i % 10), block=0,
                            payload=_pay(200 + i)))
        reqs.append(Request(req_id=500 + i, kind="read", volume=vols[i % 3],
                            page=i % 20, block=0))
    for r in reqs:
        eng.submit(r)
    assert eng.drain() == 61
    assert fail_req.status == ST_OK
    assert not pool.backend.healthy[sick, 0]
    for s in range(3):
        if s != sick:
            assert pool.backend.consistent(s)
    for v in vols:                          # old data intact everywhere
        got = np.asarray(pool.read_volume(
            v, jnp.arange(20, dtype=jnp.int32), jnp.zeros(20, jnp.int32)))
        np.testing.assert_allclose(got, baseline[v])

    # in-band rebuild, then force reads from the rebuilt replica: it must
    # serve the writes it missed while failed
    reb = Request(req_id=600, kind="rebuild", shard=sick, block=0)
    eng.submit(reb)
    assert eng.drain() == 1
    assert reb.status == ST_OK and pool.backend.consistent()
    pool.fail(sick, 1)
    got = np.asarray(pool.read_volume(
        vols[1], jnp.asarray([25], jnp.int32), jnp.zeros(1, jnp.int32)))
    assert got[0][0] >= 200.0
    pool.rebuild(sick, 1)
    assert pool.backend.healthy.all()


def test_inband_fail_rebuild_protocol_errors():
    eng = Engine(_cfg(n_shards=2))
    pool = eng.pool
    eng.create_volume()
    # rebuild of a healthy replica: CQE status, mask untouched
    r = Request(req_id=0, kind="rebuild", shard=0, block=0)
    eng.submit(r)
    eng.drain()
    assert r.status == ST_HEALTHY and pool.backend.healthy.all()
    # failing down to the last healthy replica: rejected in-band
    pool.fail(0, 0)
    r2 = Request(req_id=1, kind="fail", shard=0, block=1)
    eng.submit(r2)
    eng.drain()
    assert r2.status == ST_LAST
    assert pool.backend.healthy[0, 1]       # mask unchanged
    # the sync wrappers raise like the host-side controller
    with pytest.raises(RuntimeError):
        pool.fail(0, 1)
    with pytest.raises(ValueError):
        pool.rebuild(0, 1)
    with pytest.raises(IndexError):
        pool.fail(9, 0)
    pool.rebuild(0, 0)
    assert pool.backend.healthy.all()


# ---------------------------------------------------------------------------
# 4. dispatch accounting: in-band means IN the program
# ---------------------------------------------------------------------------
def test_one_program_per_class_signature_no_control_retrace():
    eng = Engine(_cfg(n_shards=2))
    pool = eng.pool
    vols = [eng.create_volume() for _ in range(4)]

    def traffic(base):
        for i in range(40):
            v = vols[i % 4]
            if i % 3 == 0:
                eng.submit(Request(req_id=base + i, kind="read", volume=v,
                                   page=i % 32, block=0))
            else:
                eng.submit(Request(req_id=base + i, kind="write", volume=v,
                                   page=i % 32, block=i % 8,
                                   payload=_pay(i)))
        eng.submit(Request(req_id=base + 90, kind="snapshot", volume=vols[0]))
        eng.submit(Request(req_id=base + 91, kind="unmap", volume=vols[1],
                           page=2))
    traffic(0)
    assert eng.drain() == 42
    assert all(v == 1 for v in pool.trace_counts.values()), pool.trace_counts
    before = dict(pool.trace_counts)
    d0 = pool.dispatches
    # more traffic with MORE control ops: no new programs, one dispatch per
    # pump — control ops cost zero extra host dispatches
    traffic(1000)
    assert eng.drain() == 42
    assert pool.trace_counts == before
    assert pool.dispatches > d0


def test_ring_pump_is_single_host_hop_with_control_aboard(monkeypatch):
    eng = Engine(_cfg(n_queues=1))   # one queue: the whole stream + its
                                     # control tail fit one ordered batch
    vol = eng.create_volume()
    # warm every program this traffic shape needs
    eng.submit(Request(req_id=0, kind="write", volume=vol, page=0, block=0,
                       payload=_pay(1)))
    eng.submit(Request(req_id=1, kind="snapshot", volume=vol))
    eng.drain()
    for i in range(6):
        eng.submit(Request(req_id=10 + i, kind="write", volume=vol,
                           page=1 + i, block=0, payload=_pay(i)))
    eng.submit(Request(req_id=20, kind="snapshot", volume=vol))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    done = eng.pool.pump()
    assert done == 7
    assert len(calls) == 1, f"expected 1 completion fetch, saw {len(calls)}"


# ---------------------------------------------------------------------------
# 5. satellite: unified result/status across every comm mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("comm,storage,shards", [
    ("loop", "chained", 1), ("loop", "dbs", 1),
    ("slots", "chained", 1), ("slots", "dbs", 1),
    ("fused", "dbs", 1), ("sharded", "dbs", 2), ("ring", "dbs", 2),
])
def test_result_status_unified_across_comms(comm, storage, shards):
    eng = Engine(EngineConfig(comm=comm, storage=storage, payload_shape=PAY,
                              n_extents=256, max_pages=64, batch=16,
                              n_replicas=2, n_shards=shards, max_volumes=16))
    vol = eng.create_volume()
    w = Request(req_id=0, kind="write", volume=vol, page=1, block=2,
                payload=_pay(7))
    eng.submit(w)
    assert eng.drain() == 1
    r = Request(req_id=1, kind="read", volume=vol, page=1, block=2)
    eng.submit(r)
    assert eng.drain() == 1
    assert w.status == 0 and r.status == 0
    np.testing.assert_allclose(np.asarray(r.result), np.full(PAY, 7.0))


@pytest.mark.parametrize("comm,storage,shards", [
    ("loop", "chained", 1), ("loop", "dbs", 1),
    ("slots", "chained", 1), ("slots", "dbs", 1),
    ("fused", "dbs", 1), ("sharded", "dbs", 2), ("ring", "dbs", 2),
    ("upstream", "dbs", 1), ("host", "dbs", 1),
])
def test_latency_unified_across_comms(comm, storage, shards):
    """Satellite (ISSUE 4): ``Request.latency`` is populated — in pump
    ticks — on EVERY comm mode, not just the ring's CQE path. A lone
    request completes at latency 1; under slot pressure (or the upstream/
    host one-op-per-tick loop) later requests ride later ticks."""
    eng = Engine(EngineConfig(comm=comm, storage=storage, payload_shape=PAY,
                              n_extents=256, max_pages=64, batch=8,
                              n_slots=4, n_replicas=2, n_shards=shards,
                              max_volumes=16))
    vol = eng.create_volume()
    w = Request(req_id=0, kind="write", volume=vol, page=1, block=2,
                payload=np.full(PAY, 7.0, np.float32))
    eng.submit(w)
    assert eng.drain() == 1
    assert w.latency == 1, (comm, w.latency)
    reqs = [Request(req_id=i, kind="write", volume=vol, page=2 + i, block=0,
                    payload=np.full(PAY, float(i), np.float32))
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    assert eng.drain() == 8
    lats = sorted(r.latency for r in reqs)
    assert all(l is not None and l >= 1 for l in lats), (comm, lats)
    assert lats[-1] > lats[0], (comm, lats)   # 4 slots / 1-op ticks: queueing
    rd = Request(req_id=100, kind="read", volume=vol, page=1, block=2)
    eng.submit(rd)
    assert eng.drain() == 1
    assert rd.latency is not None and rd.latency >= 1


def test_result_status_upstream_engine():
    eng = UpstreamEngine(EngineConfig(payload_shape=PAY))
    vol = eng.create_volume()
    w = Request(req_id=0, kind="write", volume=vol, page=1, block=2,
                payload=np.full(PAY, 7.0))
    eng.submit(w)
    eng.drain()
    r = Request(req_id=1, kind="read", volume=vol, page=1, block=2)
    eng.submit(r)
    eng.drain()
    assert w.status == 0 and r.status == 0
    np.testing.assert_allclose(np.asarray(r.result), np.full(PAY, 7.0))


def test_control_kinds_rejected_off_ring():
    eng = Engine(_cfg(comm="fused"))
    eng.create_volume()
    with pytest.raises(ValueError):
        eng.submit(Request(req_id=0, kind="snapshot", volume=0))
    # rejection happens at SUBMIT, not mid-drain: a drain-time failure
    # would already have popped (and then lost) innocent data requests
    pool = Engine(_cfg(comm="sharded", n_shards=2))
    vol = pool.create_volume()
    pool.frontend.submit(Request(req_id=1, kind="write", volume=vol,
                                 page=0, payload=_pay(1)))
    with pytest.raises(ValueError):
        pool.frontend.submit(Request(req_id=2, kind="snapshot", volume=vol))
    assert pool.frontend.depth() == 1       # the data request is intact
    assert pool.drain() == 1


def test_chained_store_control_ops_noop_on_miss():
    """The chained reference baseline must not diverge into KeyErrors where
    the DBS path completes harmlessly (delete-then-anything sequences)."""
    from repro.core.engine import ChainedStore
    cs = ChainedStore(PAY)
    v = cs.create_volume()
    cs.write(v, 0, 0, np.ones(PAY))
    cs.delete_volume(v)
    cs.delete_volume(v)                     # second delete: no-op
    cs.snapshot(v)
    cs.unmap(v, 0)
    assert cs.clone(v) == -1
    assert cs.read(v, 0, 0) is None


# ---------------------------------------------------------------------------
# 6. satellite: ChainedReplicas volume-id agreement + null-storage rr
# ---------------------------------------------------------------------------
def test_chained_replicas_detects_divergent_volume_ids():
    eng = Engine(EngineConfig(storage="chained", comm="slots",
                              payload_shape=PAY))
    eng.create_volume()                     # in agreement: fine
    eng.backend.stores[1].create_volume()   # one store drifts ahead
    with pytest.raises(RuntimeError):
        eng.create_volume()
    with pytest.raises(RuntimeError):
        eng.backend.clone(0)                # clone ids guarded too


def test_chained_null_storage_read_leaves_rr_alone():
    eng = Engine(EngineConfig(storage="chained", comm="slots",
                              null_storage=True, payload_shape=PAY))
    vol = eng.create_volume()
    b = eng.backend
    before = b._rr
    assert b.read(vol, [0, 1], [0, 0]) is None
    assert b.read(vol, [2], [0]) is None
    assert b._rr == before, "null-storage reads must not burn the rr cursor"
    # with real storage the cursor advances as before
    eng2 = Engine(EngineConfig(storage="chained", comm="slots",
                               payload_shape=PAY))
    v2 = eng2.create_volume()
    r0 = eng2.backend._rr
    eng2.backend.read(v2, [0], [0])
    assert eng2.backend._rr == r0 + 1


# ---------------------------------------------------------------------------
# ladder integration
# ---------------------------------------------------------------------------
def test_ladder_has_ring_column():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.ladder import COLUMNS, make_engine
    assert "+ring" in COLUMNS
    eng = make_engine("+ring", "full_engine", payload_shape=PAY,
                      max_pages=64, n_extents=256, n_shards=2)
    assert eng.cfg.comm == "ring"
    vols = [eng.create_volume() for _ in range(2)]
    for i in range(24):
        eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                           volume=vols[i % 2], page=i % 32, block=i % 8,
                           payload=jnp.ones(PAY)))
    assert eng.drain() == 24
