"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dbs_copy import dbs_copy, dbs_copy_reference
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_reference)
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_reference)
from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_scan_reference

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd,win,cap", [
    (2, 256, 4, 2, 64, 0, 0.0),
    (1, 512, 8, 2, 128, 128, 50.0),
    (2, 128, 4, 4, 64, 0, 30.0),
    (1, 384, 6, 1, 64, 96, 0.0),      # odd seq (384 = 3*128), MQA
])
def test_flash_attention_sweep(b, s, h, kv, hd, win, cap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, window=win, logit_cap=cap)
    ref = flash_attention_reference(q, k, v, window=win, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,page,p,win,cap", [
    (2, 4, 2, 64, 8, 6, 0, 0.0),
    (3, 8, 4, 128, 16, 4, 24, 50.0),
    (2, 4, 1, 64, 8, 5, 0, 30.0),
    (1, 16, 16, 64, 32, 3, 0, 0.0),   # MHA (kv == h), paper page size
])
def test_paged_attention_sweep(b, h, kv, hd, page, p, win, cap, dtype):
    e = b * p + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    pk = jax.random.normal(ks[1], (e, page, kv, hd), dtype)
    pv = jax.random.normal(ks[2], (e, page, kv, hd), dtype)
    bt = jax.random.permutation(ks[3], jnp.arange(e))[:b * p]
    bt = bt.reshape(b, p).astype(jnp.int32)
    lengths = jnp.asarray([(p * page) - (i * 3 + 1) % (p * page - 1)
                           for i in range(b)], jnp.int32)
    out = paged_attention(q, pk, pv, bt, lengths, window=win, logit_cap=cap)
    ref = paged_attention_reference(q, pk, pv, bt, lengths, window=win,
                                    logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,h,hd,chunk", [
    (2, 128, 3, 64, 32), (1, 64, 2, 32, 64), (2, 96, 4, 16, 16),
])
def test_rwkv6_scan_sweep(b, s, h, hd, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5)
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    y, s_f = rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    yr, sr = rwkv6_scan_reference(r, k, v, logw, u,
                                  jnp.zeros((b, h, hd, hd)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(sr),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("e,page,d,n", [(16, 8, 32, 4), (8, 4, 16, 4)])
def test_dbs_copy_sweep(e, page, d, n):
    ks = jax.random.split(KEY, 4)
    pool = jax.random.normal(ks[0], (e, page, d))
    src = jax.random.randint(ks[1], (n,), 0, e // 2)
    dst = (jnp.arange(n) + e // 2).astype(jnp.int32)
    mask = jax.random.bernoulli(ks[2], 0.7, (n,))
    out = dbs_copy(pool, src, dst, mask)
    ref = dbs_copy_reference(pool, src, dst, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
    # untouched extents really untouched
    touched = set(int(x) for x in np.asarray(dst))
    for i in range(e):
        if i not in touched:
            np.testing.assert_allclose(np.asarray(out[i]),
                                       np.asarray(pool[i]))


def test_dbs_copy_shim_reexports_dbs_package():
    """`kernels/dbs_copy` is a deprecation shim over `kernels/dbs`: same
    objects, not copies (so monkeypatching/config hits one implementation)."""
    from repro.kernels import dbs as pkg
    from repro.kernels import dbs_copy as shim
    assert shim.dbs_copy is pkg.dbs_copy
    assert shim.dbs_copy_pool is pkg.dbs_copy_pool
    assert shim.dbs_copy_reference is pkg.dbs_copy_reference
    from repro.kernels.dbs_copy import ops as shim_ops
    from repro.kernels.dbs import ops as pkg_ops
    assert shim_ops.dbs_copy is pkg_ops.dbs_copy
    assert shim_ops.default_interpret is pkg_ops.default_interpret


def test_dbs_copy_shim_warns_deprecation_on_import():
    """A fresh import of the shim emits DeprecationWarning pointing at
    ``repro.kernels.dbs``, and still re-exports the real objects."""
    import importlib
    import sys
    sys.modules.pop("repro.kernels.dbs_copy", None)
    try:
        with pytest.warns(DeprecationWarning, match=r"repro\.kernels\.dbs"):
            shim = importlib.import_module("repro.kernels.dbs_copy")
    finally:
        # leave a fully-initialised module behind for later tests
        if "repro.kernels.dbs_copy" not in sys.modules:
            importlib.import_module("repro.kernels.dbs_copy")
        shim = sys.modules["repro.kernels.dbs_copy"]
    from repro.kernels import dbs as pkg
    assert shim.dbs_copy is pkg.dbs_copy
    assert shim.dbs_copy_pool is pkg.dbs_copy_pool
    assert shim.dbs_copy_reference is pkg.dbs_copy_reference
