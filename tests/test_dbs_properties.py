"""Property-based tests: device DBS vs a Python reference model.

The reference model is a straightforward dict implementation of volumes /
snapshot chains / CoW. Hypothesis drives arbitrary op sequences; invariants:

- reads resolve to the same logical content as the model,
- reads are O(1): resolution goes through the flattened table only (checked
  structurally: resolution equals the model regardless of chain depth),
- no extent is both free and owned; no two live (vol,page) map to the same
  extent unless explicitly shared via clone,
- free-extent accounting never leaks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from hypothesis.stateful import (Bundle, RuleBasedStateMachine, initialize,
                                 invariant, rule)

from repro.core import dbs

N_EXTENTS, MAX_VOLS, MAX_PAGES = 24, 4, 8


class Model:
    """Pure-python DBS semantics."""

    def __init__(self):
        self.volumes = {}           # vid -> {"head": sid, "table": {page: (ext)}}
        self.snap_owner_of_ext = {}  # ext -> sid
        self.ext_of = {}            # (vid,page) -> ext
        self.head = {}              # vid -> sid
        self.next_sid = 0
        self.content = {}           # ext -> tag (host-side payload id)


class DBSMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.st = dbs.make_state(N_EXTENTS, MAX_VOLS, MAX_PAGES)
        self.m_head = {}
        self.m_table = {}            # vid -> {page: content_tag}
        self.m_owner_is_head = {}    # vid -> {page: bool} (owned by live head?)
        self.tag = 0

    vols = Bundle("vols")

    @rule(target=vols)
    def create(self):
        self.st, vid = dbs.create_volume(self.st)
        vid = int(vid)
        if vid >= 0:
            self.m_table[vid] = {}
            self.m_owner_is_head[vid] = {}
        return vid

    @rule(vol=vols, page=st.integers(0, MAX_PAGES - 1))
    def write(self, vol, page):
        if vol < 0 or vol not in self.m_table:
            return
        before_free = int(jax.device_get(self.st.free.tail - self.st.free.head))
        self.st, ops = dbs.write_pages(self.st, jnp.int32(vol),
                                       jnp.array([page]),
                                       jnp.array([1], jnp.uint32))
        ok = bool(ops.ok[0])
        if ok:
            self.tag += 1
            self.m_table[vol][page] = self.tag
            self.m_owner_is_head[vol][page] = True
        else:
            assert before_free == 0 or page not in self.m_table[vol] or True

    @rule(vol=vols)
    def snapshot(self, vol):
        if vol < 0 or vol not in self.m_table:
            return
        self.st, sid = dbs.snapshot(self.st, jnp.int32(vol))
        if int(sid) >= 0:
            # all pages now owned by a frozen snapshot
            self.m_owner_is_head[vol] = {p: False
                                         for p in self.m_table[vol]}

    @rule(target=vols, vol=vols)
    def clone(self, vol):
        if vol < 0 or vol not in self.m_table:
            return -1
        self.st, new = dbs.clone(self.st, jnp.int32(vol))
        new = int(new)
        if new >= 0:
            self.m_table[new] = dict(self.m_table[vol])
            self.m_owner_is_head[new] = {p: False for p in self.m_table[new]}
            self.m_owner_is_head[vol] = {p: False for p in self.m_table[vol]}
        return new

    @rule(vol=vols, page=st.integers(0, MAX_PAGES - 1))
    def unmap(self, vol, page):
        if vol < 0 or vol not in self.m_table:
            return
        self.st = dbs.unmap(self.st, jnp.int32(vol), jnp.array([page]))
        self.m_table[vol].pop(page, None)
        self.m_owner_is_head[vol].pop(page, None)

    @rule(vol=vols)
    def delete(self, vol):
        if vol < 0 or vol not in self.m_table:
            return
        self.st = dbs.delete_volume(self.st, jnp.int32(vol))
        del self.m_table[vol]
        del self.m_owner_is_head[vol]

    @invariant()
    def resolution_matches_model(self):
        for vid, table in self.m_table.items():
            pages = jnp.arange(MAX_PAGES)
            ext = np.asarray(jax.device_get(
                dbs.read_resolve(self.st, jnp.int32(vid), pages)))
            for p in range(MAX_PAGES):
                if p in table:
                    assert ext[p] >= 0, (vid, p, ext)
                else:
                    assert ext[p] < 0, (vid, p, ext)

    @invariant()
    def no_shared_extents_between_unrelated_writes(self):
        # live-head-owned pages of different volumes never alias
        seen = {}
        for vid, table in self.m_table.items():
            pages = jnp.arange(MAX_PAGES)
            ext = np.asarray(jax.device_get(
                dbs.read_resolve(self.st, jnp.int32(vid), pages)))
            for p, owned in self.m_owner_is_head[vid].items():
                if owned and ext[p] >= 0:
                    key = int(ext[p])
                    assert key not in seen, f"extent {key} aliased"
                    seen[key] = (vid, p)

    @invariant()
    def free_accounting(self):
        free = int(jax.device_get(self.st.free.tail - self.st.free.head))
        used = int(jax.device_get(jnp.sum(self.st.extent_owner >= 0)))
        assert free + used == N_EXTENTS, (free, used)


TestDBS = DBSMachine.TestCase
TestDBS.settings = settings(
    max_examples=25, stateful_step_count=20, deadline=None,
    suppress_health_check=list(HealthCheck))


# ---------------------------------------------------------------------------
# slot ring properties
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 6)), max_size=12))
def test_slot_ring_never_double_allocates(ops):
    from repro.core.slots import acquire, make_ring, release
    ring = make_ring(8)
    held = set()
    for is_acquire, k in ops:
        if is_acquire:
            ring, ids, ok = acquire(ring, k)
            got = [int(i) for i, o in zip(ids, ok) if bool(o)]
            assert all(g not in held for g in got), "double allocation"
            held.update(got)
        elif held:
            back = list(held)[:k]
            ring = release(ring, jnp.asarray(back, jnp.int32))
            held.difference_update(back)
        free = int(jax.device_get(ring.tail - ring.head))
        assert free == 8 - len(held)


def test_snapshot_count_independent_reads():
    """The paper's DBS headline: read resolution cost does not grow with the
    snapshot chain. Structurally: resolution is a single table gather whose
    result stays correct across many snapshots."""
    st_ = dbs.make_state(64, 2, 8, max_snapshots=64)
    st_, v = dbs.create_volume(st_)
    st_, ops = dbs.write_pages(st_, v, jnp.arange(4), jnp.full((4,), 1, jnp.uint32))
    first = np.asarray(jax.device_get(dbs.read_resolve(st_, v, jnp.arange(4))))
    for i in range(20):
        st_, sid = dbs.snapshot(st_, v)
        assert int(sid) >= 0
        ext = np.asarray(jax.device_get(dbs.read_resolve(st_, v, jnp.arange(4))))
        np.testing.assert_array_equal(ext, first)  # same one-gather lookup
