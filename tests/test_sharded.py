"""The sharded engine pool (core/sharded.py).

Contracts:

1. **shard-vs-loop equivalence** — an EnginePool with S shards reaches
   byte-identical volume contents (and identical per-shard DBS metadata)
   vs S independent fused Engines fed the same per-volume streams,
2. **one compiled program per pump** — a drain over S shards of mixed
   traffic traces the vmapped step once per geometry (jit trace count),
   and every pump is exactly one dispatch of it,
3. **pipelined drain** — ``drain`` (double-buffered completion) completes
   exactly the submitted set under mixed read/write, including requeues
   when admission starves,
4. **per-shard failover** — failing one replica of one shard mid-drain
   leaves every shard's data intact; rebuild restores consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request
from repro.core.sharded import EnginePool


def _cfg(**kw):
    base = dict(comm="sharded", storage="dbs", payload_shape=(8,),
                n_extents=256, max_pages=64, batch=16, n_replicas=2,
                n_shards=3, max_volumes=16)
    base.update(kw)
    return EngineConfig(**base)


def _mixed_traffic(n, vols, pages=48):
    """Deterministic mixed read/write stream over the given volumes."""
    reqs = []
    for i in range(n):
        v = vols[i % len(vols)]
        if i % 2:
            reqs.append(Request(req_id=i, kind="write", volume=v,
                                page=i % pages, block=(i * 3) % 8,
                                payload=jnp.full((8,), float(i + 1))))
        else:
            reqs.append(Request(req_id=i, kind="read", volume=v,
                                page=(i // 2) % pages, block=0))
    return reqs


# ---------------------------------------------------------------------------
# 1. shard-vs-loop equivalence
# ---------------------------------------------------------------------------
def test_pool_matches_independent_engines():
    """EnginePool(S=3) == 3 independent comm='fused' engines, fed the same
    per-volume request streams: identical volume contents AND identical
    per-shard replica DBS pytrees (the stacked state evolves exactly as the
    loop of engines would)."""
    S = 3
    pool = EnginePool(_cfg(n_shards=S))
    singles = [Engine(EngineConfig(comm="fused", storage="dbs",
                                   payload_shape=(8,), n_extents=256,
                                   max_pages=64, batch=16, n_replicas=2,
                                   max_volumes=16))
               for _ in range(S)]
    gvols = [pool.create_volume() for _ in range(S)]       # one per shard
    svols = [e.create_volume() for e in singles]
    assert sorted(g % S for g in gvols) == list(range(S))

    for i in range(90):                        # writes, all shards
        pay = jnp.full((8,), float(i + 1))
        s = i % S
        pool.submit(Request(req_id=i, kind="write", volume=gvols[s],
                            page=i % 48, block=i % 8, payload=pay))
        singles[s].submit(Request(req_id=i, kind="write", volume=svols[s],
                                  page=i % 48, block=i % 8, payload=pay))
    assert pool.drain() == 90
    assert sum(e.drain() for e in singles) == 90

    for s in range(S):                         # snapshot -> CoW overwrites
        pool.snapshot(gvols[s])
        singles[s].snapshot(svols[s])
    for i in range(45):
        pay = jnp.full((8,), float(1000 + i))
        s = i % S
        pool.submit(Request(req_id=i, kind="write", volume=gvols[s],
                            page=i % 24, block=(i * 5) % 8, payload=pay))
        pool.submit(Request(req_id=500 + i, kind="read", volume=gvols[s],
                            page=i % 24, block=0))
        singles[s].submit(Request(req_id=i, kind="write", volume=svols[s],
                                  page=i % 24, block=(i * 5) % 8,
                                  payload=pay))
        singles[s].submit(Request(req_id=500 + i, kind="read",
                                  volume=svols[s], page=i % 24, block=0))
    assert pool.drain() == 90
    assert sum(e.drain() for e in singles) == 90

    pages = jnp.arange(48, dtype=jnp.int32)
    for s in range(S):
        for blk in range(8):
            offs = jnp.full((48,), blk, jnp.int32)
            a = pool.read_volume(gvols[s], pages, offs)
            b = singles[s].backend.read(svols[s], pages, offs)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       err_msg=f"shard {s} block {blk}")
    # stacked replica metadata == each standalone engine's replica metadata
    for s in range(S):
        shard = gvols[s] % S
        for r in range(2):
            stacked = jax.tree.map(lambda x: x[shard],
                                   pool.backend.states[r])
            single = singles[s].backend.replicas[r].state
            for a, b in zip(jax.tree.leaves(stacked),
                            jax.tree.leaves(single)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert pool.backend.consistent()


# ---------------------------------------------------------------------------
# 2. one compiled program serves all S shards per pump
# ---------------------------------------------------------------------------
def test_one_program_per_pump():
    pool = EnginePool(_cfg(n_shards=4))
    vols = [pool.create_volume() for _ in range(8)]
    for r in _mixed_traffic(160, vols):
        pool.submit(r)
    done = pool.drain()
    assert done == 160
    # several pumps happened, all served by ONE traced program per variant
    assert pool.dispatches >= 3
    assert pool.trace_counts["step"] == 1, pool.trace_counts
    assert pool.trace_counts["step_read"] <= 1, pool.trace_counts
    # more traffic, same geometry: no retracing
    before = dict(pool.trace_counts)
    for r in _mixed_traffic(80, vols):
        pool.submit(r)
    assert pool.drain() == 80
    assert pool.trace_counts == before


# ---------------------------------------------------------------------------
# 3. pipelined drain: exact completion under requeues
# ---------------------------------------------------------------------------
def test_pipelined_drain_completes_exact_set_with_requeues():
    """More in-flight requests than slots: admission starves, the pump
    requeues not-admitted lanes at completion (one iteration behind the
    launch it missed), and the pipelined drain still completes exactly the
    submitted set."""
    pool = EnginePool(_cfg(n_shards=2, n_slots=8, batch=8))
    vols = [pool.create_volume() for _ in range(4)]
    n = 200                                     # >> slots * shards
    reads = []
    for i in range(n):
        v = vols[i % 4]
        if i % 3 == 0:
            r = Request(req_id=i, kind="read", volume=v, page=i % 32,
                        block=0)
            reads.append(r)
            pool.submit(r)
        else:
            pool.submit(Request(req_id=i, kind="write", volume=v,
                                page=i % 32, block=i % 8,
                                payload=jnp.full((8,), float(i))))
    assert pool.drain() == n
    assert pool.completed == n
    assert pool.frontend.depth() == 0
    # every read delivered a result array (zeros for unwritten holes)
    assert all(r.result is not None for r in reads)


def test_pump_async_overlaps_completion():
    """pump_async returns a handle without fetching; the handle completes
    later with the right per-lane results."""
    pool = EnginePool(_cfg(n_shards=2))
    vols = [pool.create_volume() for _ in range(2)]
    for i in range(10):
        pool.submit(Request(req_id=i, kind="write", volume=vols[i % 2],
                            page=i, block=0,
                            payload=jnp.full((8,), float(i + 1))))
    p1 = pool.pump_async()
    assert p1 is not None and pool.completed == 0     # nothing fetched yet
    # second batch admitted while the first is (logically) in flight
    rd = Request(req_id=90, kind="read", volume=vols[0], page=0, block=0)
    pool.submit(rd)
    p2 = pool.pump_async()
    assert pool._complete(p1) == 10
    assert pool._complete(p2) == 1
    np.testing.assert_allclose(np.asarray(rd.result), np.full((8,), 1.0))


# ---------------------------------------------------------------------------
# 4. per-shard failover
# ---------------------------------------------------------------------------
def test_per_shard_failover_mid_drain():
    pool = EnginePool(_cfg(n_shards=3))
    vols = [pool.create_volume() for _ in range(3)]
    for i in range(60):
        pool.submit(Request(req_id=i, kind="write", volume=vols[i % 3],
                            page=i % 20, block=0,
                            payload=jnp.full((8,), float(i + 1))))
    assert pool.drain() == 60
    baseline = {v: np.asarray(pool.read_volume(
        v, jnp.arange(20, dtype=jnp.int32), jnp.zeros(20, jnp.int32)))
        for v in vols}

    sick = vols[1] % 3
    pool.backend.fail(sick, 0)                  # one replica of ONE shard
    for i in range(30):                         # mid-drain traffic everywhere
        pool.submit(Request(req_id=100 + i, kind="write",
                            volume=vols[i % 3], page=20 + (i % 10), block=0,
                            payload=jnp.full((8,), float(200 + i))))
        pool.submit(Request(req_id=500 + i, kind="read", volume=vols[i % 3],
                            page=i % 20, block=0))
    assert pool.drain() == 60

    # surviving shards' replicas stayed consistent; old data intact everywhere
    for s in range(3):
        if s != sick:
            assert pool.backend.consistent(s)
    for v in vols:
        got = np.asarray(pool.read_volume(
            v, jnp.arange(20, dtype=jnp.int32), jnp.zeros(20, jnp.int32)))
        np.testing.assert_allclose(got, baseline[v],
                                   err_msg=f"volume {v} lost old data")

    pool.backend.rebuild(sick, 0)
    assert pool.backend.consistent()
    # the rebuilt replica serves the writes it missed
    healthy_before = pool.backend.healthy.copy()
    pool.backend.fail(sick, 1)                  # force reads from replica 0
    got = np.asarray(pool.read_volume(
        vols[1], jnp.asarray([20], jnp.int32), jnp.zeros(1, jnp.int32)))
    assert got[0][0] >= 200.0                   # a mid-drain write, rebuilt
    pool.backend.rebuild(sick, 1)
    np.testing.assert_array_equal(pool.backend.healthy, healthy_before)


def test_shard_failover_validation():
    pool = EnginePool(_cfg(n_shards=2))
    with pytest.raises(IndexError):
        pool.backend.fail(5, 0)
    with pytest.raises(IndexError):
        pool.backend.fail(0, 7)
    with pytest.raises(ValueError):
        pool.backend.rebuild(0, 0)              # healthy: nothing to rebuild
    pool.backend.fail(0, 0)
    with pytest.raises(RuntimeError):
        pool.backend.fail(0, 1)                 # last healthy in shard 0
    pool.backend.fail(1, 1)                     # other shard: independent
    with pytest.raises(IndexError):
        pool.backend.rebuild(3, 0)
    pool.backend.rebuild(0, 0)
    pool.backend.rebuild(1, 1)
    assert pool.backend.healthy.all()


# ---------------------------------------------------------------------------
# engine routing + null layers + ladder integration
# ---------------------------------------------------------------------------
def test_engine_routes_sharded_comm():
    eng = Engine(_cfg(n_shards=2))
    assert eng.pool is not None
    vols = [eng.create_volume() for _ in range(2)]
    for r in _mixed_traffic(40, vols, pages=32):
        eng.submit(r)
    assert eng.drain() == 40
    assert eng.completed == 40
    eng.completed = 0                           # the ladder's reset idiom
    assert eng.pool.completed == 0


@pytest.mark.parametrize("kw", [dict(null_backend=True),
                                dict(null_storage=True)])
def test_sharded_null_rows_complete(kw):
    eng = Engine(_cfg(n_shards=2, **kw))
    vol = eng.create_volume()
    for i in range(40):
        eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                           volume=vol, page=i % 64, block=0,
                           payload=jnp.ones((8,))))
    assert eng.drain() == 40, kw


def test_ladder_has_sharded_column():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.ladder import COLUMNS, make_engine
    assert "+sharded" in COLUMNS
    eng = make_engine("+sharded", "full_engine", payload_shape=(8,),
                      max_pages=64, n_extents=256, n_shards=2)
    assert eng.cfg.comm == "sharded"
    vols = [eng.create_volume() for _ in range(2)]
    for r in _mixed_traffic(24, vols, pages=32):
        eng.submit(r)
    assert eng.drain() == 24
