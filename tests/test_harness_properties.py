"""Property test: random traces x random chaos schedules stay oracle-clean
(hypothesis-driven; skipped when hypothesis is not installed).

For every (transport, write_policy, read_policy) combo the host-dispatch
engine supports, hypothesis draws a ``(trace_seed, chaos_seed)`` pair plus
small trace/chaos shapes, and the harness replays the run end to end:
seeded fio-style load, trace-indexed fault injection, shadow byte oracle
on every read, final delta rebuild, and byte-equivalence forced onto EACH
surviving replica (``run()``'s verification sweep). The property is the
ISSUE 6 core claim: whatever the schedule does — fails, quorum loss,
rebuilds racing writes, lossy links, mid-trace snapshot/clone/discard —
every acked read returns oracle bytes, every replica converges after the
final rebuild, and no ``IOFuture`` hangs.

Shrinking works on the seeds and shapes: a failure minimizes to the
smallest trace/schedule pair that still breaks, which (with the replay
determinism the harness guarantees) is a ready-made regression case.
"""
import pytest

from repro.harness import ChaosConfig, TraceConfig, run

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

# the policy surface of the host-dispatch (slots) plane: quorum/async and
# latency-weighted reads are transport-generic, but only simnet makes them
# interesting (drop/reorder/straggler); local/device pin the baselines
COMBOS = [
    ("local", "all", "rr"),
    ("device", "all", "rr"),
    ("simnet", "all", "rr"),
    ("simnet", "quorum", "latency"),
    ("simnet", "async", "rr"),
]

_TRACE = st.builds(
    TraceConfig,
    n_ops=st.integers(10, 28),
    n_volumes=st.integers(1, 3),
    read_frac=st.sampled_from([0.0, 0.3, 0.6]),
    seq_frac=st.sampled_from([0.0, 0.5]),
    unaligned_frac=st.sampled_from([0.0, 0.25]),
    mean_burst=st.integers(1, 6),
)
_CHAOS = st.builds(ChaosConfig, n_events=st.integers(0, 5))


@pytest.mark.slow
@pytest.mark.parametrize("transport,write_policy,read_policy", COMBOS)
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture,
                                 HealthCheck.too_slow])
@given(trace_seed=st.integers(0, 2**16), chaos_seed=st.integers(0, 2**16),
       trace=_TRACE, chaos=_CHAOS)
def test_property_random_trace_random_chaos_oracle_clean(
        transport, write_policy, read_policy, trace_seed, chaos_seed,
        trace, chaos):
    res = run(trace_seed=trace_seed, chaos_seed=chaos_seed, trace=trace,
              chaos=chaos, backend="slots", n_replicas=3,
              transport=transport, write_policy=write_policy,
              read_policy=read_policy,
              transport_opts=(dict(latency=2, window=16, drop=0.1)
                              if transport == "simnet" else None))
    assert res.ok, "\n".join(res.oracle_failures + res.harness_failures)
    assert len(res.completion_ticks) == trace.n_ops
