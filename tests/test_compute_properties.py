"""Property-based computational-storage test (hypothesis, importorskip-
gated): random byte writes followed by random ``Volume.compute`` calls are
bit-equal to the pure-Python bytearray oracle (the registry's ``mirror``
functions), parametrized over the host oracle and the fused / sharded /
ring backends with both DBS kernels.

The oracle IS the mirror: every built-in's ``mirror`` runs against a host
bytearray shadow that tracks the volume byte-for-byte (including the
``compare_and_write`` commit, which the mirror applies to the shadow on
match — so a CAS mid-sequence keeps the two worlds in lockstep)."""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.compute import make_storage_fn
from repro.compute.functions import py_blocksum, py_i32
from repro.core.blockdev import VolumeManager

BB = 16         # block_bytes
PB = 2          # page_blocks -> page_bytes = 32
PAGES = 8       # capacity = 256 bytes
CAP = BB * PB * PAGES

MATRIX = [("host", 1, "xla"), ("fused", 1, "xla"), ("fused", 1, "pallas"),
          ("sharded", 2, "xla"), ("sharded", 2, "pallas"),
          ("ring", 2, "xla"), ("ring", 2, "pallas")]

_MGRS = {}      # (backend, n_shards, kernel) -> (manager, volume), reused


def _vol(backend, n_shards, kernel):
    key = (backend, n_shards, kernel)
    if key not in _MGRS:
        mgr = VolumeManager(backend=backend, n_shards=n_shards,
                            kernel=kernel, payload_elems=BB, page_blocks=PB,
                            max_pages=PAGES, n_extents=256, max_volumes=16,
                            batch=16, n_replicas=2)
        _MGRS[key] = (mgr, mgr.create())
    return _MGRS[key]


_FNS = ("checksum", "scan_count", "filter_pages", "compare_and_write",
        "verify_on_read")

ops_st = st.lists(
    st.tuples(st.sampled_from(("write",) + _FNS),
              st.integers(0, 2 ** 30),      # position seed
              st.integers(0, 2 ** 30),      # arg / length seed
              st.binary(min_size=BB, max_size=BB)),
    min_size=1, max_size=6)


@pytest.mark.parametrize("backend,n_shards,kernel", MATRIX,
                         ids=[f"{b}-{k}" for b, _, k in MATRIX])
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(base=st.binary(min_size=CAP, max_size=CAP), ops=ops_st)
def test_random_computes_match_bytearray_oracle(backend, n_shards, kernel,
                                                base, ops):
    mgr, vol = _vol(backend, n_shards, kernel)
    pby = mgr.page_bytes
    n_pages = CAP // pby
    # reset: a full-capacity write makes each example independent even
    # though the manager/volume (and its compiled programs) are reused
    vol.write(0, base)
    shadow = bytearray(base)

    for kind, pos, aseed, blob in ops:
        if kind == "write":
            off = pos % CAP
            n = 1 + aseed % (CAP - off)
            data = (blob * (n // BB + 1))[:n]
            vol.write(off, data)
            shadow[off:off + n] = data
            continue

        entry = make_storage_fn(kind)
        if entry.scope == "range":
            p0 = pos % n_pages
            cnt = 1 + aseed % (n_pages - p0)
            off, nbytes = p0 * pby, cnt * pby
            arg = (-1 if aseed % 5 == 0 else aseed % 256)
            if kind == "checksum":
                arg = 0
            want = entry.mirror(shadow, pby, BB, p0, cnt, arg, None)
            res = vol.compute(kind, off, nbytes, arg=arg).result()
        else:
            ab = pos % (CAP // BB)
            off = ab * BB
            cur = py_blocksum(shadow[off:off + BB])
            data = None
            if kind == "compare_and_write":
                data = blob
                arg = cur if aseed % 2 else py_i32((cur + 1) & 0xFFFFFFFF)
            else:
                arg = cur if aseed % 2 else py_i32(aseed or 1)
            want = entry.mirror(shadow, pby, BB, ab // PB, ab % PB,
                                arg, data)
            res = vol.compute(kind, off, arg=arg, data=data).result()

        assert (res.value, res.status) == (int(want[0]), int(want[1])), kind
        if want[2] is not None:
            if kind == "filter_pages":
                assert res.pages() == list(want[2])
            else:
                assert res.data() == bytes(want[2])

    # final byte-for-byte agreement (CAS commits included)
    assert vol.read(0, CAP) == bytes(shadow)
