"""Serving engine integration: continuous batching, forking, leak-freedom,
frontend/engine behaviour (the paper's data path end-to-end)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import Engine, EngineConfig, Request, UpstreamEngine
from repro.core import dbs as D
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("granite-3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_completes_all(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 6                               # more requests than slots
    for rid in range(n_req):
        eng.submit(GenRequest(req_id=rid,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=(8 + rid,)),
                              max_new=4))
    outs = eng.run(max_steps=40)
    assert len(outs) == n_req
    assert all(len(v) == 4 for v in outs.values()), outs
    st = D.stats(eng.state)
    assert st["extents_used"] == 0, f"extent leak: {st}"
    assert st["volumes"] == 0


def test_fork_shares_prefix_and_diverges_safely(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(9,))
    eng.submit(GenRequest(req_id=0, prompt=prompt, max_new=10))
    for _ in range(3):
        eng.step()
    child = eng.fork(0, 1, max_new=5)
    assert child is not None
    shared = list(child.out_tokens)
    for _ in range(12):
        eng.step()
    parent_toks = eng.live[0].out_tokens
    child_toks = eng.live[1].out_tokens
    # greedy decoding from a shared prefix must continue identically
    assert child_toks[:len(shared)] == shared
    assert child_toks == parent_toks[:len(child_toks)], \
        (parent_toks, child_toks)


def test_engine_ladder_modes():
    """Paper §IV-A: null-backend / null-storage / full-engine all complete."""
    for kwargs in (dict(null_backend=True), dict(null_storage=True), dict()):
        e = Engine(EngineConfig(payload_shape=(8,), **kwargs))
        v = e.create_volume()
        for i in range(64):
            e.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                             volume=v, page=i % 16, block=i % 4,
                             payload=jnp.ones((8,))))
        assert e.drain() == 64


def test_upstream_engine_chained_reads_degrade_with_snapshots():
    """Structural check of the paper's complaint: upstream chained lookup
    touches every snapshot layer; DBS resolution stays one gather."""
    cfg = EngineConfig(payload_shape=(4,))
    up = UpstreamEngine(cfg)
    v = up.create_volume()
    up.stores[0].write(v, 0, 0, jnp.ones((4,)))
    layers_touched = []
    for n_snaps in (0, 8, 32):
        for _ in range(n_snaps - len(up.stores[0].chains[v]) + 1):
            up.snapshot(v)
        # count layers walked for a miss (worst case read)
        walked = 0
        for layer in reversed(up.stores[0].chains[v]):
            walked += 1
            if (1, 0) in layer:
                break
        layers_touched.append(walked)
    assert layers_touched[-1] > layers_touched[0], layers_touched


def test_replica_group_mirror_and_rebuild():
    from repro.core.replication import ReplicaGroup
    g = ReplicaGroup(n_replicas=3, n_extents=32, max_volumes=4, max_pages=16,
                     page_blocks=8, payload_shape=(4,))
    v = g.create_volume()
    pages = jnp.arange(4)
    offs = jnp.zeros((4,), jnp.int32)
    payload = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    g.write(v, pages, offs, payload)
    assert g.consistent()
    r0 = g.read(v, pages, offs)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(payload))
    # fail one replica; reads keep working; rebuild restores consistency
    g.fail(1)
    r1 = g.read(v, pages, offs)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(payload))
    g.write(v, pages, offs + 1, payload * 2)     # writes while degraded
    g.rebuild(1)
    assert g.consistent()
    r2 = g.read(v, pages, offs + 1)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(payload * 2))


def test_multiqueue_frontend_backpressure():
    from repro.core.frontend import MultiQueueFrontend, Request
    fe = MultiQueueFrontend(n_queues=2, n_slots=4, batch=8)
    for i in range(10):
        fe.submit(Request(req_id=i, kind="read", volume=0, page=0))
    ids, admitted = fe.poll_batch()
    assert len(admitted) == 4                   # slot-bounded admission
    assert fe.depth() == 6
    fe.complete(ids[:4])
    _, admitted2 = fe.poll_batch()
    assert len(admitted2) == 4


def test_serve_pool_shards_and_completes(small_model):
    """ServePool: requests hash across S ServeEngine shards, all complete,
    forks stay on the parent's shard, per-shard DBS state stays leak-free."""
    from repro.serving import ServePool
    cfg, params = small_model
    pool = ServePool(cfg, params, n_shards=2, n_slots=4, max_len=64)
    rng = np.random.default_rng(2)
    n_req = 5
    for rid in range(n_req):
        pool.submit(GenRequest(req_id=rid,
                               prompt=rng.integers(0, cfg.vocab_size,
                                                   size=(6 + rid,)),
                               max_new=6))
    for _ in range(3):
        pool.step()
    child = pool.fork(0, 10, max_new=2)         # rid 10 hashes to shard 0...
    assert child is not None
    assert pool.shard_of(10) == pool.shard_of(0)   # ...because parent owns it
    outs = pool.run(max_steps=30)
    assert set(outs) == set(range(n_req)) | {10}
    assert all(len(outs[r]) == 6 for r in range(n_req))
    for sh in pool.shards:
        st = D.stats(sh.state)
        assert st["extents_used"] == 0 and st["volumes"] == 0, st
