"""Serving engine integration: continuous batching, forking, leak-freedom,
frontend/engine behaviour (the paper's data path end-to-end)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import Engine, EngineConfig, Request, UpstreamEngine
from repro.core import dbs as D
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("granite-3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_completes_all(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 6                               # more requests than slots
    for rid in range(n_req):
        eng.submit(GenRequest(req_id=rid,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=(8 + rid,)),
                              max_new=4))
    outs = eng.run(max_steps=40)
    assert len(outs) == n_req
    assert all(len(v) == 4 for v in outs.values()), outs
    st = D.stats(eng.state)
    assert st["extents_used"] == 0, f"extent leak: {st}"
    assert st["volumes"] == 0


def test_fork_shares_prefix_and_diverges_safely(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(9,))
    eng.submit(GenRequest(req_id=0, prompt=prompt, max_new=10))
    for _ in range(3):
        eng.step()
    child = eng.fork(0, 1, max_new=5)
    assert child is not None
    shared = list(child.out_tokens)
    for _ in range(12):
        eng.step()
    parent_toks = eng.live[0].out_tokens
    child_toks = eng.live[1].out_tokens
    # greedy decoding from a shared prefix must continue identically
    assert child_toks[:len(shared)] == shared
    assert child_toks == parent_toks[:len(child_toks)], \
        (parent_toks, child_toks)


def test_engine_ladder_modes():
    """Paper §IV-A: null-backend / null-storage / full-engine all complete."""
    for kwargs in (dict(null_backend=True), dict(null_storage=True), dict()):
        e = Engine(EngineConfig(payload_shape=(8,), **kwargs))
        v = e.create_volume()
        for i in range(64):
            e.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                             volume=v, page=i % 16, block=i % 4,
                             payload=jnp.ones((8,))))
        assert e.drain() == 64


def test_upstream_engine_chained_reads_degrade_with_snapshots():
    """Structural check of the paper's complaint: upstream chained lookup
    touches every snapshot layer; DBS resolution stays one gather."""
    cfg = EngineConfig(payload_shape=(4,))
    up = UpstreamEngine(cfg)
    v = up.create_volume()
    up.stores[0].write(v, 0, 0, jnp.ones((4,)))
    layers_touched = []
    for n_snaps in (0, 8, 32):
        for _ in range(n_snaps - len(up.stores[0].chains[v]) + 1):
            up.snapshot(v)
        # count layers walked for a miss (worst case read)
        walked = 0
        for layer in reversed(up.stores[0].chains[v]):
            walked += 1
            if (1, 0) in layer:
                break
        layers_touched.append(walked)
    assert layers_touched[-1] > layers_touched[0], layers_touched


def test_replica_group_mirror_and_rebuild():
    from repro.core.replication import ReplicaGroup
    g = ReplicaGroup(n_replicas=3, n_extents=32, max_volumes=4, max_pages=16,
                     page_blocks=8, payload_shape=(4,))
    v = g.create_volume()
    pages = jnp.arange(4)
    offs = jnp.zeros((4,), jnp.int32)
    payload = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    g.write(v, pages, offs, payload)
    assert g.consistent()
    r0 = g.read(v, pages, offs)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(payload))
    # fail one replica; reads keep working; rebuild restores consistency
    g.fail(1)
    r1 = g.read(v, pages, offs)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(payload))
    g.write(v, pages, offs + 1, payload * 2)     # writes while degraded
    g.rebuild(1)
    assert g.consistent()
    r2 = g.read(v, pages, offs + 1)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(payload * 2))


def test_multiqueue_frontend_backpressure():
    from repro.core.frontend import MultiQueueFrontend, Request
    fe = MultiQueueFrontend(n_queues=2, n_slots=4, batch=8)
    for i in range(10):
        fe.submit(Request(req_id=i, kind="read", volume=0, page=0))
    ids, admitted = fe.poll_batch()
    assert len(admitted) == 4                   # slot-bounded admission
    assert fe.depth() == 6
    fe.complete(ids[:4])
    _, admitted2 = fe.poll_batch()
    assert len(admitted2) == 4


def test_fork_cow_shares_prefix_extents_and_matches_reference(small_model):
    """Zero-copy fork property (PR 8): fork mid-decode shares the prefix
    EXTENTS (no copy — the clone's extent-map row equals the parent's),
    diverging writes CoW only the frontier page, and both sessions' post-
    fork logits are bit-identical to two independently-decoded sessions."""
    cfg, params = small_model
    page = cfg.page_blocks
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64, record_logits=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=(9,))
    eng.submit(GenRequest(req_id=0, prompt=prompt, max_new=12))
    for _ in range(4):
        eng.step()
    parent = eng.live[0]
    child = eng.fork(0, 1, max_new=8)
    assert child is not None
    tbl = np.asarray(jax.device_get(eng.volumes.device_extent_map()))
    prow, crow = tbl[parent.volume].copy(), tbl[child.volume].copy()
    np.testing.assert_array_equal(prow, crow)      # shared, not copied
    assert (prow >= 0).sum() >= 2                  # a real prefix exists
    frontier = (9 + 4) // page                     # page holding fork pos
    for _ in range(2):                             # diverge both sides
        eng.step()
    tbl2 = np.asarray(jax.device_get(eng.volumes.device_extent_map()))
    prow2, crow2 = tbl2[parent.volume], tbl2[child.volume]
    # frontier page CoW'd apart; full prefix pages still shared
    assert prow2[frontier] != crow2[frontier], (prow2, crow2)
    for p in range(frontier):
        assert prow2[p] == crow2[p] == prow[p]
    # drain both sessions, then decode the same two streams independently
    for _ in range(16):
        eng.step()
    ref = ServeEngine(cfg, params, n_slots=4, max_len=64, record_logits=True)
    ref.submit(GenRequest(req_id=0, prompt=prompt.copy(), max_new=12))
    ref.submit(GenRequest(req_id=1, prompt=prompt.copy(), max_new=12))
    ref.run(max_steps=20)
    assert eng.live[0].out_tokens == ref.live[0].out_tokens[:12]
    # child's trace starts at the fork step (absolute step 4)
    np.testing.assert_array_equal(
        np.stack(eng.live[0].logit_trace[4:]),
        np.stack(ref.live[0].logit_trace[4:12]))
    np.testing.assert_array_equal(
        np.stack(eng.live[1].logit_trace),
        np.stack(ref.live[1].logit_trace[4:4 + len(eng.live[1].logit_trace)]))


def test_paged_attention_kernel_matches_ref_ragged_window_holes():
    """Parity of the Pallas paged-attention kernel (split-pool and pooled
    zero-copy variants) against the jnp oracle over ragged lengths, sliding
    windows, logit caps and hole pages."""
    from repro.kernels.paged_attention.kernel import (
        paged_attention_fwd, paged_attention_pool_fwd)
    from repro.kernels.paged_attention.ref import (
        paged_attention_pool_ref, paged_attention_ref)
    rng = np.random.default_rng(7)
    b, h, kv, d, page, p_max, e = 4, 4, 2, 8, 4, 5, 24
    pool_k = jnp.asarray(rng.normal(size=(e, page, kv, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(e, page, kv, d)), jnp.float32)
    table = rng.permutation(e - 1)[: b * p_max].reshape(b, p_max) + 1
    lengths = np.array([1, 7, 13, 20], np.int32)
    for i in range(b):                              # holes past the length
        for p in range((lengths[i] + page - 1) // page, p_max):
            table[i, p] = -1
    table[3, 1] = -1                                # a hole BELOW the length
    table = jnp.asarray(table, jnp.int32)
    lengths = jnp.asarray(lengths)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    for window in (0, 3):
        for cap in (0.0, 5.0):
            out_k = paged_attention_fwd(q, pool_k, pool_v, table, lengths,
                                        window=window, logit_cap=cap,
                                        interpret=True)
            out_r = paged_attention_ref(q, pool_k, pool_v, table, lengths,
                                        window=window, logit_cap=cap)
            np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                       atol=1e-5, rtol=1e-5)
    # pooled variant: K/V as two planes of ONE engine extent pool
    pool = jnp.asarray(rng.normal(size=(e, page, 4, kv, d)), jnp.float32)
    for kp, vp in ((0, 1), (2, 3)):
        out_k = paged_attention_pool_fwd(q, pool, table, lengths, k_plane=kp,
                                         v_plane=vp, interpret=True)
        out_r = paged_attention_pool_ref(q, pool, table, lengths, k_plane=kp,
                                         v_plane=vp)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(out_r),
            np.asarray(paged_attention_ref(q, pool[:, :, kp], pool[:, :, vp],
                                           table, lengths)),
            atol=1e-6, rtol=1e-6)


def test_clone_inherits_page_rev_on_serving_route():
    """PR 8 fix check: the ``VolumeManager.clone`` route serving uses must
    inherit the source volume's page_rev watermark row (PR 5 fixed the
    ring/transport route) — otherwise a forked session rebuilt after a
    replica failure serves a stale prefix."""
    from repro.core.blockdev import VolumeManager
    with VolumeManager(backend="sharded", n_shards=2, n_replicas=2,
                       payload_elems=8, page_blocks=4, n_extents=64,
                       max_volumes=8, max_pages=8) as mgr:
        vol = mgr.create()
        data = bytes(range(32))                     # one full page
        vol.write(0, data)
        clone = vol.clone()
        assert clone is not None
        shard = vol.vid % 2
        assert clone.vid % 2 == shard               # clone stays shard-local
        revs = np.asarray(jax.device_get(
            mgr.engine.backend.device_page_revs()))  # (R, S, V, P)
        src_l, cl_l = vol.vid // 2, clone.vid // 2
        assert revs[0, shard, src_l].max() > 0
        np.testing.assert_array_equal(revs[:, shard, cl_l],
                                      revs[:, shard, src_l])
        # the failure-mode it protects: rebuild a replica, then force reads
        # from it — the clone's prefix must come back fresh
        mgr.flush()
        mgr.engine.control("fail", shard=shard, replica=0)
        clone.write(32, b"\xff" * 8)                # diverge while degraded
        mgr.engine.control("rebuild", shard=shard, replica=0)
        mgr.engine.control("fail", shard=shard, replica=1)
        assert clone.read(0, 32) == data
        assert clone.read(32, 8) == b"\xff" * 8
        mgr.engine.control("rebuild", shard=shard, replica=1)


def test_serving_zero_copy_replica_failure_mid_decode(small_model):
    """Chaos-compatibility of the zero-copy KV store: failing a replica
    mid-decode must not corrupt any session (tokens and logits stay
    identical to an undisturbed engine), and rebuild restores mirroring."""
    cfg, params = small_model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=(7,))
    engines = []
    for _ in range(2):
        e = ServeEngine(cfg, params, n_slots=2, max_len=64,
                        record_logits=True)
        e.submit(GenRequest(req_id=0, prompt=prompt.copy(), max_new=10))
        engines.append(e)
    eng, ref = engines
    for _ in range(3):
        eng.step()
        ref.step()
    eng.control("fail", replica=1)                  # mid-decode failure
    for e in engines:
        while not e.live[0].done:
            e.step()
    assert eng.live[0].out_tokens == ref.live[0].out_tokens
    np.testing.assert_array_equal(np.stack(eng.live[0].logit_trace),
                                  np.stack(ref.live[0].logit_trace))
    eng.control("rebuild", replica=1)
    assert eng.volumes.engine.backend.consistent()


def test_serve_pool_shards_and_completes(small_model):
    """ServePool: requests hash across S ServeEngine shards, all complete,
    forks stay on the parent's shard, per-shard DBS state stays leak-free."""
    from repro.serving import ServePool
    cfg, params = small_model
    pool = ServePool(cfg, params, n_shards=2, n_slots=4, max_len=64)
    rng = np.random.default_rng(2)
    n_req = 5
    for rid in range(n_req):
        pool.submit(GenRequest(req_id=rid,
                               prompt=rng.integers(0, cfg.vocab_size,
                                                   size=(6 + rid,)),
                               max_new=6))
    for _ in range(3):
        pool.step()
    child = pool.fork(0, 10, max_new=2)         # rid 10 hashes to shard 0...
    assert child is not None
    assert pool.shard_of(10) == pool.shard_of(0)   # ...because parent owns it
    outs = pool.run(max_steps=30)
    assert set(outs) == set(range(n_req)) | {10}
    assert all(len(outs[r]) == 6 for r in range(n_req))
    for sh in pool.shards:
        st = D.stats(sh.state)
        assert st["extents_used"] == 0 and st["volumes"] == 0, st
