"""End-to-end behaviour of the whole system: train -> checkpoint -> restart
-> serve with the paged engine (the full paper data path on one host)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ExecutionPlan, smoke_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine
from repro.training.trainer import Trainer

PLAN = ExecutionPlan(remat="none", compute_dtype="float32", microbatches=1,
                     logits_chunk=0)


def test_train_checkpoint_restart_serve(tmp_path):
    cfg = smoke_config("granite-3-8b")
    dirs = [str(tmp_path / d) for d in "ab"]
    for d in dirs:
        os.makedirs(d)
    data = SyntheticLM(cfg.vocab_size, 4, 16)

    tr = Trainer(cfg, PLAN, data, ckpt_dirs=dirs, ckpt_every=4,
                 total_steps=20, warmup=2)
    hist = tr.run(8)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.05
    step_before = tr.step
    tr.ckpt.close()

    # "preemption": a fresh process-equivalent trainer resumes exactly
    tr2 = Trainer(cfg, PLAN, data, ckpt_dirs=dirs, ckpt_every=4,
                  total_steps=20, warmup=2)
    assert tr2.step == step_before
    # and the restored params serve through the paged engine
    eng = ServeEngine(cfg, tr2.params, n_slots=2, max_len=48)
    eng.submit(GenRequest(req_id=0,
                          prompt=np.arange(8, dtype=np.int64) % cfg.vocab_size,
                          max_new=4))
    outs = eng.run(max_steps=12)
    assert len(outs[0]) == 4
    tr2.ckpt.close()


def test_straggler_accounting(tmp_path):
    import time
    cfg = smoke_config("gemma2-2b")
    data = SyntheticLM(cfg.vocab_size, 2, 16)
    tr = Trainer(cfg, PLAN, data, ckpt_dirs=None, total_steps=20, warmup=1,
                 deadline_factor=0.0)   # every step after warmup flags
    tr.run(8)
    assert tr.straggler_events > 0      # the deadline accounting fires


def test_prefetcher_overlaps_and_closes():
    src = SyntheticLM(100, 4, 8)
    pf = Prefetcher(src, depth=3)
    batches = [next(pf) for _ in range(5)]
    assert all(b["tokens"].shape == (4, 8) for b in batches)
    # shard disjointness: different shards draw different streams
    a = next(iter(SyntheticLM(100, 4, 8, shard=0, n_shards=2)))
    b = next(iter(SyntheticLM(100, 4, 8, shard=1, n_shards=2)))
    assert not np.array_equal(a["tokens"], b["tokens"])
    pf.close()


def test_memmap_source(tmp_path):
    from repro.data.pipeline import MemmapLM
    path = str(tmp_path / "tokens.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    src = MemmapLM(path, batch=2, seq=16)
    b0 = next(iter(src))
    assert b0["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
