"""Computational storage (ISSUE 9): the storage-function registry
(repro/compute), the COMPUTE opcode class, and ``Volume.compute``.

Contracts:

1. **cross-backend bit-identity** — every built-in storage function
   returns (value, status, payload) bit-identical to its pure-Python
   mirror over a bytearray reference, parametrized over the host oracle
   and the fused / sharded / ring device backends with both the ``xla``
   and ``pallas`` DBS kernels.
2. **compare_and_write rides the CoW write path** — a matching CAS
   commits its payload (visible to subsequent reads on every replica), a
   stale expectation returns ``ST_MISMATCH`` (a positive op-level status,
   NOT an ``OSError``) and leaves the bytes untouched; a snapshot before
   the CAS keeps the frozen image (CoW, not in-place).
3. **in-band ordering** — on the ring, a COMPUTE SQE submitted between
   writes observes exactly the preceding writes (submission order is
   execution order), including when the batch mixes data and compute
   lanes and when control ops drain on a sibling shard in the same pump.
4. **registry surface** — registration order defines the SQE fn ids,
   unknown names raise naming the registered entries, ``Volume.compute``
   validates scope/alignment/data.
"""
import numpy as np
import pytest

from repro.compute import (ST_MISMATCH, available_storage_fns,
                           make_storage_fn, register_storage_fn,
                           storage_fn_id)
from repro.compute.functions import py_blocksum, py_i32
from repro.core.blockdev import VolumeManager

BB = 16         # block_bytes
PB = 4          # page_blocks -> page_bytes = 64
PAGES = 8       # capacity = 512 bytes

# (backend, n_shards) x kernel: the acceptance matrix. The host oracle
# executes the sequential host_ref (kernel-independent).
MATRIX = [("host", 1, "xla"), ("fused", 1, "xla"), ("fused", 1, "pallas"),
          ("sharded", 2, "xla"), ("sharded", 2, "pallas"),
          ("ring", 2, "xla"), ("ring", 2, "pallas")]


def _mgr(backend: str, n_shards: int = 1, **kw) -> VolumeManager:
    base = dict(backend=backend, n_shards=n_shards, payload_elems=BB,
                page_blocks=PB, max_pages=PAGES, n_extents=256,
                max_volumes=16, batch=16, n_replicas=2)
    base.update(kw)
    return VolumeManager(**base)


def _pat(seed: int, n: int) -> bytes:
    return bytes((seed * 37 + i * 11) % 251 for i in range(n))


def _mirror(fn: str, shadow: bytearray, page, block, arg=0, data=None):
    entry = make_storage_fn(fn)
    return entry.mirror(shadow, PB * BB, BB, page, block, arg, data)


# ---------------------------------------------------------------------------
# 1. every built-in, bit-identical to the mirror, on every backend/kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,n_shards,kernel", MATRIX)
def test_builtins_match_mirror_on_every_backend(backend, n_shards, kernel):
    with _mgr(backend, n_shards, kernel=kernel) as mgr:
        vol = mgr.create()
        shadow = bytearray(mgr.capacity)
        data = _pat(3, mgr.capacity - mgr.page_bytes)   # leave a hole page
        vol.write(0, data)
        shadow[:len(data)] = data
        n_pages = mgr.capacity // mgr.page_bytes

        # checksum: whole device and a page-aligned sub-range
        for p0, cnt in ((0, n_pages), (2, 3)):
            res = vol.compute("checksum", p0 * mgr.page_bytes,
                              cnt * mgr.page_bytes).result()
            want = _mirror("checksum", shadow, p0, cnt)
            assert (res.value, res.status) == (want[0], want[1])

        # scan_count / filter_pages: a present byte, an absent byte, and
        # the nonzero predicate
        present = data[5]
        for arg in (present, 250 if present != 250 else 249, -1):
            res = vol.compute("scan_count", arg=arg).result()
            want = _mirror("scan_count", shadow, 0, n_pages, arg)
            assert (res.value, res.status) == (want[0], want[1]), arg
            res = vol.compute("filter_pages", arg=arg).result()
            want = _mirror("filter_pages", shadow, 0, n_pages, arg)
            assert (res.value, res.status) == (want[0], want[1]), arg
            assert res.pages() == want[2], arg

        # verify_on_read: bytes + blocksum, with and without the check
        off = 3 * BB
        cur = py_blocksum(shadow[off:off + BB])
        for arg in (0, cur):
            res = vol.compute("verify_on_read", off, arg=arg).result()
            want = _mirror("verify_on_read", shadow,
                           (off // BB) // PB, (off // BB) % PB, arg)
            assert res.ok and res.value == want[0]
            assert res.data() == bytes(want[2])
        res = vol.compute("verify_on_read", off,
                          arg=py_i32((cur + 1) & 0xFFFFFFFF)).result()
        assert res.status == ST_MISMATCH and not res.ok
        assert res.value == cur       # actual blocksum still reported


@pytest.mark.parametrize("backend,n_shards,kernel", MATRIX)
def test_compare_and_write_commit_and_mismatch(backend, n_shards, kernel):
    with _mgr(backend, n_shards, kernel=kernel) as mgr:
        vol = mgr.create()
        vol.write(0, _pat(7, mgr.capacity))
        off = 2 * BB
        old = vol.read(off, BB)
        new = _pat(9, BB)

        # stale expectation: ST_MISMATCH (not OSError), bytes untouched
        res = vol.compute("compare_and_write", off, data=new,
                          arg=py_i32((py_blocksum(old) + 1)
                                     & 0xFFFFFFFF)).result()
        assert res.status == ST_MISMATCH
        assert res.value == py_blocksum(old)     # actual blocksum reported
        assert vol.read(off, BB) == old

        # matching expectation: committed, visible to subsequent reads
        res = vol.compute("compare_and_write", off, data=new,
                          arg=py_blocksum(old)).result()
        assert res.ok and res.value == py_blocksum(old)
        assert vol.read(off, BB) == new


def test_cas_is_cow_snapshot_preserved():
    """The CAS commit rides the CoW write path: a snapshot taken before
    the CAS keeps the frozen image while the head diverges."""
    with _mgr("ring", 2) as mgr:
        vol = mgr.create()
        vol.write(0, _pat(1, mgr.capacity))
        old = vol.read(0, BB)
        vol.snapshot()
        new = _pat(2, BB)
        res = vol.compute("compare_and_write", 0, data=new,
                          arg=py_blocksum(old)).result()
        assert res.ok
        assert vol.read(0, BB) == new
        child = vol.clone()   # clones fork the head (new bytes)
        assert child is not None and child.read(0, BB) == new


# ---------------------------------------------------------------------------
# 3. in-band ordering on the ring
# ---------------------------------------------------------------------------
def test_ring_compute_ordered_with_writes_in_one_drain():
    """write -> compute -> write -> compute, all submitted before one
    flush: each COMPUTE must observe exactly the writes submitted before
    it (data lanes batch ahead of compute lanes; a later write never
    jumps a pending compute)."""
    with _mgr("ring", 2) as mgr:
        vol = mgr.create()
        a, b = _pat(4, BB), _pat(5, BB)
        shadow = bytearray(mgr.capacity)
        f1 = vol.pwrite(0, a)
        shadow[:BB] = a
        c1 = vol.compute("verify_on_read", 0)
        want1 = bytes(shadow[:BB])
        f2 = vol.pwrite(0, b)
        shadow[:BB] = b
        c2 = vol.compute("verify_on_read", 0)
        want2 = bytes(shadow[:BB])
        mgr.flush()
        assert (f1.result(), f2.result()) == (BB, BB)
        assert c1.result().data() == want1 == a
        assert c2.result().data() == want2 == b


def test_ring_compute_with_control_on_sibling_shard():
    """One pump can drain control lanes on shard 0 while shard 1 drains
    COMPUTE lanes — the merged batch signature must still execute the
    compute phase (the cross-shard tier promotion in ``_canon``)."""
    with _mgr("ring", 2) as mgr:
        v0, v1 = mgr.create(), mgr.create()   # round-robin -> shards 0, 1
        data = _pat(6, mgr.capacity)
        v1.write(0, data)
        mgr.flush()
        # submit a control op (shard 0) and a compute (shard 1) into the
        # same drain window
        from repro.core.frontend import Request
        r = Request(req_id=1 << 20, kind="snapshot", volume=v0.vid)
        mgr.engine.submit(r)
        fut = v1.compute("verify_on_read", 0)
        mgr.flush()
        assert r.status == 0
        assert fut.result().data() == data[:BB]


def test_ring_batch_mixes_data_and_compute_lanes():
    """A read submitted after a CAS on the same block lands in a LATER
    batch (rank downgrade cuts), so it observes the committed bytes."""
    with _mgr("ring", 1) as mgr:
        vol = mgr.create()
        old = _pat(8, BB)
        vol.write(0, old)
        new = _pat(9, BB)
        f_cas = vol.compute("compare_and_write", 0, data=new,
                            arg=py_blocksum(old))
        f_read = vol.pread(0, BB)
        mgr.flush()
        assert f_cas.result().ok
        assert f_read.result() == new


# ---------------------------------------------------------------------------
# 4. registry + API surface
# ---------------------------------------------------------------------------
def test_registry_order_defines_fn_ids():
    fns = available_storage_fns()
    assert fns[:5] == ("checksum", "scan_count", "filter_pages",
                       "compare_and_write", "verify_on_read")
    for i, name in enumerate(fns):
        assert storage_fn_id(name) == i


def test_unknown_fn_raises_naming_registered():
    with pytest.raises(ValueError, match="checksum"):
        make_storage_fn("nope")
    with _mgr("host") as mgr:
        vol = mgr.create()
        with pytest.raises(ValueError, match="unknown storage function"):
            vol.compute("nope")


def test_compute_validates_scope_alignment_and_data():
    with _mgr("ring") as mgr:
        vol = mgr.create()
        with pytest.raises(ValueError, match="page-aligned"):
            vol.compute("checksum", 3)
        with pytest.raises(ValueError, match="block-aligned"):
            vol.compute("verify_on_read", 5)
        with pytest.raises(ValueError, match="exactly one block"):
            vol.compute("verify_on_read", 0, 2 * BB)
        with pytest.raises(ValueError, match="pass data="):
            vol.compute("compare_and_write", 0)
        with pytest.raises(ValueError, match="one block"):
            vol.compute("compare_and_write", 0, data=b"x")
        with pytest.raises(ValueError, match="does not take data"):
            vol.compute("checksum", data=b"y" * BB)
        with pytest.raises(ValueError, match="outside"):
            vol.compute("verify_on_read", mgr.capacity)


def test_custom_storage_fn_registers_and_runs():
    """Embedder surface: a registered function is immediately callable on
    a live ring manager (the program cache retraces on registry version)."""
    import jax.numpy as jnp

    def _apply(content, page, block, arg, payload):
        s = content.reshape(-1).astype(jnp.int32).sum()
        return (s, jnp.int32(0), jnp.zeros_like(payload),
                jnp.asarray(False))

    def _mirror(shadow, page_bytes, block_bytes, page, block, arg, data):
        return sum(shadow), 0, None

    name = "test_byte_sum"
    if name not in available_storage_fns():
        register_storage_fn(name, apply=_apply, host_ref=_apply,
                            mirror=_mirror)
    with _mgr("ring") as mgr:
        vol = mgr.create()
        data = _pat(11, mgr.capacity)
        vol.write(0, data)
        vol.compute("checksum").result()     # compile the pre-reg program
        res = vol.compute(name).result()
        assert res.value == sum(data) and res.ok


def test_compute_on_null_storage_raises():
    with pytest.raises(ValueError, match="storage functions"):
        with _mgr("fused", null_storage=True) as mgr:
            vol = mgr.create()
            vol.compute("checksum").result()
