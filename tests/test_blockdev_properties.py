"""Property test: random byte spans through the public block-device API
(hypothesis-driven; skipped when hypothesis is not installed).

Random interleavings of ``pwrite``/``pread``/``discard`` byte spans —
biased toward page edges, sub-block offsets, and cross-extent lengths —
are driven against a host-side bytearray reference on ``backend="ring"``
and ``backend="fused"`` (ISSUE 4 satellite). Async reads are checked
against the reference content at SUBMISSION time, pinning the manager's
sequential per-volume ordering semantics.
"""
import pytest

from repro.core.blockdev import VolumeManager

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

BB = 8          # block_bytes
PB = 4          # page_blocks -> page_bytes = 32
PAGES = 8       # capacity = 256 bytes
_CAP = PAGES * PB * BB

# offsets biased toward block edges, page edges, and extent crossings
_EDGES = sorted({0, 1, BB - 1, BB, BB + 1, PB * BB - 1, PB * BB,
                 PB * BB + 1, 2 * PB * BB - 1, _CAP - 1})
_OFF = st.one_of(st.sampled_from(_EDGES), st.integers(0, _CAP - 1))
_LEN = st.one_of(st.integers(0, 3 * BB), st.integers(0, 2 * PB * BB))
_OP = st.one_of(
    st.tuples(st.just("write"), _OFF, _LEN, st.integers(0, 250)),
    st.tuples(st.just("read"), _OFF, _LEN),
    st.tuples(st.just("discard"), _OFF, _LEN),
    st.tuples(st.just("flush")),
)

_MGRS = {}


def _pat(seed: int, n: int) -> bytes:
    return bytes((seed * 37 + i) % 251 for i in range(n))


def _cached_mgr(backend: str) -> VolumeManager:
    if backend not in _MGRS:        # reuse: keeps the jitted programs warm
        _MGRS[backend] = VolumeManager(
            backend=backend, n_shards=2 if backend == "ring" else 1,
            payload_elems=BB, page_blocks=PB, max_pages=PAGES,
            n_extents=512, max_volumes=16, batch=16)
    return _MGRS[backend]


@pytest.mark.parametrize("backend", ["ring", "fused"])
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(_OP, max_size=14))
def test_property_random_byte_spans(backend, ops):
    mgr = _cached_mgr(backend)
    v = mgr.create()
    ref = bytearray(mgr.capacity)
    try:
        checks = []
        for op in ops:
            if op[0] == "write":
                _, off, n, seed = op
                n = min(n, mgr.capacity - off)
                data = _pat(seed, n)
                v.pwrite(off, data)
                ref[off:off + n] = data
            elif op[0] == "read":
                _, off, n = op
                n = min(n, mgr.capacity - off)
                checks.append((v.pread(off, n), bytes(ref[off:off + n])))
            elif op[0] == "discard":
                _, off, n = op
                n = min(n, mgr.capacity - off)
                v.discard(off, n)
                ref[off:off + n] = bytes(n)
            else:
                mgr.flush()
        mgr.flush()
        for fut, want in checks:
            assert fut.result() == want
        assert v.read(0, mgr.capacity) == bytes(ref)
    finally:
        mgr.delete(v)
