"""The fused device-resident engine step (core/fused.py).

Three contracts:

1. the Pallas ``dbs_copy`` kernel data plane is exactly equivalent to the
   ``apply_write_ops`` gather/scatter reference on CoW batches — including
   masked lanes, failed lanes, and the input/output-aliased pool,
2. the fused engine reaches byte-identical volume contents vs the unfused
   ``comm="slots"`` multi-dispatch path on a mixed CoW workload,
3. a fused ``pump()`` performs exactly ONE ``device_get`` — at completion;
   nothing crosses the host between admission and completion.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request, dbs
from repro.core.fused import _cow_apply

KEY = jax.random.PRNGKey(0)


def _engine(comm, cow="auto", **kw):
    base = dict(comm=comm, storage="dbs", cow=cow, payload_shape=(8,),
                n_extents=256, max_pages=128, batch=16, n_replicas=2)
    base.update(kw)
    return Engine(EngineConfig(**base))


# ---------------------------------------------------------------------------
# 1. kernel vs apply_write_ops equivalence
# ---------------------------------------------------------------------------
def test_cow_kernel_matches_ref_on_crafted_ops():
    """Hand-built WriteOps covering every lane species: CoW, in-place, hole
    fill, failed (dst=-1), and a CoW landing on extent 0 (the index real
    failed lanes would clamp onto)."""
    # last row is the fused data plane's scratch extent (never a real dst)
    e, page, d = 16, 4, 8
    pool = jax.random.normal(KEY, (e, page, d))
    ops = dbs.WriteOps(
        dst=jnp.asarray([10, 2, -1, 0, 5, -1], jnp.int32),
        cow_src=jnp.asarray([1, -1, -1, 3, -1, 4], jnp.int32),
        ok=jnp.asarray([True, True, False, True, True, False]))
    payload = jax.random.normal(jax.random.PRNGKey(1), (6, d))
    blocks = jnp.asarray([0, 3, 1, 2, 1, 0], jnp.int32)
    ref = dbs.apply_write_ops(pool, ops, payload, blocks)
    out = _cow_apply(pool, ops, payload, blocks, "pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
    # aliasing contract: extents named by no ok lane are untouched
    touched = {10, 2, 0, 5}
    for i in range(e):
        if i not in touched:
            np.testing.assert_allclose(np.asarray(out[i]),
                                       np.asarray(pool[i]))


def test_cow_kernel_matches_ref_on_write_pages_ops():
    """Ops produced by the real control plane: fill pages, snapshot (so every
    overwrite is a CoW), overwrite a masked batch; both data planes must
    produce the same pool."""
    st = dbs.make_state(64, 2, 16)
    st, vol = dbs.create_volume(st)
    pool = jax.random.normal(KEY, (65, 8, 4))   # +1 scratch row (engine conv)
    pages = jnp.arange(8)
    bits = jnp.full((8,), 1, jnp.uint32)
    st, ops = dbs.write_pages(st, vol, pages, bits)
    payload = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    blocks = jnp.arange(8, dtype=jnp.int32) % 8
    pool = dbs.apply_write_ops(pool, ops, payload, blocks)
    st, _ = dbs.snapshot(st, vol)
    # masked overwrite: half the lanes are inert (the fused step's read lanes)
    mask = jnp.arange(8) % 2 == 0
    st, ops = dbs.write_pages(st, vol, pages, bits, mask)
    assert bool(jnp.any(ops.cow_src >= 0)), "expected CoW lanes"
    payload2 = jax.random.normal(jax.random.PRNGKey(3), (8, 4))
    ref = dbs.apply_write_ops(pool, ops, payload2, blocks)
    out = _cow_apply(pool, ops, payload2, blocks, "pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# 2. fused engine == unfused engine, end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cow", ["ref", "pallas"])
def test_fused_matches_slots_volume_contents(cow):
    engs = [_engine("slots"), _engine("fused", cow)]
    vols = [e.create_volume() for e in engs]
    for i in range(60):                       # base data
        pay = jnp.full((8,), float(i + 1))
        for e, v in zip(engs, vols):
            e.submit(Request(req_id=i, kind="write", volume=v, page=i % 48,
                             block=i % 8, payload=pay))
    for e in engs:
        assert e.drain() == 60
    for e, v in zip(engs, vols):
        e.snapshot(v)
    for i in range(30):                       # CoW overwrites + reads mixed in
        pay = jnp.full((8,), float(1000 + i))
        for e, v in zip(engs, vols):
            e.submit(Request(req_id=i, kind="write", volume=v, page=i % 24,
                             block=(i * 3) % 8, payload=pay))
            e.submit(Request(req_id=i + 500, kind="read", volume=v,
                             page=i % 24, block=0))
    done = [e.drain() for e in engs]
    assert done[0] == done[1] == 60
    pages = jnp.arange(48, dtype=jnp.int32)
    for blk in range(8):
        offs = jnp.full((48,), blk, jnp.int32)
        a = engs[0].backend.read(vols[0], pages, offs)
        b = engs[1].backend.read(vols[1], pages, offs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=f"block {blk}")
    # mirrored: both replicas of the fused engine agree too
    assert engs[1].backend.consistent()


def test_fused_read_results_delivered():
    eng = _engine("fused")
    vol = eng.create_volume()
    eng.submit(Request(req_id=0, kind="write", volume=vol, page=3, block=2,
                       payload=jnp.full((8,), 7.0)))
    eng.drain()
    r = Request(req_id=1, kind="read", volume=vol, page=3, block=2)
    eng.submit(r)
    eng.drain()
    np.testing.assert_allclose(np.asarray(r.result), np.full((8,), 7.0))


def test_fused_null_rows_complete():
    """The ladder's layer cuts run through the fused path too."""
    for kw in (dict(null_backend=True), dict(null_storage=True)):
        eng = _engine("fused", **kw)
        vol = eng.create_volume()
        for i in range(40):
            eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                               volume=vol, page=i % 64, block=0,
                               payload=jnp.ones((8,))))
        assert eng.drain() == 40, kw


def test_fused_survives_replica_failure():
    eng = _engine("fused")
    vol = eng.create_volume()
    for i in range(20):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=jnp.full((8,), float(i))))
    eng.drain()
    eng.backend.fail(0)
    for i in range(10):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=20 + i,
                           block=0, payload=jnp.full((8,), float(100 + i))))
        eng.submit(Request(req_id=i + 500, kind="read", volume=vol, page=i,
                           block=0))
    assert eng.drain() == 20
    eng.backend.rebuild(0)
    assert eng.backend.consistent()


# ---------------------------------------------------------------------------
# 3. host-hop accounting
# ---------------------------------------------------------------------------
def test_fused_pump_is_single_host_hop(monkeypatch):
    """Within one pump(): zero device_get between admission and completion —
    the only fetch is the completion readback itself."""
    eng = _engine("fused")
    vol = eng.create_volume()
    for i in range(10):
        eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                           volume=vol, page=i, block=0,
                           payload=jnp.ones((8,))))
    eng.pump()                     # warm the compiled program first
    for i in range(10):
        eng.submit(Request(req_id=100 + i, kind="write" if i % 2 else "read",
                           volume=vol, page=i, block=0,
                           payload=jnp.ones((8,))))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    done = eng.pump()
    assert done == 10
    assert len(calls) == 1, f"expected 1 completion fetch, saw {len(calls)}"


def test_unfused_pump_hops_more(monkeypatch):
    """Sanity check on the baseline: the comm='slots' path really does cross
    the host mid-iteration (admission ids/ok), so the fused column's claim
    is measuring a real difference."""
    eng = _engine("slots")
    vol = eng.create_volume()
    for i in range(10):
        eng.submit(Request(req_id=i, kind="write", volume=vol, page=i,
                           block=0, payload=jnp.ones((8,))))
    eng.pump()
    for i in range(10):
        eng.submit(Request(req_id=100 + i, kind="write", volume=vol, page=i,
                           block=0, payload=jnp.ones((8,))))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    eng.pump()
    assert len(calls) >= 2


# ---------------------------------------------------------------------------
# ladder integration
# ---------------------------------------------------------------------------
def test_ladder_has_fused_column():
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.ladder import COLUMNS, make_engine
    assert "+fused" in COLUMNS
    eng = make_engine("+fused", "full_engine", payload_shape=(8,),
                      max_pages=64, n_extents=256)
    assert eng.cfg.comm == "fused"
    vol = eng.create_volume()
    for i in range(20):
        eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                           volume=vol, page=i % 32, block=i % 8,
                           payload=jnp.ones((8,))))
    assert eng.drain() == 20
