"""Durability subsystem (ISSUE 10): write-ahead journal, crash recovery,
incremental snapshot export, and the cold-extent spill tier.

Contracts:

1. **journal format** — record encode/decode roundtrips every WireMsg
   field the journal carries; the reader commits records batch-by-batch at
   each seal, drops unsealed records, and stops at the first torn/short/
   mis-summed frame; reopening a journal truncates the torn tail and
   resumes the sequence numbering.
2. **crash-at-every-pump-boundary recovery** — on host/fused/sharded/ring,
   abandoning the manager (never closed — a dead process) after each
   durable flush and recovering from the WAL yields volumes byte-identical
   to a bytearray shadow oracle, through writes (aligned and unaligned),
   snapshots, clones and discards; a half-written record torn onto the
   tail is detected and dropped.
3. **incremental export exactness** — each ``export()`` ships exactly the
   extents backing pages whose ``page_rev`` advanced past the previous
   section's watermark (transport-style counters are the assertion
   handle); install + tail replay recovers a fused manager from the last
   export plus only the records sealed after it; backends without a flat
   replica plane fall back to full-journal replay.
4. **spill tier** — at 2x pool over-subscription the fused engine serves
   every byte correctly (spills and fills both observed), CoW snapshots
   and clones keep frozen images, and ``tier=`` on a non-fused backend is
   a config error.
5. **checkpoint stream rebuild** — a lost ``ReplicatedCheckpoint`` replica
   rebuilds by streaming the donor's committed volumes through the public
   block paths, with STREAM-verb accounting.
"""
import os

import numpy as np
import pytest

from repro.core.blockdev import VolumeManager
from repro.core.transport import MSG_SNAPSHOT, MSG_UNMAP, MSG_WRITE, WireMsg
from repro.durability import (ExtentTier, Journal, OP_COMPUTE, OP_SEAL,
                              SnapshotExport, read_journal, recover)
from repro.durability.journal import decode_record, encode_record

BB = 16         # block_bytes
PB = 4          # page_blocks -> page_bytes = 64
PAGES = 8       # capacity = 512 bytes per volume

# the recovery acceptance matrix: flat replica plane (fused installs
# exports wholesale) and the full-replay fallbacks (host/sharded/ring)
MATRIX = [("host", 1), ("fused", 1), ("sharded", 2), ("ring", 2)]


def _kw(backend: str, n_shards: int = 1, **kw) -> dict:
    base = dict(backend=backend, n_shards=n_shards, payload_elems=BB,
                page_blocks=PB, max_pages=PAGES, n_extents=256,
                max_volumes=16, batch=16, n_replicas=2)
    base.update(kw)
    return base


def _pat(seed: int, n: int) -> bytes:
    return bytes((seed * 37 + i * 11) % 251 for i in range(n))


# ---------------------------------------------------------------------------
# 1. journal format
# ---------------------------------------------------------------------------
def test_np_blocksum_matches_py_blocksum():
    """The journal's vectorized record checksum is the SAME rotate/XOR
    fold the compute registry runs in-band."""
    from repro.compute.functions import (np_blocksum, np_blocksum_many,
                                         py_blocksum)
    rng = np.random.default_rng(7)
    for n in (0, 1, 30, 31, 32, 63, 257, 4096):
        blob = bytes(rng.integers(0, 256, n, dtype=np.uint8))
        assert np_blocksum(blob) == py_blocksum(blob)
    blobs = [bytes(rng.integers(0, 256, n, dtype=np.uint8))
             for n in (27, 1, 31, 32, 100, 313)]
    assert np_blocksum_many(blobs) == [py_blocksum(b) for b in blobs]


def test_coalesce_writes_merges_adjacent_same_volume():
    from repro.durability.journal import coalesce_writes
    w = [WireMsg(op=MSG_WRITE, volume=0, pages=[i], blocks=[i % PB],
                 payload=bytes([i] * BB)) for i in range(3)]
    other = WireMsg(op=MSG_WRITE, volume=1, pages=[5], blocks=[0],
                    payload=bytes(BB))
    ctl = WireMsg(op=MSG_SNAPSHOT, volume=0, meta=(1, 0))
    out = coalesce_writes([w[0], w[1], other, ctl, w[2]])
    assert [m.op for m in out] == [MSG_WRITE, MSG_WRITE, MSG_SNAPSHOT,
                                   MSG_WRITE]
    merged = out[0]                   # w0+w1: one record, order preserved
    assert merged.pages == [0, 1] and merged.blocks == [0, 1]
    assert merged.payload == w[0].payload + w[1].payload
    assert out[1].volume == 1 and out[3].pages == [2]
    # ndarray-shaped records (tests/tools) pass through unmerged
    nd = WireMsg(op=MSG_WRITE, volume=0, pages=np.asarray([0], np.int32),
                 blocks=np.asarray([0], np.int32),
                 payload=np.zeros((1, BB), np.float32))
    assert len(coalesce_writes([nd, nd])) == 2


def test_record_roundtrip_write():
    lanes = np.arange(2 * BB, dtype=np.float32).reshape(2, BB)
    msg = WireMsg(op=MSG_WRITE, volume=3, pages=np.asarray([1, 2], np.int32),
                  blocks=np.asarray([0, 3], np.int32), payload=lanes)
    rec = encode_record(7, msg)
    back = decode_record(rec[12:-4])          # strip frame + checksum
    assert back.op == MSG_WRITE and back.volume == 3
    np.testing.assert_array_equal(back.pages, [1, 2])
    np.testing.assert_array_equal(back.blocks, [0, 3])
    np.testing.assert_array_equal(back.payload, lanes)


def test_record_roundtrip_control_and_compute():
    ctl = decode_record(encode_record(1, WireMsg(
        op=MSG_SNAPSHOT, volume=2, meta=(9, 0)))[12:-4])
    assert (ctl.op, ctl.volume, ctl.meta[0]) == (MSG_SNAPSHOT, 2, 9)
    comp = decode_record(encode_record(2, WireMsg(
        op=OP_COMPUTE, volume=1, pages=np.asarray([4], np.int32),
        blocks=np.asarray([2], np.int32), extents=b"compare_and_write",
        meta=(123, 0), payload=b"\x01\x02\x03"))[12:-4])
    assert comp.op == OP_COMPUTE
    assert bytes(comp.extents) == b"compare_and_write"
    assert comp.meta == (123, 0)
    assert bytes(comp.payload) == b"\x01\x02\x03"


def test_journal_group_commit_and_resume(tmp_path):
    path = str(tmp_path / "wal.dbsj")
    j = Journal(path)
    msgs = [WireMsg(op=MSG_WRITE, volume=0,
                    pages=np.asarray([i], np.int32),
                    blocks=np.asarray([0], np.int32),
                    payload=np.full((1, BB), i, np.float32))
            for i in range(3)]
    j.append_batch(msgs)                      # ONE append: 3 records + seal
    j.append_batch(msgs[:1])
    assert (j.appends, j.records) == (2, 4)
    j.sync()
    j.close()
    view = read_journal(path)
    assert len(view.records) == 4 and not view.torn and view.dropped == 0
    assert [s for s, _ in view.records] == [1, 2, 3, 5]   # 4 is the seal
    j2 = Journal(path)                        # resume: seq continues
    assert j2.seq == view.last_seq
    j2.append_batch(msgs[:1])
    assert j2.seq == view.last_seq + 2
    j2.close()


def test_torn_tail_detected_and_truncated(tmp_path):
    path = str(tmp_path / "wal.dbsj")
    j = Journal(path)
    j.append_batch([WireMsg(op=MSG_UNMAP, volume=0,
                            pages=np.asarray([1], np.int32))])
    j.close()
    good = os.path.getsize(path)
    rec = encode_record(99, WireMsg(op=MSG_UNMAP, volume=1,
                                    pages=np.asarray([2], np.int32)))
    with open(path, "ab") as f:               # crash mid-append
        f.write(rec[:len(rec) // 2])
    view = read_journal(path)
    assert view.torn and len(view.records) == 1
    assert view.valid_bytes == good
    j2 = Journal(path)                        # reopen truncates the tail
    j2.close()
    assert os.path.getsize(path) == good
    assert not read_journal(path).torn


def test_unsealed_records_dropped(tmp_path):
    path = str(tmp_path / "wal.dbsj")
    j = Journal(path)
    j.append_batch([WireMsg(op=MSG_UNMAP, volume=0,
                            pages=np.asarray([1], np.int32))])
    j.close()
    with open(path, "ab") as f:               # two intact but UNSEALED recs
        f.write(encode_record(50, WireMsg(op=MSG_UNMAP, volume=1,
                                          pages=np.asarray([2], np.int32))))
        f.write(encode_record(51, WireMsg(op=MSG_UNMAP, volume=1,
                                          pages=np.asarray([3], np.int32))))
    view = read_journal(path)
    assert len(view.records) == 1 and view.dropped == 2 and not view.torn


def test_corrupt_checksum_tears(tmp_path):
    path = str(tmp_path / "wal.dbsj")
    j = Journal(path)
    j.append_batch([WireMsg(op=MSG_UNMAP, volume=0,
                            pages=np.asarray([1], np.int32))])
    j.append_batch([WireMsg(op=MSG_UNMAP, volume=0,
                            pages=np.asarray([2], np.int32))])
    j.close()
    view0 = read_journal(path)
    with open(path, "r+b") as f:              # flip one body byte of the
        f.seek(os.path.getsize(path) - 20)    # last batch
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    view = read_journal(path)
    assert view.torn and len(view.records) < len(view0.records)


# ---------------------------------------------------------------------------
# 2. crash-at-every-pump-boundary recovery vs the shadow oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,n_shards", MATRIX)
def test_crash_at_every_pump_boundary(tmp_path, backend, n_shards):
    kw = _kw(backend, n_shards)
    jp = str(tmp_path / "wal.dbsj")
    mgr = VolumeManager(journal=jp, **kw)
    cap = mgr.capacity
    shadow = {}
    for _ in range(2):
        shadow[mgr.create().vid] = bytearray(cap)
    vids = sorted(shadow)
    try:
        for burst in range(6):
            for i in range(3):
                vid = vids[(burst + i) % len(vids)]
                off = ((burst * 37 + i * 13) * 7) % (cap - 64)
                n = 9 + (burst * 11 + i * 5) % 48      # unaligned spans too
                data = _pat(burst * 10 + i, n)
                mgr.pwrite(vid, off, data)
                shadow[vid][off:off + n] = data
            if burst == 2:
                mgr.snapshot(vids[0])
            if burst == 3:
                child = mgr.clone(vids[0])
                assert child is not None
                shadow[child.vid] = bytearray(shadow[vids[0]])
                vids.append(child.vid)
            if burst == 4:
                mgr.discard(vids[1], 32, 3 * mgr.page_bytes)
                shadow[vids[1]][32:32 + 3 * mgr.page_bytes] = bytes(
                    3 * mgr.page_bytes)
            mgr.flush(durable=True)
            if burst % 2 == 1:                # every 2nd crash mid-append
                rec = encode_record(10 ** 9, WireMsg(
                    op=MSG_WRITE, volume=0,
                    pages=np.asarray([0], np.int32),
                    blocks=np.asarray([0], np.int32),
                    payload=np.zeros((1, BB), np.float32)))
                with open(jp, "ab") as f:
                    f.write(rec[:len(rec) // 2])
            mgr = recover(jp, **kw)           # dead mgr abandoned, not closed
            info = mgr.recovery_info
            assert info["replayed"] == info["sealed_records"] > 0
            assert info["torn_tail"] == (burst % 2 == 1)
            for vid in vids:
                got = mgr.open(vid).read(0, cap)
                assert got == bytes(shadow[vid]), (
                    f"{backend}: vol {vid} diverged after crash {burst}")
    finally:
        mgr.close()


def test_recovered_manager_keeps_journaling(tmp_path):
    """Reattach: the recovered manager appends to the same file, and a
    SECOND crash+recovery replays both generations of records."""
    kw = _kw("fused")
    jp = str(tmp_path / "wal.dbsj")
    mgr = VolumeManager(journal=jp, **kw)
    vid = mgr.create().vid
    mgr.pwrite(vid, 0, _pat(1, 100))
    mgr.flush(durable=True)
    mgr = recover(jp, **kw)
    mgr.pwrite(vid, 50, _pat(2, 100))         # journaled via the reattached
    mgr.flush(durable=True)                   # handle
    mgr = recover(jp, **kw)
    want = bytearray(mgr.capacity)
    want[0:100] = _pat(1, 100)
    want[50:150] = _pat(2, 100)
    assert mgr.open(vid).read(0, mgr.capacity) == bytes(want)
    assert mgr.recovery_info["replayed"] >= 3  # create + both writes
    mgr.close()


def test_replay_refuses_attached_journal(tmp_path):
    from repro.durability.recovery import replay
    jp = str(tmp_path / "wal.dbsj")
    mgr = VolumeManager(journal=jp, **_kw("host"))
    mgr.create()
    mgr.flush(durable=True)
    with pytest.raises(ValueError, match="detach"):
        replay(mgr, read_journal(jp))
    mgr.close()


def test_mutating_compute_journaled_and_replayed(tmp_path):
    """compare_and_write is write-ahead logged (OP_COMPUTE) and re-runs on
    replay; read-only functions leave no record."""
    from repro.compute.functions import py_blocksum
    kw = _kw("ring", 2)
    jp = str(tmp_path / "wal.dbsj")
    mgr = VolumeManager(journal=jp, **kw)
    vid = mgr.create().vid
    old = _pat(3, BB)
    mgr.pwrite(vid, 0, old)
    mgr.flush()
    new = _pat(4, BB)
    res = mgr.compute(vid, "compare_and_write", 0, BB,
                      arg=py_blocksum(old), data=new).result()
    assert res.ok
    mgr.compute(vid, "checksum").result()     # read-only: not journaled
    mgr.flush(durable=True)
    ops = [m.op for _, m in read_journal(jp).records]
    assert ops.count(OP_COMPUTE) == 1
    mgr = recover(jp, **kw)
    assert mgr.open(vid).read(0, BB) == new
    mgr.close()


# ---------------------------------------------------------------------------
# 3. incremental export: watermark exactness, install + tail replay
# ---------------------------------------------------------------------------
def test_export_ships_exactly_the_delta(tmp_path):
    kw = _kw("fused")
    mgr = VolumeManager(**kw)
    vid = mgr.create().vid
    pby = mgr.page_bytes
    for p in range(4):                        # map 4 extents
        mgr.pwrite(vid, p * pby, _pat(p, pby))
    mgr.flush()
    exp = SnapshotExport(str(tmp_path / "inc.dbsx"))
    first = exp.export(mgr)
    assert first["extents_moved"] == 4
    mgr.pwrite(vid, 1 * pby, _pat(9, pby))    # touch exactly 2 pages
    mgr.pwrite(vid, 3 * pby, _pat(8, pby))
    mgr.flush()
    second = exp.export(mgr)
    assert second["extents_moved"] == 2       # the post-watermark extents
    third = exp.export(mgr)                   # nothing moved since
    assert third["extents_moved"] == 0
    assert exp.counters.sent["EXPORT"] == 3
    assert exp.counters.extents_moved == 6
    mgr.close()


def test_export_install_plus_tail_replay(tmp_path):
    kw = _kw("fused")
    jp = str(tmp_path / "wal.dbsj")
    xp = str(tmp_path / "inc.dbsx")
    mgr = VolumeManager(journal=jp, **kw)
    vid = mgr.create().vid
    mgr.pwrite(vid, 0, _pat(1, 200))
    mgr.flush(durable=True)
    SnapshotExport(xp).export(mgr, journal=mgr._journal)
    mgr.pwrite(vid, 100, _pat(2, 200))        # the tail past the export
    mgr.flush(durable=True)
    mgr = recover(jp, export=xp, **kw)
    info = mgr.recovery_info
    assert info["installed"] is not None and info["after_seq"] > 0
    assert 0 < info["replayed"] < info["sealed_records"]   # tail only
    want = bytearray(mgr.capacity)
    want[0:200] = _pat(1, 200)
    want[100:300] = _pat(2, 200)
    assert mgr.open(vid).read(0, mgr.capacity) == bytes(want)
    mgr.close()


def test_export_fallback_to_full_replay(tmp_path):
    """A backend without a flat replica plane ignores the export and
    replays the whole journal."""
    kw = _kw("sharded", 2)
    jp = str(tmp_path / "wal.dbsj")
    xp = str(tmp_path / "inc.dbsx")
    donor = VolumeManager(**_kw("fused"))     # export from a fused twin
    donor.create()
    donor.flush()
    SnapshotExport(xp).export(donor)
    donor.close()
    mgr = VolumeManager(journal=jp, **kw)
    vid = mgr.create().vid
    mgr.pwrite(vid, 0, _pat(5, 300))
    mgr.flush(durable=True)
    mgr = recover(jp, export=xp, **kw)
    info = mgr.recovery_info
    assert info["installed"] is None and info["after_seq"] == 0
    assert mgr.open(vid).read(0, 300) == _pat(5, 300)
    mgr.close()


def test_export_reload_from_disk(tmp_path):
    """A reopened export file sees the committed sections (header count),
    and install replays sections in order — later rows win."""
    kw = _kw("fused")
    xp = str(tmp_path / "inc.dbsx")
    mgr = VolumeManager(**kw)
    vid = mgr.create().vid
    pby = mgr.page_bytes
    exp = SnapshotExport(xp)
    mgr.pwrite(vid, 0, _pat(1, pby))
    mgr.flush()
    exp.export(mgr)
    mgr.pwrite(vid, 0, _pat(2, pby))          # same page, newer content
    mgr.flush()
    exp.export(mgr)
    mgr.close()
    exp2 = SnapshotExport(xp)                 # reload
    assert exp2.sections == 2
    fresh = VolumeManager(**kw)
    try:
        exp2.install(fresh)
        assert fresh.open(vid).read(0, pby) == _pat(2, pby)
    finally:
        fresh.close()


# ---------------------------------------------------------------------------
# 4. the cold-extent spill tier
# ---------------------------------------------------------------------------
def test_tier_serves_reads_at_2x_over_subscription():
    # 2 volumes x PAGES pages = 16 mapped extents vs an 8-extent budget
    mgr = VolumeManager(tier=PAGES, **_kw("fused"))
    cap, pby = mgr.capacity, mgr.page_bytes
    vids = [mgr.create().vid for _ in range(2)]
    for k, vid in enumerate(vids):
        for p in range(PAGES):
            mgr.pwrite(vid, p * pby, _pat(k * 100 + p, pby))
    mgr.flush()
    st = mgr.stats()["tier"]
    assert st["device_extents"] == PAGES
    assert st["spills"] >= 1 and st["resident"] <= PAGES
    for k, vid in enumerate(vids):            # every byte served correctly
        got = mgr.open(vid).read(0, cap)
        want = b"".join(_pat(k * 100 + p, pby) for p in range(PAGES))
        assert got == want
    assert mgr.stats()["tier"]["fills"] >= 1  # reads faulted extents in
    mgr.close()


def test_tier_cow_snapshot_and_clone():
    mgr = VolumeManager(tier=PAGES, **_kw("fused"))
    pby = mgr.page_bytes
    vid = mgr.create().vid
    for p in range(PAGES):
        mgr.pwrite(vid, p * pby, _pat(p, pby))
    child = mgr.clone(vid)
    for p in range(PAGES // 2):               # CoW: child keeps the frozen
        mgr.pwrite(vid, p * pby, _pat(50 + p, pby))
    mgr.flush()
    for p in range(PAGES):
        want_v = _pat(50 + p if p < PAGES // 2 else p, pby)
        assert mgr.open(vid).read(p * pby, pby) == want_v
        assert child.read(p * pby, pby) == _pat(p, pby)
    mgr.close()


def test_tier_discard_and_reallocate():
    """A spilled-then-freed extent must NOT fault stale bytes over a fresh
    allocation (the tier's mapped-only eviction + reconcile rule)."""
    mgr = VolumeManager(tier=4, **_kw("fused"))
    pby = mgr.page_bytes
    vid = mgr.create().vid
    for p in range(PAGES):
        mgr.pwrite(vid, p * pby, _pat(p, pby))
    mgr.flush()                               # force spills (8 mapped vs 4)
    mgr.discard(vid, 0, mgr.capacity)         # free everything
    for p in range(PAGES):                    # reallocate with new content
        mgr.pwrite(vid, p * pby, _pat(70 + p, pby))
    mgr.flush()
    for p in range(PAGES):
        assert mgr.open(vid).read(p * pby, pby) == _pat(70 + p, pby)
    mgr.close()


def test_tier_requires_fused_backend():
    with pytest.raises(ValueError, match="fused"):
        VolumeManager(tier=4, **_kw("ring", 2))


def test_tier_budget_validation():
    with pytest.raises(ValueError):
        ExtentTier(16, 0)


# ---------------------------------------------------------------------------
# 5. checkpoint stream rebuild + the journal in manager stats
# ---------------------------------------------------------------------------
def test_checkpoint_rebuild_streams_blocks(tmp_path):
    from repro.checkpoint import ReplicatedCheckpoint
    dirs = [str(tmp_path / d) for d in "ab"]
    for d in dirs:
        os.makedirs(d)
    rc = ReplicatedCheckpoint(dirs, capacity_bytes=1 << 24)
    tree = {"w": np.arange(512, dtype=np.float32).reshape(16, 32)}
    rc.save("train", 4, tree)
    rc.fail(1)
    info = rc.rebuild(1)
    assert info is rc.last_rebuild
    assert info["volumes"] and info["counters"]["sent"]["STREAM"] >= 1
    assert info["counters"]["bytes_moved"] > 0
    step, back = rc.stores[1].restore("train", like=tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(back["w"]), tree["w"])
    rc.close()


def test_stats_expose_journal_counters(tmp_path):
    jp = str(tmp_path / "wal.dbsj")
    mgr = VolumeManager(journal=jp, **_kw("fused"))
    vid = mgr.create().vid
    for i in range(3):
        mgr.pwrite(vid, i * BB, _pat(i, BB))
    mgr.flush(durable=True)
    js = mgr.stats()["journal"]
    # create + the 3 adjacent same-volume writes coalesced into ONE record
    assert js["records"] == 2
    assert js["appends"] <= 2                 # group commit, not per-op
    mgr.close()


def test_harness_crash_scenario():
    """The chaos harness's crash/journal scenario: kill at fixed pump
    boundaries (one torn), recover, oracle sweep clean — and deterministic."""
    from repro.harness.runner import run_scenario
    res = run_scenario("crash/journal", n_ops=80)
    res.raise_if_failed()
    assert res.crashes == 1
    assert any("crash" in e for e in res.events_applied)
