"""Registry parity (ISSUE 9 satellite): the four extension registries —
engine backends, replica transports, DBS kernels, storage functions —
share one contract:

* unknown lookups raise ``ValueError`` naming the registered entries,
* duplicate registration raises ``ValueError`` pointing at
  ``override=True``,
* ``override=True`` replaces the entry in place,

covered by ONE parametrized test per behaviour so a new registry (or a
drive-by change to one of them) can't silently diverge from the others.
"""
import pytest

import repro.compute.registry as _sfreg
import repro.core.backends as _bereg
import repro.core.transport as _trreg
import repro.kernels.dbs.registry as _krreg
from repro.compute import (available_storage_fns, make_storage_fn,
                           register_storage_fn)
from repro.core.backends import (available_backends, make_backend,
                                 register_backend)
from repro.core.transport import (available_transports, make_transport,
                                  register_transport)
from repro.kernels.dbs import available_kernels, make_kernel, register_kernel


def _noop_apply(content, page, block, arg, payload):  # pragma: no cover
    raise AssertionError("parity-test storage fn must never execute")


class _Reg:
    """One registry's uniform surface, plus enough to register (and then
    scrub) a throwaway entry without perturbing the real table."""

    def __init__(self, label, module, register, lookup, available, known):
        self.label = label
        self._dict = module._REGISTRY
        self.register = register
        self.lookup = lookup
        self.available = available
        self.known = known          # a built-in that must be named in errors

    def add(self, name, **kw):
        if self.label == "backend":
            return register_backend(name, lambda cfg: None, **kw)
        if self.label == "transport":
            return register_transport(name, lambda ep, **o: None, **kw)
        if self.label == "kernel":
            return register_kernel(name, write=lambda *a: None,
                                   read=lambda *a: None, **kw)
        return register_storage_fn(name, apply=_noop_apply, **kw)

    def scrub(self, name):
        self._dict.pop(name, None)


REGISTRIES = [
    _Reg("backend", _bereg, register_backend,
         lambda n: make_backend(n, None), available_backends, "ring"),
    _Reg("transport", _trreg, register_transport,
         lambda n: make_transport(n, None), available_transports, "local"),
    _Reg("kernel", _krreg, register_kernel,
         make_kernel, available_kernels, "xla"),
    _Reg("storage-fn", _sfreg, register_storage_fn,
         make_storage_fn, available_storage_fns, "checksum"),
]
_IDS = [r.label for r in REGISTRIES]


@pytest.mark.parametrize("reg", REGISTRIES, ids=_IDS)
def test_unknown_lookup_raises_naming_registered(reg):
    with pytest.raises(ValueError, match="unknown") as ei:
        reg.lookup("definitely_not_registered")
    msg = str(ei.value)
    assert "definitely_not_registered" in msg
    assert "registered" in msg and reg.known in msg


@pytest.mark.parametrize("reg", REGISTRIES, ids=_IDS)
def test_duplicate_registration_raises_pointing_at_override(reg):
    name = f"_parity_{reg.label.replace('-', '_')}"
    try:
        reg.add(name)
        with pytest.raises(ValueError, match="duplicate") as ei:
            reg.add(name)
        assert "override=True" in str(ei.value)
        # a BUILT-IN duplicate is rejected the same way
        with pytest.raises(ValueError, match="duplicate"):
            reg.add(reg.known)
    finally:
        reg.scrub(name)


@pytest.mark.parametrize("reg", REGISTRIES, ids=_IDS)
def test_override_replaces_in_place(reg):
    name = f"_parity_{reg.label.replace('-', '_')}"
    try:
        reg.add(name)
        before = len(reg.available())
        reg.add(name, override=True)
        assert len(reg.available()) == before
        assert name in reg.available()
    finally:
        reg.scrub(name)


def test_all_four_registries_nonempty_and_disjoint_namespaces():
    """The built-ins every other test relies on are present."""
    assert "ring" in available_backends() and "host" in available_backends()
    assert "local" in available_transports()
    assert {"xla", "pallas"} <= set(available_kernels())
    assert available_storage_fns()[:5] == (
        "checksum", "scan_count", "filter_pages", "compare_and_write",
        "verify_on_read")
